"""AOT driver: lower the L2 model to HLO text artifacts + weights blob.

Run once at build time (``make artifacts``); the rust coordinator then
loads everything from ``artifacts/`` and python never touches the request
path again.

Interchange format is HLO *text* (not serialized HloModuleProto): jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import (
    CONFIGS,
    DECODE_BATCH_BUCKETS,
    PREFILL_BUCKETS,
    SELECT_VARIANTS,
    ModelConfig,
)

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def make_weights(cfg: ModelConfig, seed: int = 42) -> dict:
    """Deterministic synthetic weights (substitute for pretrained ones).

    The V/O projections are scaled up (and the embedding down) so the
    attention branch — a slowly-varying average over history — dominates
    the residual stream. This reproduces the trained-model property the
    paper's speculative retrieval rests on: adjacent-step query cosine
    similarity ~0.9 on layers > 0 and ~0 on layer 0 (Fig. 3a / Table 8 and
    the paper's observation that compression is not applied to the first
    layer). Calibration: scales (v x3, o x8, embed x0.5) measured mean
    per-layer similarity [0.00, 0.92, 0.97, 0.96] on the tiny config.
    """
    rng = np.random.default_rng(seed)
    w = {}

    def init(shape, std):
        return rng.normal(0.0, std, size=shape).astype(np.float32)

    gshapes = model.global_weight_shapes(cfg)
    w["embed"] = init(gshapes["embed"], 0.02 * 0.5)
    w["ln_f"] = np.ones(gshapes["ln_f"], np.float32)
    lshapes = model.layer_weight_shapes(cfg)
    for i in range(cfg.n_layers):
        for name, shape in lshapes.items():
            if name.startswith("ln"):
                w[f"layers.{i}.{name}"] = np.ones(shape, np.float32)
            else:
                # residual-branch scaling keeps activations O(1) deep in
                # the random net so golden logits are well-conditioned
                std = 0.02 / np.sqrt(2 * cfg.n_layers) if name in ("wo", "wd") else 0.02
                if name == "wv":
                    std *= 3.0
                if name == "wo":
                    std *= 8.0
                w[f"layers.{i}.{name}"] = init(shape, std)
    return w


def write_weights(w: dict, path: str):
    """Flat little-endian f32 blob + tensor table (offsets in floats)."""
    table, off = [], 0
    with open(path, "wb") as f:
        for name in sorted(w):
            arr = np.ascontiguousarray(w[name], np.float32)
            f.write(arr.tobytes())
            table.append(
                {"name": name, "shape": list(arr.shape), "offset": off, "size": arr.size}
            )
            off += arr.size
    return table


# ---------------------------------------------------------------------------
# Artifact builders: (callable, arg specs) per artifact kind.
# ---------------------------------------------------------------------------

def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32 if dtype == F32 else jnp.int32)


def build_artifacts(cfg: ModelConfig):
    """Yield (name, kind, fn, args) where args = [(name, dtype, shape, is_weight)]."""
    d, dh, m, qo = cfg.d_model, cfg.d_head, cfg.n_kv, cfg.n_qo
    s, pmax, k = cfg.budget_slots, cfg.n_pages_max, cfg.select_pages
    lw = model.layer_weight_shapes(cfg)
    gw = model.global_weight_shapes(cfg)
    lw_args = [(n, F32, list(lw[n]), True) for n in model.LAYER_WEIGHTS]

    arts = []
    for b in DECODE_BATCH_BUCKETS:
        arts.append((
            f"embed_b{b}", "embed",
            functools.partial(model.embed, cfg),
            [("tokens", I32, [b], False), ("embed", F32, list(gw["embed"]), True)],
        ))
        arts.append((
            f"layer_decode_b{b}", "layer_decode",
            functools.partial(model.layer_decode, cfg),
            [
                ("h", F32, [b, d], False),
                ("pos", I32, [b], False),
                ("k_cache", F32, [b, m, s, dh], False),
                ("v_cache", F32, [b, m, s, dh], False),
                ("valid", F32, [b, m, s], False),
                *lw_args,
            ],
        ))
        qkv_w = [(n, F32, list(lw[n]), True) for n in ("ln1", "wq", "wk", "wv")]
        attn_w = [(n, F32, list(lw[n]), True) for n in ("wo", "ln2", "wg", "wu", "wd")]
        arts.append((
            f"layer_qkv_b{b}", "layer_qkv",
            functools.partial(model.layer_qkv, cfg),
            [("h", F32, [b, d], False), ("pos", I32, [b], False), *qkv_w],
        ))
        arts.append((
            f"layer_attn_b{b}", "layer_attn",
            functools.partial(model.layer_attn, cfg),
            [
                ("h", F32, [b, d], False),
                ("q", F32, [b, qo, dh], False),
                ("k_new", F32, [b, m, dh], False),
                ("v_new", F32, [b, m, dh], False),
                ("k_cache", F32, [b, m, s, dh], False),
                ("v_cache", F32, [b, m, s, dh], False),
                ("valid", F32, [b, m, s], False),
                *attn_w,
            ],
        ))
        arts.append((
            f"logits_b{b}", "logits",
            functools.partial(model.logits, cfg),
            [
                ("h", F32, [b, d], False),
                ("ln_f", F32, list(gw["ln_f"]), True),
                ("embed", F32, list(gw["embed"]), True),
            ],
        ))
        for variant in SELECT_VARIANTS if b == 1 else ("means",):
            arts.append((
                f"select_{variant}_b{b}", "select",
                functools.partial(model.select, cfg, variant=variant),
                [
                    ("q", F32, [b, qo, dh], False),
                    ("smin", F32, [b, m, pmax, dh], False),
                    ("smax", F32, [b, m, pmax, dh], False),
                    ("page_mask", F32, [b, pmax], False),
                ],
            ))
    for t in PREFILL_BUCKETS:
        if t > cfg.max_context:
            continue
        arts.append((
            f"embed_t{t}", "embed",
            functools.partial(model.embed, cfg),
            [("tokens", I32, [t], False), ("embed", F32, list(gw["embed"]), True)],
        ))
        arts.append((
            f"layer_prefill_t{t}", "layer_prefill",
            functools.partial(model.layer_prefill, cfg),
            [
                ("h", F32, [t, d], False),
                ("pos", I32, [t], False),
                ("valid", F32, [t], False),
                *lw_args,
            ],
        ))
        arts.append((
            f"summarize_t{t}", "summarize",
            functools.partial(model.summarize, cfg),
            [("k", F32, [m, t, dh], False)],
        ))
        arts.append((
            f"logits_t{t}", "logits",
            functools.partial(model.logits, cfg),
            [
                ("h", F32, [t, d], False),
                ("ln_f", F32, list(gw["ln_f"]), True),
                ("embed", F32, list(gw["embed"]), True),
            ],
        ))
    return arts


def lower_artifact(fn, args):
    specs = [_spec(shape, dtype) for (_, dtype, shape, _) in args]
    return to_hlo_text(jax.jit(fn).lower(*specs))


# ---------------------------------------------------------------------------
# Golden trace for rust integration tests
# ---------------------------------------------------------------------------

def make_golden(cfg: ModelConfig, weights: dict, n_steps: int = 8):
    """Greedy full-attention decode the rust engine must reproduce."""
    jw = {k: jnp.asarray(v) for k, v in weights.items()}
    prompt = list(b"FreeKV speculative retrieval golden trace, page size 32. " * 2)
    toks = list(prompt)
    logits_trace = []
    for _ in range(n_steps):
        lg = model.reference_forward(cfg, jw, toks)[-1]
        logits_trace.append(np.asarray(lg, np.float32))
        toks.append(int(np.argmax(logits_trace[-1])))
    return {
        "prompt": prompt,
        "generated": toks[len(prompt):],
        "final_logits": [float(x) for x in logits_trace[-1]],
        "first_logits_head": [float(x) for x in logits_trace[0][:16]],
    }


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny",
                    help="comma list of model configs, or 'all'")
    ap.add_argument("--golden-steps", type=int, default=8)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(CONFIGS) if args.configs == "all" else args.configs.split(",")
    manifest = {
        "configs": {},
        "artifacts": [],
        "weights": {},
        "buckets": {
            "decode_batch": list(DECODE_BATCH_BUCKETS),
            "prefill": list(PREFILL_BUCKETS),
        },
        "select_variants": list(SELECT_VARIANTS),
        "layer_weights": list(model.LAYER_WEIGHTS),
        "global_weights": list(model.GLOBAL_WEIGHTS),
    }

    for cname in names:
        cfg = CONFIGS[cname]
        manifest["configs"][cname] = cfg.to_dict()
        print(f"[aot] {cname}: weights ...", flush=True)
        w = make_weights(cfg)
        wfile = f"weights_{cname}.bin"
        table = write_weights(w, os.path.join(args.out_dir, wfile))
        manifest["weights"][cname] = {"file": wfile, "tensors": table}

        for name, kind, fn, arg_specs in build_artifacts(cfg):
            fname = f"{cname}_{name}.hlo.txt"
            print(f"[aot] {cname}: lowering {name} -> {fname}", flush=True)
            text = lower_artifact(fn, arg_specs)
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": f"{cname}_{name}",
                "config": cname,
                "kind": kind,
                "file": fname,
                "args": [
                    {"name": n, "dtype": dt, "shape": sh, "weight": isw}
                    for (n, dt, sh, isw) in arg_specs
                ],
            })

        print(f"[aot] {cname}: golden trace ...", flush=True)
        golden = make_golden(cfg, w, args.golden_steps)
        with open(os.path.join(args.out_dir, f"golden_{cname}.json"), "w") as f:
            json.dump(golden, f)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
