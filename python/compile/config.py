"""Model and retrieval configurations shared by the compile path.

Every artifact the AOT driver emits is parameterized by one of these
configs; the same values are serialized into ``artifacts/manifest.json``
so the rust coordinator (L3) agrees with the compiled HLO on shapes.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Geometry of a GQA transformer (Llama-style: RMSNorm + RoPE + SwiGLU)."""

    name: str
    n_layers: int
    d_model: int
    n_qo: int           # query/output heads
    n_kv: int           # KV heads (GQA); group size G = n_qo // n_kv
    d_head: int
    d_ffn: int          # SwiGLU inner dim
    vocab: int
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    # --- retrieval geometry (FreeKV) ---
    page_size: int = 32          # p: tokens per KV page
    max_context: int = 4096      # max tokens tracked -> n_pages_max
    sink_pages: int = 2          # S = sink_pages * page_size sink tokens
    window_pages: int = 2        # W = window_pages * page_size local window
    select_pages: int = 12       # K pages chosen by retrieval per kv head

    def __post_init__(self):
        assert self.n_qo % self.n_kv == 0, "GQA group must divide evenly"
        assert self.max_context % self.page_size == 0
        assert self.d_head % 2 == 0, "RoPE needs an even head dim"

    @property
    def group_size(self) -> int:
        return self.n_qo // self.n_kv

    @property
    def n_pages_max(self) -> int:
        return self.max_context // self.page_size

    @property
    def budget_pages(self) -> int:
        """Total pages resident on 'GPU' per kv head: sink + window + selected."""
        return self.sink_pages + self.window_pages + self.select_pages

    @property
    def budget_slots(self) -> int:
        """S: token slots the decode attention kernel sees (excl. current token)."""
        return self.budget_pages * self.page_size

    def to_dict(self):
        d = asdict(self)
        d.update(
            group_size=self.group_size,
            n_pages_max=self.n_pages_max,
            budget_pages=self.budget_pages,
            budget_slots=self.budget_slots,
        )
        return d


# "tiny": the CI / test model. Small enough that every pytest sweep and the
# rust integration tests run in seconds on one CPU core.
TINY = ModelConfig(
    name="tiny",
    n_layers=4,
    d_model=256,
    n_qo=8,
    n_kv=2,
    d_head=32,
    d_ffn=704,
    vocab=260,  # byte-level tokenizer: 256 bytes + BOS/EOS/PAD/SEP
    max_context=4096,
)

# "small": the end-to-end serving example model (~78M params, Llama-style).
SMALL = ModelConfig(
    name="small",
    n_layers=12,
    d_model=768,
    n_qo=12,
    n_kv=4,
    d_head=64,
    d_ffn=2048,
    vocab=260,
    max_context=4096,
    select_pages=12,
)

CONFIGS = {c.name: c for c in (TINY, SMALL)}

# Decode batch buckets compiled per config; the rust batcher pads to the
# smallest bucket that fits.
DECODE_BATCH_BUCKETS = (1, 4)
# Prefill length buckets (single request at a time, padded).
PREFILL_BUCKETS = (512, 1024, 2048)

# Group-consistent selection variants (paper Appendix B.2). MeanS is the
# one FreeKV adopts; the others exist for the Table 5 ablation.
SELECT_VARIANTS = ("means", "maxs", "meanqk", "maxqk", "meanq", "maxq")
