"""Layer-2 JAX model: a Llama-style GQA transformer built from L1 kernels.

Each public function here becomes one AOT artifact (see aot.py). Weights
are *runtime arguments* (not baked constants) so a single ``layer_decode``
artifact serves every layer — the rust coordinator passes the layer's
weight buffers on each call.

Conventions shared with the rust side (encoded in artifacts/manifest.json):
- keys are stored **post-RoPE**; positions are only needed for the current
  token's q/k projection.
- the decode KV operand is the *gathered* per-kv-head slot buffer
  ``[n_kv, S, d]`` (sink pages + local window + selected pages), assembled
  by the rust KV-cache manager from the GPU NHD page cache.
- query heads are laid out so that kv head m owns query heads
  ``m*G .. (m+1)*G-1``.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import pallas_kernels as pk
from .kernels import ref

# Layer weight argument order for layer artifacts. The manifest records
# this so the rust side binds buffers positionally.
LAYER_WEIGHTS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")
GLOBAL_WEIGHTS = ("embed", "ln_f")


def layer_weight_shapes(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ffn
    qd, kd = cfg.n_qo * cfg.d_head, cfg.n_kv * cfg.d_head
    return {
        "ln1": (d,),
        "wq": (d, qd),
        "wk": (d, kd),
        "wv": (d, kd),
        "wo": (qd, d),
        "ln2": (d,),
        "wg": (d, f),
        "wu": (d, f),
        "wd": (f, d),
    }


def global_weight_shapes(cfg: ModelConfig):
    return {"embed": (cfg.vocab, cfg.d_model), "ln_f": (cfg.d_model,)}


def embed(cfg: ModelConfig, tokens, embed_w):
    """tokens [N] i32 -> hidden [N, d]."""
    return embed_w[tokens]


def logits(cfg: ModelConfig, h, ln_f, embed_w):
    """h [B, d] -> next-token logits [B, vocab] (tied embedding head)."""
    return ref.rms_norm(h, ln_f, cfg.rms_eps) @ embed_w.T


def _project_qkv(cfg: ModelConfig, x, wq, wk, wv, pos):
    """x [N, d] -> q [N, n_qo, dh], k/v [N, n_kv, dh], RoPE applied."""
    n = x.shape[0]
    q = (x @ wq).reshape(n, cfg.n_qo, cfg.d_head)
    k = (x @ wk).reshape(n, cfg.n_kv, cfg.d_head)
    v = (x @ wv).reshape(n, cfg.n_kv, cfg.d_head)
    q = ref.rope(q, pos, cfg.rope_theta)
    k = ref.rope(k, pos, cfg.rope_theta)
    return q, k, v


def layer_qkv(cfg: ModelConfig, h, pos, ln1, wq, wk, wv):
    """First half of a decode layer: norm + QKV projection + RoPE.

    Split from the attention half so the rust coordinator can apply
    FreeKV's *fine-grained correction* (paper §3.3) between computing the
    current step's query and running attention: cos(q_i, q_{i-1}) is
    checked in rust, flagged kv heads get a blocking select+recall, and
    only then is ``layer_attn`` launched.

    h: [B, d]; pos: [B] i32. Returns (q [B, n_qo, dh], k_new [B, n_kv,
    dh], v_new [B, n_kv, dh]).
    """
    x = ref.rms_norm(h, ln1, cfg.rms_eps)
    return _project_qkv(cfg, x, wq, wk, wv, pos)


def layer_attn(cfg: ModelConfig, h, q, k_new, v_new, k_cache, v_cache, valid,
               wo, ln2, wg, wu, wd):
    """Second half of a decode layer: gathered-page attention + FFN.

    Consumes the q/k/v produced by ``layer_qkv`` (possibly after a
    correction re-gather of k_cache/v_cache). Returns h_out [B, d].
    """
    b = h.shape[0]
    k_all = jnp.concatenate([k_cache, k_new[:, :, None, :]], axis=2)
    v_all = jnp.concatenate([v_cache, v_new[:, :, None, :]], axis=2)
    valid_all = jnp.concatenate(
        [valid, jnp.ones((b, cfg.n_kv, 1), jnp.float32)], axis=2
    )
    qg = q.reshape(b, cfg.n_kv, cfg.group_size, cfg.d_head)
    o = jax.vmap(pk.decode_attention)(qg, k_all, v_all, valid_all)
    o = o.reshape(b, cfg.n_qo * cfg.d_head)
    h = h + o @ wo
    h = h + ref.swiglu(ref.rms_norm(h, ln2, cfg.rms_eps), wg, wu, wd)
    return h


def layer_decode(cfg: ModelConfig, h, pos, k_cache, v_cache, valid, *w):
    """One decode step through one transformer layer (batched).

    h: [B, d]; pos: [B] i32 absolute position of the current token;
    k_cache/v_cache: [B, n_kv, S, d] gathered slots; valid: [B, n_kv, S].
    w: LAYER_WEIGHTS in order.
    Returns (h_out [B, d], q [B, n_qo, dh], k_new [B, n_kv, dh],
             v_new [B, n_kv, dh]).
    """
    ln1, wq, wk, wv, wo, ln2, wg, wu, wd = w
    q, k_new, v_new = layer_qkv(cfg, h, pos, ln1, wq, wk, wv)
    h = layer_attn(cfg, h, q, k_new, v_new, k_cache, v_cache, valid,
                   wo, ln2, wg, wu, wd)
    return h, q, k_new, v_new


def layer_prefill(cfg: ModelConfig, h, pos, valid, *w, q_chunk: int = 256):
    """Full causal prefill through one layer (single request).

    h: [T, d]; pos: [T] i32 (absolute positions; padding slots get
    pos = -1); valid: [T] float (0 for padding).
    Returns (h_out [T, d], k [n_kv, T, dh], v [n_kv, T, dh],
             q_last [n_qo, dh]) with q_last the query of the last *valid*
    token (seed for the first speculative selection).
    """
    ln1, wq, wk, wv, wo, ln2, wg, wu, wd = w
    t = h.shape[0]
    x = ref.rms_norm(h, ln1, cfg.rms_eps)
    q, k, v = _project_qkv(cfg, x, wq, wk, wv, jnp.maximum(pos, 0))

    # Chunk the query axis to bound the [chunk, T] score buffer (the
    # prefill analog of flash tiling; real XLA fuses the masked softmax).
    qg = q.reshape(t, cfg.n_kv, cfg.group_size, cfg.d_head)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
    kv_valid = valid > 0

    def chunk_attn(args):
        q_blk, pos_blk = args  # [C, n_kv, G, dh], [C]
        s = jnp.einsum("cmgd,tmd->cmgt", q_blk, k.reshape(t, cfg.n_kv, cfg.d_head)) * scale
        mask = (pos[None, :] <= pos_blk[:, None]) & kv_valid[None, :]
        s = jnp.where(mask[:, None, None, :], s, jnp.float32(-1e30))
        p = jnp.exp(s - s.max(axis=-1, keepdims=True))
        p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
        return jnp.einsum("cmgt,tmd->cmgd", p, v.reshape(t, cfg.n_kv, cfg.d_head))

    n_chunks = t // q_chunk if t % q_chunk == 0 else -1
    if n_chunks > 1:
        o = jax.lax.map(
            chunk_attn,
            (
                qg.reshape(n_chunks, q_chunk, cfg.n_kv, cfg.group_size, cfg.d_head),
                pos.reshape(n_chunks, q_chunk),
            ),
        ).reshape(t, cfg.n_kv, cfg.group_size, cfg.d_head)
    else:
        o = chunk_attn((qg, pos))
    o = o.reshape(t, cfg.n_qo * cfg.d_head)
    h = h + o @ wo
    h = h + ref.swiglu(ref.rms_norm(h, ln2, cfg.rms_eps), wg, wu, wd)

    last = jnp.maximum(valid.astype(jnp.int32).sum() - 1, 0)
    q_last = q[last]
    # K/V returned in [n_kv, T, d] (HND-ish) so the rust side can slice
    # pages contiguously when populating the CPU pool.
    return h, k.transpose(1, 0, 2), v.transpose(1, 0, 2), q_last


def select(cfg: ModelConfig, q, smin, smax, page_mask, variant: str = "means"):
    """Page selection: scores (Pallas) + top-k (XLA), batched.

    q: [B, n_qo, dh]; smin/smax: [B, n_kv, P, dh]; page_mask: [B, P].
    Returns (scores [B, n_kv, P], idx [B, n_kv, K] i32).
    """
    b = q.shape[0]
    qg = q.reshape(b, cfg.n_kv, cfg.group_size, cfg.d_head)
    scores = jax.vmap(
        lambda qq, lo, hi, msk: pk.select_scores(qq, lo, hi, msk, variant)
    )(qg, smin, smax, page_mask)
    # argsort-based top-k: lax.top_k lowers to the `topk(..., largest=true)`
    # HLO op that xla_extension 0.5.1's text parser rejects; sort-based
    # lowering round-trips cleanly.
    idx = jnp.argsort(-scores, axis=-1)[..., : cfg.select_pages]
    return scores, idx.astype(jnp.int32)


def summarize(cfg: ModelConfig, k):
    """Prefill page summaries: k [n_kv, T, d] -> (smin, smax) [n_kv, P, d]."""
    return pk.page_summaries(k, cfg.page_size)


# ---------------------------------------------------------------------------
# Reference full-model forward (oracle for integration tests / golden file).
# ---------------------------------------------------------------------------

def reference_forward(cfg: ModelConfig, weights: dict, tokens):
    """Full-attention forward over a token sequence; returns logits [T, vocab].

    Pure jnp, no pallas, no paging — the numerical oracle that the rust
    decode loop (with a budget covering the whole context) must match.
    """
    t = len(tokens)
    pos = jnp.arange(t, dtype=jnp.int32)
    h = weights["embed"][jnp.asarray(tokens, jnp.int32)]
    for i in range(cfg.n_layers):
        w = {name: weights[f"layers.{i}.{name}"] for name in LAYER_WEIGHTS}
        x = ref.rms_norm(h, w["ln1"], cfg.rms_eps)
        q, k, v = _project_qkv(cfg, x, w["wq"], w["wk"], w["wv"], pos)
        qg = q.reshape(t, cfg.n_kv, cfg.group_size, cfg.d_head)
        o = ref.causal_attention(qg, k, v, pos, pos)
        h = h + o.reshape(t, cfg.n_qo * cfg.d_head) @ w["wo"]
        h = h + ref.swiglu(
            ref.rms_norm(h, w["ln2"], cfg.rms_eps), w["wg"], w["wu"], w["wd"]
        )
    return ref.rms_norm(h, weights["ln_f"], cfg.rms_eps) @ weights["embed"].T
