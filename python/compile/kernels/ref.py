"""Pure-jnp oracles for every Pallas kernel (L1 correctness references).

These are the ground truth the pytest suite checks the Pallas kernels
against (``assert_allclose``), and they double as the reference
implementation used by the L2 model tests.
"""

import jax.numpy as jnp


def rope(x, positions, theta: float = 10000.0):
    """Rotary position embedding, Llama "half" convention.

    x: [..., T, n_heads, d_head]
    positions: int32 [..., T] absolute positions matching x's T axis.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over the heads axis
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rms_norm(x, g, eps: float = 1e-5):
    """RMSNorm over the last axis."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jnp.reciprocal(jnp.sqrt(ms + eps))) * g


def page_summaries(k, page_size: int):
    """Min/max page summaries (Quest-style) of a key cache.

    k: [n_kv, T, d] with T divisible by page_size.
    Returns (smin, smax): [n_kv, T // page_size, d].
    """
    n_kv, t, d = k.shape
    pages = k.reshape(n_kv, t // page_size, page_size, d)
    return pages.min(axis=2), pages.max(axis=2)


def _qbound(qv, smin, smax):
    """Quest upper bound sum_d max(q_d*min_d, q_d*max_d).

    qv: [n_kv, H, d]; smin/smax: [n_kv, P, d] -> [n_kv, H, P]."""
    lo = qv[:, :, None, :] * smin[:, None, :, :]
    hi = qv[:, :, None, :] * smax[:, None, :, :]
    return jnp.maximum(lo, hi).sum(axis=-1)


def select_scores(q, smin, smax, page_mask, variant: str = "means"):
    """Group-consistent page scores (paper §3.2 + Appendix B.2).

    q: [n_kv, G, d] query vectors grouped by kv head.
    smin, smax: [n_kv, P, d] page summaries.
    page_mask: [P] float (1 = selectable, 0 = masked out).
    Returns scores [n_kv, P]; masked pages score -1e30 (pre-softmax
    variants) or 0 (post-softmax variants) so they never win top-k.
    """
    neg = jnp.float32(-1e30)

    if variant in ("meanq", "maxq"):
        pooled_q = q.mean(axis=1) if variant == "meanq" else q.max(axis=1)
        s = _qbound(pooled_q[:, None, :], smin, smax)[:, 0, :]  # [n_kv, P]
        return jnp.where(page_mask[None, :] > 0, s, neg)

    s = _qbound(q, smin, smax)  # [n_kv, G, P]
    if variant in ("meanqk", "maxqk"):
        pooled = s.mean(axis=1) if variant == "meanqk" else s.max(axis=1)
        return jnp.where(page_mask[None, :] > 0, pooled, neg)

    if variant in ("means", "maxs"):
        masked = jnp.where(page_mask[None, None, :] > 0, s, neg)
        sm = jnp.exp(masked - masked.max(axis=-1, keepdims=True))
        sm = sm / jnp.maximum(sm.sum(axis=-1, keepdims=True), 1e-30)
        sm = jnp.where(page_mask[None, None, :] > 0, sm, 0.0)
        return sm.mean(axis=1) if variant == "means" else sm.max(axis=1)

    raise ValueError(f"unknown variant {variant!r}")


def decode_attention(q, k, v, valid):
    """GQA decode attention over gathered KV slots.

    q: [n_kv, G, d] current-token queries grouped by kv head (post-RoPE).
    k, v: [n_kv, S, d] gathered cache slots (post-RoPE keys).
    valid: [n_kv, S] float mask (1 = real token, 0 = empty slot).
    Returns o: [n_kv, G, d].
    """
    d = q.shape[-1]
    scores = jnp.einsum("mgd,msd->mgs", q, k) / jnp.sqrt(jnp.float32(d))
    scores = jnp.where(valid[:, None, :] > 0, scores, jnp.float32(-1e30))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p * (valid[:, None, :] > 0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("mgs,msd->mgd", p, v)


def swiglu(x, wg, wu, wd):
    """SwiGLU FFN: (silu(x @ wg) * (x @ wu)) @ wd."""
    g = x @ wg
    return (g * jnp.reciprocal(1.0 + jnp.exp(-g)) * (x @ wu)) @ wd


def causal_attention(q, k, v, pos_q, pos_k):
    """Full prefill attention with causal mask (oracle for prefill path).

    q: [T, n_kv, G, d]; k, v: [S, n_kv, d]; pos_q: [T], pos_k: [S].
    Returns o: [T, n_kv, G, d].
    """
    d = q.shape[-1]
    scores = jnp.einsum("tmgd,smd->tmgs", q, k) / jnp.sqrt(jnp.float32(d))
    mask = pos_k[None, :] <= pos_q[:, None]  # [T, S]
    scores = jnp.where(mask[:, None, None, :], scores, jnp.float32(-1e30))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("tmgs,smd->tmgd", p, v)
