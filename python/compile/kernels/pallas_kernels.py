"""Layer-1 Pallas kernels: FreeKV's compute hot-spots.

All kernels run with ``interpret=True`` so they lower to plain HLO the CPU
PJRT plugin can execute (real-TPU lowering emits Mosaic custom-calls the
CPU client cannot run). The *structure*, however, is written for the TPU:

Hardware adaptation (paper targets A100 CUDA; see DESIGN.md):
- The paper's recall/selection GPU work is threadblock-tiled over pages.
  Here each kernel tiles the slot/page axis into VMEM-sized blocks via an
  in-kernel ``fori_loop`` (decode attention: online-softmax flash blocks)
  or a 2-D grid (summaries), expressing the HBM->VMEM schedule the paper
  expressed with threadblocks.
- The Quest bound  sum_d max(q_d*min_d, q_d*max_d)  is rewritten as two
  MXU matmuls:  0.5 * (q @ (min+max)^T + |q| @ (max-min)^T)  — exact
  because max-min >= 0 — instead of the elementwise/broadcast form a CUDA
  warp reduction would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True

# Slot-axis tile for the decode attention flash loop. 128 matches the MXU
# systolic tile; S (budget slots) is always a multiple of the page size so
# padding only occurs on the final +1 (current token) slot.
ATTN_BLOCK_S = 128


# ---------------------------------------------------------------------------
# Decode attention: GQA, one grid cell per kv head, flash-style over slots.
# ---------------------------------------------------------------------------

def _decode_attn_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, *, block_s: int):
    q = q_ref[0]  # [G, d]
    g, d = q.shape
    s_total = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    n_blocks = s_total // block_s

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_blk = k_ref[0, pl.dslice(i * block_s, block_s), :]      # [bs, d]
        v_blk = v_ref[0, pl.dslice(i * block_s, block_s), :]      # [bs, d]
        msk = valid_ref[0, pl.dslice(i * block_s, block_s)]       # [bs]
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(msk[None, :] > 0, s, jnp.float32(-1e30))
        m_new = jnp.maximum(m_prev, s.max(axis=-1))               # [G]
        p = jnp.exp(s - m_new[:, None]) * (msk[None, :] > 0)
        alpha = jnp.exp(m_prev - m_new)                           # [G]
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    init = (
        jnp.full((g,), -1e30, jnp.float32),
        jnp.zeros((g,), jnp.float32),
        jnp.zeros((g, d), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


def decode_attention(q, k, v, valid, *, block_s: int = ATTN_BLOCK_S):
    """GQA decode attention over gathered KV slots (single batch element).

    q: [n_kv, G, d]; k, v: [n_kv, S, d]; valid: [n_kv, S] (float 0/1).
    S is padded to a multiple of ``block_s`` internally (mask extended 0).
    Returns o: [n_kv, G, d].
    """
    n_kv, g, d = q.shape
    s = k.shape[1]
    pad = (-s) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    s_padded = s + pad
    kern = functools.partial(_decode_attn_kernel, block_s=block_s)
    return pl.pallas_call(
        kern,
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, s_padded, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, s_padded, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, s_padded), lambda m: (m, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda m: (m, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, g, d), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v, valid)


# ---------------------------------------------------------------------------
# Page selection scores: Quest bound + group-consistent pooling (MeanS etc).
# ---------------------------------------------------------------------------

def _select_scores_kernel(q_ref, ssum_ref, sdiff_ref, mask_ref, o_ref, *, variant):
    # ssum = smin + smax, sdiff = smax - smin (>= 0), both [P, d].
    q = q_ref[0]            # [G, d] (or [1, d] for pre-pooled q variants)
    ssum = ssum_ref[0]      # [P, d]
    sdiff = sdiff_ref[0]    # [P, d]
    mask = mask_ref[...]    # [P]
    neg = jnp.float32(-1e30)
    # Quest bound as two MXU matmuls (see module docstring).
    s = 0.5 * (
        jnp.dot(q, ssum.T, preferred_element_type=jnp.float32)
        + jnp.dot(jnp.abs(q), sdiff.T, preferred_element_type=jnp.float32)
    )  # [G, P]
    if variant in ("meanq", "maxq"):
        # q was pooled outside the kernel; G axis is 1.
        o_ref[0] = jnp.where(mask > 0, s[0], neg)
    elif variant in ("meanqk", "maxqk"):
        pooled = s.mean(axis=0) if variant == "meanqk" else s.max(axis=0)
        o_ref[0] = jnp.where(mask > 0, pooled, neg)
    else:  # means / maxs: softmax per q-head over pages, then pool.
        sm = jnp.where(mask[None, :] > 0, s, neg)
        e = jnp.exp(sm - sm.max(axis=-1, keepdims=True))
        e = e / jnp.maximum(e.sum(axis=-1, keepdims=True), 1e-30)
        e = jnp.where(mask[None, :] > 0, e, 0.0)
        o_ref[0] = e.mean(axis=0) if variant == "means" else e.max(axis=0)


def select_scores(q, smin, smax, page_mask, variant: str = "means"):
    """Group-consistent page scores; one grid cell per kv head.

    q: [n_kv, G, d]; smin/smax: [n_kv, P, d]; page_mask: [P].
    Returns scores [n_kv, P] (masked pages -1e30 or 0, matching ref).
    """
    n_kv, g, d = q.shape
    p = smin.shape[1]
    if variant in ("meanq", "maxq"):
        q = (q.mean(axis=1) if variant == "meanq" else q.max(axis=1))[:, None, :]
        g = 1
    ssum = smin + smax
    sdiff = smax - smin
    kern = functools.partial(_select_scores_kernel, variant=variant)
    return pl.pallas_call(
        kern,
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, p, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((1, p, d), lambda m: (m, 0, 0)),
            pl.BlockSpec((p,), lambda m: (0,)),
        ],
        out_specs=pl.BlockSpec((1, p), lambda m: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, p), jnp.float32),
        interpret=INTERPRET,
    )(q, ssum, sdiff, page_mask)


# ---------------------------------------------------------------------------
# Page summaries: min/max over each page of the key cache.
# ---------------------------------------------------------------------------

def _summarize_kernel(k_ref, lo_ref, hi_ref):
    page = k_ref[0]  # [p, d]
    lo_ref[0, 0] = page.min(axis=0)
    hi_ref[0, 0] = page.max(axis=0)


def page_summaries(k, page_size: int):
    """Min/max summaries per page; grid (n_kv, n_pages).

    k: [n_kv, T, d], T divisible by page_size.
    Returns (smin, smax): [n_kv, T // page_size, d].
    """
    n_kv, t, d = k.shape
    n_pages = t // page_size
    return pl.pallas_call(
        _summarize_kernel,
        grid=(n_kv, n_pages),
        in_specs=[pl.BlockSpec((1, page_size, d), lambda m, pg: (m, pg, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda m, pg: (m, pg, 0)),
            pl.BlockSpec((1, 1, d), lambda m, pg: (m, pg, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_kv, n_pages, d), jnp.float32),
            jax.ShapeDtypeStruct((n_kv, n_pages, d), jnp.float32),
        ],
        interpret=INTERPRET,
    )(k)
