"""L2 model tests: the paged decode path must equal full attention when the
budget covers the whole context, and artifact functions must be shape-sound.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.aot import make_weights
from compile.config import ModelConfig

# A micro config so each test runs in < seconds under interpret mode.
MICRO = ModelConfig(
    name="micro",
    n_layers=2,
    d_model=64,
    n_qo=4,
    n_kv=2,
    d_head=16,
    d_ffn=128,
    vocab=64,
    page_size=4,
    max_context=64,
    sink_pages=1,
    window_pages=1,
    select_pages=2,
)


@pytest.fixture(scope="module")
def weights():
    return {k: jnp.asarray(v) for k, v in make_weights(MICRO, seed=7).items()}


def layer_w(weights, i):
    return [weights[f"layers.{i}.{n}"] for n in model.LAYER_WEIGHTS]


def decode_full_budget(cfg, weights, tokens):
    """Run the decode path token-by-token with a gathered buffer that holds
    the *entire* history (S = budget_slots >= len(tokens)); returns logits
    of the final step. Mirrors exactly what the rust engine does."""
    s = cfg.budget_slots
    t = len(tokens)
    assert t <= s
    k_cache = np.zeros((cfg.n_layers, cfg.n_kv, s, cfg.d_head), np.float32)
    v_cache = np.zeros_like(k_cache)
    valid = np.zeros((cfg.n_layers, cfg.n_kv, s), np.float32)
    logits = None
    for i, tok in enumerate(tokens):
        h = model.embed(cfg, jnp.asarray([tok], jnp.int32), weights["embed"])
        pos = jnp.asarray([i], jnp.int32)
        for l in range(cfg.n_layers):
            h, q, k_new, v_new = model.layer_decode(
                cfg, h, pos,
                jnp.asarray(k_cache[l][None]), jnp.asarray(v_cache[l][None]),
                jnp.asarray(valid[l][None]), *layer_w(weights, l),
            )
            k_cache[l, :, i, :] = np.asarray(k_new[0])
            v_cache[l, :, i, :] = np.asarray(v_new[0])
            valid[l, :, i] = 1.0
        logits = model.logits(cfg, h, weights["ln_f"], weights["embed"])
    return np.asarray(logits[0])


def test_decode_matches_reference_full_attention(weights):
    tokens = [3, 17, 42, 5, 9, 13, 27, 31, 8, 2]
    want = np.asarray(
        model.reference_forward(MICRO, weights, tokens)[-1]
    )
    got = decode_full_budget(MICRO, weights, tokens)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_prefill_matches_reference(weights):
    tokens = [1, 2, 3, 4, 5, 6, 7, 8]
    t = len(tokens)
    pos = jnp.arange(t, dtype=jnp.int32)
    valid = jnp.ones((t,), jnp.float32)
    h = model.embed(MICRO, jnp.asarray(tokens, jnp.int32), weights["embed"])
    for l in range(MICRO.n_layers):
        h, k, v, q_last = model.layer_prefill(
            MICRO, h, pos, valid, *layer_w(weights, l)
        )
    lg = model.logits(MICRO, h, weights["ln_f"], weights["embed"])
    want = np.asarray(model.reference_forward(MICRO, weights, tokens))
    assert_allclose(np.asarray(lg), want, rtol=2e-4, atol=2e-4)


def test_prefill_padding_does_not_change_valid_outputs(weights):
    tokens = [5, 6, 7, 8, 9]
    t_pad = 8
    pos = jnp.asarray(list(range(len(tokens))) + [-1] * (t_pad - len(tokens)), jnp.int32)
    valid = jnp.asarray([1.0] * len(tokens) + [0.0] * (t_pad - len(tokens)), jnp.float32)
    toks_pad = jnp.asarray(tokens + [0] * (t_pad - len(tokens)), jnp.int32)
    h = model.embed(MICRO, toks_pad, weights["embed"])
    for l in range(MICRO.n_layers):
        h, k, v, q_last = model.layer_prefill(MICRO, h, pos, valid, *layer_w(weights, l))
    lg = np.asarray(model.logits(MICRO, h, weights["ln_f"], weights["embed"]))
    want = np.asarray(model.reference_forward(MICRO, weights, tokens))
    assert_allclose(lg[: len(tokens)], want, rtol=2e-4, atol=3e-4)


def test_prefill_kv_matches_decode_kv(weights):
    """K/V produced by prefill must equal K/V produced stepping one by one."""
    tokens = [9, 8, 7, 6]
    t = len(tokens)
    pos = jnp.arange(t, dtype=jnp.int32)
    valid = jnp.ones((t,), jnp.float32)
    h0 = model.embed(MICRO, jnp.asarray(tokens, jnp.int32), weights["embed"])
    _, k_pre, v_pre, _ = model.layer_prefill(MICRO, h0, pos, valid, *layer_w(weights, 0))

    s = MICRO.budget_slots
    kc = jnp.zeros((1, MICRO.n_kv, s, MICRO.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    vmask = jnp.zeros((1, MICRO.n_kv, s), jnp.float32)
    for i, tok in enumerate(tokens):
        h = model.embed(MICRO, jnp.asarray([tok], jnp.int32), weights["embed"])
        _, _, k_new, v_new = model.layer_decode(
            MICRO, h, jnp.asarray([i], jnp.int32), kc, vc, vmask, *layer_w(weights, 0)
        )
        assert_allclose(
            np.asarray(k_new[0]), np.asarray(k_pre)[:, i, :], rtol=1e-4, atol=1e-5
        )
        kc = kc.at[0, :, i, :].set(k_new[0])
        vc = vc.at[0, :, i, :].set(v_new[0])
        vmask = vmask.at[0, :, i].set(1.0)


def test_select_artifact_shapes(weights):
    b, p = 2, MICRO.n_pages_max
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, MICRO.n_qo, MICRO.d_head)), jnp.float32)
    smin = jnp.asarray(rng.normal(size=(b, MICRO.n_kv, p, MICRO.d_head)), jnp.float32)
    smax = smin + 1.0
    mask = jnp.ones((b, p), jnp.float32)
    scores, idx = model.select(MICRO, q, smin, smax, mask)
    assert scores.shape == (b, MICRO.n_kv, p)
    assert idx.shape == (b, MICRO.n_kv, MICRO.select_pages)
    assert int(idx.max()) < p and int(idx.min()) >= 0


def test_select_prefers_high_attention_pages(weights):
    """Pages whose keys align with q must be selected over orthogonal ones."""
    rng = np.random.default_rng(1)
    p, psz, d = MICRO.n_pages_max, MICRO.page_size, MICRO.d_head
    q = jnp.asarray(rng.normal(size=(1, MICRO.n_qo, d)), jnp.float32)
    keys = rng.normal(size=(MICRO.n_kv, p * psz, d)).astype(np.float32) * 0.01
    hot = [3, 7, 11]
    for pg in hot:
        # keys in hot pages point along q for every head in the group
        keys[:, pg * psz:(pg + 1) * psz, :] += np.asarray(q).reshape(
            MICRO.n_kv, MICRO.group_size, d
        ).mean(1)[:, None, :]
    from compile.kernels import ref as _ref
    smin, smax = _ref.page_summaries(jnp.asarray(keys), psz)
    mask = jnp.ones((1, p), jnp.float32)
    _, idx = model.select(MICRO, q, smin[None], smax[None], mask)
    got = set(np.asarray(idx).ravel().tolist())
    assert set(hot) <= got


def test_split_layer_equals_combined(weights):
    """layer_qkv + layer_attn (the correction-capable path the rust engine
    uses) must equal the fused layer_decode artifact exactly."""
    rng = np.random.default_rng(2)
    s = MICRO.budget_slots
    h = jnp.asarray(rng.normal(size=(1, MICRO.d_model)), jnp.float32)
    pos = jnp.asarray([5], jnp.int32)
    kc = jnp.asarray(rng.normal(size=(1, MICRO.n_kv, s, MICRO.d_head)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(1, MICRO.n_kv, s, MICRO.d_head)), jnp.float32)
    valid = jnp.asarray(rng.integers(0, 2, size=(1, MICRO.n_kv, s)), jnp.float32)
    w = layer_w(weights, 0)
    h1, q1, k1, v1 = model.layer_decode(MICRO, h, pos, kc, vc, valid, *w)
    ln1, wq, wk, wv, wo, ln2, wg, wu, wd = w
    q2, k2, v2 = model.layer_qkv(MICRO, h, pos, ln1, wq, wk, wv)
    h2 = model.layer_attn(MICRO, h, q2, k2, v2, kc, vc, valid, wo, ln2, wg, wu, wd)
    assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)
    assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)


def test_logits_finite(weights):
    h = jnp.ones((1, MICRO.d_model), jnp.float32)
    lg = model.logits(MICRO, h, weights["ln_f"], weights["embed"])
    assert np.isfinite(np.asarray(lg)).all()
