"""L1 kernel tests: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/masks; assert_allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


def _f32(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.sampled_from([1, 2, 4]),        # n_kv
    st.sampled_from([1, 2, 4, 8]),     # G
    st.sampled_from([8, 32, 64]),      # d
    st.sampled_from([16, 96, 128, 257]),  # S (incl. non-multiple of block)
    st.integers(0, 2**31 - 1),         # seed
)


@given(shape_strategy)
def test_decode_attention_matches_ref(args):
    n_kv, g, d, s, seed = args
    rng = _rng(seed)
    q = _f32(rng, n_kv, g, d)
    k = _f32(rng, n_kv, s, d)
    v = _f32(rng, n_kv, s, d)
    valid = jnp.asarray(rng.integers(0, 2, size=(n_kv, s)), jnp.float32)
    got = pk.decode_attention(q, k, v, valid)
    want = ref.decode_attention(q, k, v, valid)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_attention_all_masked_row_is_zero():
    rng = _rng(0)
    q, k, v = (_f32(rng, 2, 4, 16) for _ in range(1)), None, None
    q = _f32(rng, 2, 4, 16)
    k = _f32(rng, 2, 64, 16)
    v = _f32(rng, 2, 64, 16)
    valid = jnp.zeros((2, 64), jnp.float32).at[1].set(1.0)
    got = np.asarray(pk.decode_attention(q, k, v, valid))
    assert_allclose(got[0], 0.0, atol=1e-6)  # fully masked head -> zeros
    assert np.abs(got[1]).max() > 0


def test_decode_attention_single_valid_slot_returns_its_value():
    rng = _rng(1)
    q = _f32(rng, 1, 2, 8)
    k = _f32(rng, 1, 32, 8)
    v = _f32(rng, 1, 32, 8)
    valid = jnp.zeros((1, 32), jnp.float32).at[0, 7].set(1.0)
    got = np.asarray(pk.decode_attention(q, k, v, valid))
    want = np.broadcast_to(np.asarray(v)[0, 7], (2, 8))
    assert_allclose(got[0], want, rtol=1e-5)


def test_decode_attention_invariant_to_masked_values():
    """Changing K/V under masked slots must not change the output."""
    rng = _rng(2)
    q = _f32(rng, 2, 2, 16)
    k = _f32(rng, 2, 96, 16)
    v = _f32(rng, 2, 96, 16)
    valid = jnp.asarray(rng.integers(0, 2, size=(2, 96)), jnp.float32)
    out1 = np.asarray(pk.decode_attention(q, k, v, valid))
    noise = _f32(rng, 2, 96, 16) * 100.0
    k2 = jnp.where(valid[..., None] > 0, k, k + noise)
    v2 = jnp.where(valid[..., None] > 0, v, v - noise)
    out2 = np.asarray(pk.decode_attention(q, k2, v2, valid))
    assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# select_scores
# ---------------------------------------------------------------------------

select_strategy = st.tuples(
    st.sampled_from([1, 2, 4]),      # n_kv
    st.sampled_from([1, 2, 4]),      # G
    st.sampled_from([8, 32]),        # d
    st.sampled_from([4, 16, 128]),   # P
    st.sampled_from(["means", "maxs", "meanqk", "maxqk", "meanq", "maxq"]),
    st.integers(0, 2**31 - 1),
)


@given(select_strategy)
def test_select_scores_matches_ref(args):
    n_kv, g, d, p, variant, seed = args
    rng = _rng(seed)
    q = _f32(rng, n_kv, g, d)
    k = _f32(rng, n_kv, p * 4, d)
    smin, smax = ref.page_summaries(k, 4)
    mask = jnp.asarray(rng.integers(0, 2, size=(p,)), jnp.float32)
    got = pk.select_scores(q, smin, smax, mask, variant)
    want = ref.select_scores(q, smin, smax, mask, variant)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_select_bound_dominates_true_scores():
    """Quest property: the page bound >= any true q.k in the page."""
    rng = _rng(3)
    n_kv, g, d, p, psz = 2, 4, 32, 8, 16
    q = _f32(rng, n_kv, g, d)
    k = _f32(rng, n_kv, p * psz, d)
    smin, smax = ref.page_summaries(k, psz)
    mask = jnp.ones((p,), jnp.float32)
    bound = np.asarray(pk.select_scores(q, smin, smax, mask, "meanqk"))
    # compare against mean-over-group of true max q.k per page
    true = np.einsum("mgd,msd->mgs", np.asarray(q), np.asarray(k))
    true = true.reshape(n_kv, g, p, psz).max(-1).mean(1)
    assert (bound + 1e-4 >= true).all()


def test_select_masked_pages_never_win():
    rng = _rng(4)
    q = _f32(rng, 2, 4, 16)
    k = _f32(rng, 2, 16 * 8, 16) * 10.0
    smin, smax = ref.page_summaries(k, 8)
    mask = jnp.ones((16,), jnp.float32).at[3].set(0.0).at[9].set(0.0)
    for variant in ("means", "maxs", "meanqk", "maxq"):
        s = np.asarray(pk.select_scores(q, smin, smax, mask, variant))
        order = np.argsort(-s, axis=-1)
        assert 3 not in order[:, :14] or s[:, 3].max() <= s.max() - 1
        # masked scores are sentinel-low (or zero for softmax variants)
        assert (s[:, 3] <= 0).all() and (s[:, 9] <= 0).all()


# ---------------------------------------------------------------------------
# page_summaries
# ---------------------------------------------------------------------------

@given(
    st.sampled_from([1, 2, 4]),
    st.sampled_from([1, 4, 16]),
    st.sampled_from([8, 32]),
    st.sampled_from([4, 32]),
    st.integers(0, 2**31 - 1),
)
def test_page_summaries_matches_ref(n_kv, p, d, psz, seed):
    rng = _rng(seed)
    k = _f32(rng, n_kv, p * psz, d)
    lo1, hi1 = pk.page_summaries(k, psz)
    lo2, hi2 = ref.page_summaries(k, psz)
    assert_allclose(np.asarray(lo1), np.asarray(lo2))
    assert_allclose(np.asarray(hi1), np.asarray(hi2))


def test_page_summaries_bracket_every_key():
    rng = _rng(5)
    k = _f32(rng, 2, 128, 16)
    lo, hi = pk.page_summaries(k, 32)
    pages = np.asarray(k).reshape(2, 4, 32, 16)
    assert (np.asarray(lo)[:, :, None, :] <= pages + 1e-7).all()
    assert (np.asarray(hi)[:, :, None, :] >= pages - 1e-7).all()


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    rng = _rng(6)
    x = _f32(rng, 16, 4, 32)
    pos = jnp.arange(16, dtype=jnp.int32)
    y = ref.rope(x, pos)
    assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    rng = _rng(7)
    q = _f32(rng, 1, 1, 64)
    k = _f32(rng, 1, 1, 64)

    def dot(m, n):
        qm = ref.rope(q, jnp.asarray([m], jnp.int32))
        kn = ref.rope(k, jnp.asarray([n], jnp.int32))
        return float(np.asarray(qm).ravel() @ np.asarray(kn).ravel())

    assert dot(5, 3) == pytest.approx(dot(105, 103), rel=1e-4)
    assert dot(17, 0) == pytest.approx(dot(1017, 1000), rel=1e-4)


def test_rope_position_zero_is_identity():
    rng = _rng(8)
    x = _f32(rng, 1, 2, 16)
    y = ref.rope(x, jnp.zeros((1,), jnp.int32))
    assert_allclose(np.asarray(y), np.asarray(x), atol=1e-7)
