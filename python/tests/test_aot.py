"""AOT driver tests: manifest schema, weight blob layout, determinism."""

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.config import CONFIGS, TINY

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_deterministic():
    w1 = aot.make_weights(TINY, seed=42)
    w2 = aot.make_weights(TINY, seed=42)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
    w3 = aot.make_weights(TINY, seed=43)
    assert any(not np.array_equal(w1[k], w3[k]) for k in w1)


def test_weight_shapes_cover_model():
    w = aot.make_weights(TINY, seed=42)
    lshapes = model.layer_weight_shapes(TINY)
    for i in range(TINY.n_layers):
        for name, shape in lshapes.items():
            assert w[f"layers.{i}.{name}"].shape == shape
    assert w["embed"].shape == (TINY.vocab, TINY.d_model)


def test_write_weights_table_contiguous(tmp_path):
    w = aot.make_weights(TINY, seed=42)
    path = tmp_path / "w.bin"
    table = aot.write_weights(w, str(path))
    expected_floats = sum(arr.size for arr in w.values())
    assert os.path.getsize(path) == expected_floats * 4
    off = 0
    for ent in table:
        assert ent["offset"] == off
        assert ent["size"] == int(np.prod(ent["shape"]))
        off += ent["size"]
    # round-trip one tensor
    ent = next(e for e in table if e["name"] == "layers.0.wq")
    raw = np.fromfile(path, np.float32, count=ent["size"], offset=ent["offset"] * 4)
    np.testing.assert_array_equal(raw.reshape(ent["shape"]), w["layers.0.wq"])


def test_build_artifacts_covers_kinds():
    arts = aot.build_artifacts(TINY)
    kinds = {k for (_, k, _, _) in arts}
    assert kinds == {"embed", "layer_decode", "layer_qkv", "layer_attn", "logits",
                     "select", "layer_prefill", "summarize"}
    names = [n for (n, _, _, _) in arts]
    assert len(names) == len(set(names)), "artifact names must be unique"


def test_artifact_arg_specs_are_concrete():
    for (_, _, _, args) in aot.build_artifacts(TINY):
        for (name, dtype, shape, is_weight) in args:
            assert dtype in ("f32", "i32")
            assert all(isinstance(s, int) and s > 0 for s in shape)
            assert isinstance(is_weight, bool)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_files_exist(self, manifest):
        for art in manifest["artifacts"]:
            path = os.path.join(ART, art["file"])
            assert os.path.exists(path), art["file"]
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_manifest_weights_exist(self, manifest):
        for cfg, went in manifest["weights"].items():
            path = os.path.join(ART, went["file"])
            n_floats = sum(t["size"] for t in went["tensors"])
            assert os.path.getsize(path) == n_floats * 4

    def test_manifest_configs_match_source(self, manifest):
        for name, cdict in manifest["configs"].items():
            src = CONFIGS[name].to_dict()
            assert cdict == src

    def test_golden_exists_and_sane(self, manifest):
        for name in manifest["configs"]:
            with open(os.path.join(ART, f"golden_{name}.json")) as f:
                g = json.load(f)
            assert len(g["generated"]) >= 4
            assert len(g["final_logits"]) == CONFIGS[name].vocab
            assert all(0 <= t < CONFIGS[name].vocab for t in g["generated"])

    def test_layer_decode_args_match_config(self, manifest):
        for art in manifest["artifacts"]:
            if art["kind"] != "layer_decode":
                continue
            cfg = CONFIGS[art["config"]]
            args = {a["name"]: a for a in art["args"]}
            b = args["h"]["shape"][0]
            assert args["k_cache"]["shape"] == [b, cfg.n_kv, cfg.budget_slots, cfg.d_head]
            assert args["valid"]["shape"] == [b, cfg.n_kv, cfg.budget_slots]
            assert [a["name"] for a in art["args"] if a["weight"]] == list(model.LAYER_WEIGHTS)
