//! Bench: recall path microbenchmarks — the Fig. 1 (right) breakdown on
//! paper geometry, plus *real* chunked-copy throughput of the transfer
//! engine under HND vs NHD CPU layouts (the physical effect the hybrid
//! layout exploits). `cargo bench --bench recall`.

use std::time::Instant;

use freekv::kvcache::{GpuLayerCache, LayerPool, Layout};
use freekv::policies::latency::{simulate_request, Method, SimKnobs};
use freekv::sim::{CostModel, DeviceProfile};
use freekv::transfer::TransferEngine;
use freekv::util::rng::Rng;

fn main() {
    println!("=== bench recall: Fig. 1 (right) breakdown (modeled, Llama-3.1-8B 32K) ===");
    let cm = CostModel::new(
        DeviceProfile::a100_pcie4(),
        freekv::config::ModelConfig::llama31_8b(),
    );
    let knobs = SimKnobs::default();
    for method in [Method::ArkVale, Method::ShadowKv, Method::InfiniGen, Method::FreeKv] {
        let r = simulate_request(method, &cm, 1, 32768, 64, &knobs);
        let per = r.steps as f64;
        println!(
            "{:<10} total {:>7.2} ms/tok | compute {:>6.2} sel {:>5.2} recall-exposed {:>7.2} (busy {:>7.2})",
            method.name(),
            r.per_token() * 1e3,
            (r.compute_busy - r.selection_busy) / per * 1e3,
            r.selection_busy / per * 1e3,
            r.recall_exposed / per * 1e3,
            r.recall_busy / per * 1e3,
        );
    }

    println!();
    println!("=== bench recall: REAL chunked-copy throughput (HND vs NHD pool) ===");
    // paper-scale page geometry: p=32, d=128, n_kv=8
    let (pages, n_kv, p, d) = (256usize, 8usize, 32usize, 128usize);
    let mut rng = Rng::new(1);
    for layout in [Layout::Hnd, Layout::Nhd] {
        let mut pool = LayerPool::new(layout, pages, n_kv, p, d);
        let page_elems = p * n_kv * d;
        let kdata: Vec<f32> = (0..page_elems).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for pg in 0..pages {
            pool.write_page(pg, &kdata, &kdata);
        }
        let mut gpu = GpuLayerCache::new(n_kv, d, p, 2, 2, 48, pages);
        // fill the gpu cache so selection slots exist
        for _ in 0..p * 4 {
            let t: Vec<f32> = (0..n_kv * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            gpu.append(&t.clone(), &t);
        }
        let mut eng = TransferEngine::new(p, d, true);
        let iters = 2000usize;
        let t0 = Instant::now();
        for i in 0..iters {
            let page = 4 + (i % (pages - 8));
            let head = i % n_kv;
            let slot = i % 48;
            eng.recall_page(&pool, page, head, &mut gpu, slot);
        }
        let dt = t0.elapsed().as_secs_f64();
        let c = &eng.counters;
        println!(
            "{:?}: {} page-head recalls in {:>7.2} ms | {:>6.1} MB/s effective | {} chunks ({} B/chunk) | h2d {:.2} ms convert {:.2} ms",
            layout,
            iters,
            dt * 1e3,
            c.h2d_bytes as f64 / dt / 1e6,
            c.h2d_chunks,
            c.h2d_bytes / c.h2d_chunks.max(1),
            c.real_h2d_secs * 1e3,
            c.real_convert_secs * 1e3,
        );
    }

    println!();
    println!("=== bench recall: modeled PCIe time per 32-page recall ===");
    for (label, hnd) in [("HND (FreeKV)", true), ("NHD (baseline)", false)] {
        let t = cm.recall_pages(32, hnd);
        println!("{:<16} {:>9.3} ms", label, t * 1e3);
    }
    println!(
        "token-wise (InfiniGen-style, same bytes): {:>9.3} ms",
        cm.recall_tokens(32 * 32) * 1e3
    );
}
