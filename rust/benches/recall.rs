//! Bench: recall path microbenchmarks — the Fig. 1 (right) breakdown on
//! paper geometry, *real* chunked-copy throughput of the transfer engine
//! under HND vs NHD CPU layouts (the physical effect the hybrid layout
//! exploits), and the *real* overlap win of the background recall
//! pipeline vs inline dispatch. `cargo bench --bench recall`.

use std::time::Instant;

use freekv::kvcache::{apply_selection_parts, LayerPool, LayerXfer, Layout, SelectSlots};
use freekv::linalg;
use freekv::policies::latency::{simulate_request, Method, SimKnobs};
use freekv::sim::{CostModel, DeviceProfile};
use freekv::transfer::{RecallJob, RecallPipeline, TransferEngine};
use freekv::util::rng::Rng;

fn main() {
    println!("=== bench recall: Fig. 1 (right) breakdown (modeled, Llama-3.1-8B 32K) ===");
    let cm = CostModel::new(
        DeviceProfile::a100_pcie4(),
        freekv::config::ModelConfig::llama31_8b(),
    );
    let knobs = SimKnobs::default();
    for method in [Method::ArkVale, Method::ShadowKv, Method::InfiniGen, Method::FreeKv] {
        let r = simulate_request(method, &cm, 1, 32768, 64, &knobs);
        let per = r.steps as f64;
        println!(
            "{:<10} total {:>7.2} ms/tok | compute {:>6.2} sel {:>5.2} recall-exposed {:>7.2} (busy {:>7.2})",
            method.name(),
            r.per_token() * 1e3,
            (r.compute_busy - r.selection_busy) / per * 1e3,
            r.selection_busy / per * 1e3,
            r.recall_exposed / per * 1e3,
            r.recall_busy / per * 1e3,
        );
    }

    println!();
    println!("=== bench recall: REAL chunked-copy throughput (HND vs NHD pool) ===");
    // paper-scale page geometry: p=32, d=128, n_kv=8
    let (pages, n_kv, p, d) = (256usize, 8usize, 32usize, 128usize);
    let mut rng = Rng::new(1);
    for layout in [Layout::Hnd, Layout::Nhd] {
        let mut pool = LayerPool::new(layout, pages, n_kv, p, d);
        let page_elems = p * n_kv * d;
        let kdata: Vec<f32> = (0..page_elems).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for pg in 0..pages {
            pool.write_page(pg, &kdata, &kdata);
        }
        let mut sel = SelectSlots::new(n_kv, d, p, 48);
        let mut eng = TransferEngine::new(p, d, true);
        let iters = 2000usize;
        let t0 = Instant::now();
        for i in 0..iters {
            let page = 4 + (i % (pages - 8));
            let head = i % n_kv;
            let slot = i % 48;
            eng.recall_page(&pool, page, head, &mut sel, slot);
        }
        let dt = t0.elapsed().as_secs_f64();
        let c = &eng.counters;
        println!(
            "{:?}: {} page-head recalls in {:>7.2} ms | {:>6.1} MB/s effective | {} chunks ({} B/chunk) | h2d {:.2} ms convert {:.2} ms",
            layout,
            iters,
            dt * 1e3,
            c.h2d_bytes as f64 / dt / 1e6,
            c.h2d_chunks,
            c.h2d_bytes / c.h2d_chunks.max(1),
            c.real_h2d_secs * 1e3,
            c.real_convert_secs * 1e3,
        );
    }

    println!();
    println!("=== bench recall: REAL inline vs pipelined recall (worker-thread overlap) ===");
    // Recall a churning selection while the "engine" does compute work of
    // comparable cost: inline pays recall + compute serially; the
    // pipeline hides the recall behind the compute.
    {
        let (pages, n_kv, p, d, sel_k) = (256usize, 8usize, 32usize, 128usize, 32usize);
        let mut rng = Rng::new(2);
        let mut pool = LayerPool::new(Layout::Hnd, pages, n_kv, p, d);
        let page_elems = p * n_kv * d;
        for pg in 0..pages {
            let k: Vec<f32> = (0..page_elems).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            pool.write_page(pg, &k, &k);
        }
        // two disjoint page sets so every iteration misses the page cache
        let set_a: Vec<Vec<usize>> = (0..n_kv).map(|_| (4..4 + sel_k).collect()).collect();
        let set_b: Vec<Vec<usize>> =
            (0..n_kv).map(|_| (4 + sel_k..4 + 2 * sel_k).collect()).collect();
        let work: Vec<f32> = (0..1 << 16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let compute = |rounds: usize| {
            let mut acc = 0.0f32;
            for _ in 0..rounds {
                acc += linalg::dot(&work, &work);
            }
            acc
        };
        let iters = 60usize;
        let rounds = 24usize;

        // inline dispatch
        let mut sel = SelectSlots::new(n_kv, d, p, sel_k);
        let mut eng = TransferEngine::new(p, d, true);
        let mut sink = 0.0f32;
        let t0 = Instant::now();
        for i in 0..iters {
            let pick = if i % 2 == 0 { &set_a } else { &set_b };
            for (head, pg) in pick.iter().enumerate() {
                apply_selection_parts(&mut sel, &pool, head, pg, &mut eng);
            }
            sink += compute(rounds);
        }
        let inline_secs = t0.elapsed().as_secs_f64();

        // pipelined dispatch: same work, recall on the worker
        let mut pipe = RecallPipeline::new(p, d);
        let mut xfer = Some(LayerXfer { select: SelectSlots::new(n_kv, d, p, sel_k), pool });
        let t0 = Instant::now();
        for i in 0..iters {
            let pick = if i % 2 == 0 { &set_a } else { &set_b };
            pipe.submit(RecallJob {
                seq_uid: 1,
                layer: 0,
                selections: pick.clone(),
                xfer: xfer.take().unwrap(),
            });
            sink += compute(rounds);
            let done = pipe.wait(1, 0);
            xfer = Some(done.xfer);
        }
        let piped_secs = t0.elapsed().as_secs_f64();
        println!(
            "inline   {:>8.2} ms  ({} iterations of {}-page x {}-head recall + compute)",
            inline_secs * 1e3,
            iters,
            sel_k,
            n_kv,
        );
        println!(
            "pipeline {:>8.2} ms  -> {:.2}x  [checksum {:.1}]",
            piped_secs * 1e3,
            inline_secs / piped_secs,
            sink
        );
    }

    println!();
    println!("=== bench recall: modeled PCIe time per 32-page recall ===");
    for (label, hnd) in [("HND (FreeKV)", true), ("NHD (baseline)", false)] {
        let t = cm.recall_pages(32, hnd);
        println!("{:<16} {:>9.3} ms", label, t * 1e3);
    }
    println!(
        "token-wise (InfiniGen-style, same bytes): {:>9.3} ms",
        cm.recall_tokens(32 * 32) * 1e3
    );
}
