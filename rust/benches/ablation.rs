//! Bench: Fig. 9 optimization ablation (HL / DB / SR) on paper geometry,
//! plus the real-engine speculative-vs-blocking comparison on the tiny
//! model. `cargo bench --bench ablation`.

use std::time::Instant;

use freekv::config::{FreeKvParams, ModelConfig};
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::policies::latency::{simulate_request, Method, SimKnobs};
use freekv::runtime::Runtime;
use freekv::sim::{CostModel, DeviceProfile};

fn main() {
    println!("=== bench ablation: Fig. 9 (modeled, Llama-3.1-8B) ===");
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
    for (scenario, input, output, base) in [
        ("long-input 32K->512", 32768usize, 512usize, SimKnobs::default()),
        ("long-gen 600->2K", 600, 2048, SimKnobs::long_generation()),
    ] {
        for b in [1usize, 4] {
            println!("--- {} (b={}) ---", scenario, b);
            let mut baseline = 0.0;
            for (label, hl, db, sr, ov) in [
                ("none", false, false, false, true),
                ("+HL", true, false, false, true),
                ("+HL+DB", true, true, false, true),
                ("+HL+DB+SR serial", true, true, true, false),
                ("+HL+DB+SR", true, true, true, true),
            ] {
                let knobs = SimKnobs {
                    hybrid_layout: hl,
                    double_buffer: db,
                    speculative: sr,
                    overlap: ov,
                    ..base.clone()
                };
                let r = simulate_request(Method::FreeKv, &cm, b, input, output.min(1024), &knobs);
                let pt = r.per_token() * 1e3;
                if !hl {
                    baseline = pt;
                }
                println!("{:<10} {:>8.2} ms/tok   {:>5.2}x", label, pt, baseline / pt);
            }
        }
    }

    println!();
    println!("=== bench ablation: REAL engine speculative vs blocking (tiny) ===");
    if Runtime::load("artifacts").is_err() {
        println!("artifacts/ missing — run `make artifacts` (skipping real bench)");
        return;
    }
    for (label, blocking, tau, overlap) in [
        ("speculative overlapped", false, 0.9f32, true),
        ("speculative serial", false, 0.9, false),
        ("blocking (no spec)", true, 1.0, true),
    ] {
        let rt = Runtime::load("artifacts").unwrap();
        let mut eng =
            Engine::new(rt, "tiny", FreeKvParams { tau, overlap, ..Default::default() }).unwrap();
        eng.blocking_mode = blocking;
        let prompt: Vec<i32> = (0..600).map(|i| (i * 13 % 250) as i32).collect();
        let mut seq = eng.new_sequence(
            1,
            prompt,
            96,
            SampleParams { temperature: 0.8, top_p: 0.95, seed: 3 },
        );
        let t0 = Instant::now();
        eng.generate(&mut seq).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<22} {:>6.1} ms/step | recalled {:>5} pages | corrections {:>4} | recall wall {:>6.1} ms [total {:.2}s]",
            label,
            eng.stats.decode_secs / eng.stats.steps.max(1) as f64 * 1e3,
            eng.stats.recalled_pages,
            eng.stats.corrections,
            eng.stats.recall_secs * 1e3,
            dt,
        );
    }
}
