//! Bench: end-to-end latency per method (Fig. 7 + Fig. 8 grids on the
//! paper geometries via the event simulator) plus the *real* tiny-model
//! decode throughput of the rust engine, including the serial-dispatch
//! vs overlapped speculative-recall ablation. Results are also written
//! to `BENCH_decode.json` for machine consumption.
//! `cargo bench --bench e2e`.

use std::time::Instant;

use freekv::config::{FreeKvParams, ModelConfig};
use freekv::coordinator::engine::{Engine, SampleParams, Sequence};
use freekv::policies::latency::{simulate_lane_scaling, simulate_request, Method, SimKnobs};
use freekv::runtime::Runtime;
use freekv::sim::{CostModel, DeviceProfile};
use freekv::util::json::{Json, JsonObj};

/// One real-engine N-lane decode run: `batch` sequences decoded through
/// `decode_step_lanes` with the engine's bucket-aware planner capped at
/// `max_lanes`. Returns (ms/step, tokens, stats snapshot).
fn real_lane_decode(
    batch: usize,
    max_lanes: usize,
    exec_workers: usize,
    steps: usize,
) -> Option<(f64, Vec<Vec<i32>>, freekv::coordinator::engine::EngineStats)> {
    let rt = Runtime::load("artifacts").ok()?;
    let params =
        FreeKvParams { tau: 0.9, overlap: true, exec_workers, max_lanes, ..Default::default() };
    let mut eng = Engine::new(rt, "tiny", params).ok()?;
    let prompt: Vec<i32> = (0..480).map(|i| (i * 17 % 250) as i32).collect();
    let mut seqs: Vec<Sequence> = (0..batch)
        .map(|i| {
            eng.new_sequence(
                i as u64,
                prompt.clone(),
                steps + 1,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let _ = eng.prefill(s).unwrap();
        s.tokens.push(1);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut lanes: Vec<Vec<&mut Sequence>> = vec![seqs.iter_mut().collect()];
        eng.decode_step_lanes(&mut lanes).unwrap();
    }
    let ms_per_step = t0.elapsed().as_secs_f64() / steps as f64 * 1e3;
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    let tokens = seqs.iter().map(|s| s.generated().to_vec()).collect();
    Some((ms_per_step, tokens, eng.stats.clone()))
}

/// One real-engine decode run; returns (ms/step, stats snapshot, tokens).
fn real_decode(
    overlap: bool,
    exec_workers: usize,
    batch: usize,
    steps: usize,
) -> Option<(f64, freekv::coordinator::engine::EngineStats, Vec<Vec<i32>>)> {
    let rt = Runtime::load("artifacts").ok()?;
    let params = FreeKvParams { tau: 0.9, overlap, exec_workers, ..Default::default() };
    let mut eng = Engine::new(rt, "tiny", params).ok()?;
    let prompt: Vec<i32> = (0..480).map(|i| (i * 17 % 250) as i32).collect();
    let mut seqs: Vec<_> = (0..batch)
        .map(|i| {
            eng.new_sequence(
                i as u64,
                prompt.clone(),
                steps + 1,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let _ = eng.prefill(s).unwrap();
        s.tokens.push(1);
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut batch_refs: Vec<&mut _> = seqs.iter_mut().collect();
        eng.decode_step(&mut batch_refs).unwrap();
    }
    let ms_per_step = t0.elapsed().as_secs_f64() / steps as f64 * 1e3;
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    let tokens = seqs.iter().map(|s| s.generated().to_vec()).collect();
    Some((ms_per_step, eng.stats.clone(), tokens))
}

fn main() {
    let mut report = JsonObj::new();

    println!("=== bench e2e: Fig. 7 grid (A100 profile, modeled) ===");
    for model in [ModelConfig::qwen25_7b(), ModelConfig::llama31_8b()] {
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), model.clone());
        for (scenario, input, output, knobs) in [
            ("long-input 32K->512", 32768usize, 512usize, SimKnobs::default()),
            ("long-gen 600->16K", 600, 16384, SimKnobs::long_generation()),
        ] {
            println!("--- {} {} ---", model.name, scenario);
            let steps = output.min(1024);
            let mut freekv_total = f64::MAX;
            let mut rows = Vec::new();
            for method in [
                Method::Razor,
                Method::RaaS,
                Method::ArkVale,
                Method::ShadowKv,
                Method::InfiniGen,
                Method::FreeKv,
            ] {
                let t0 = Instant::now();
                let r = simulate_request(method, &cm, 4, input, steps, &knobs);
                let total = r.prefill_secs + r.per_token() * output as f64;
                if method == Method::FreeKv {
                    freekv_total = total;
                }
                rows.push((method, total, t0.elapsed().as_secs_f64()));
            }
            for (method, total, sim_wall) in rows {
                println!(
                    "{:<10} b=4 modeled {:>8.2}s  ({:>5.2}x vs freekv)  [sim wall {:.2}s]",
                    method.name(),
                    total,
                    total / freekv_total,
                    sim_wall
                );
            }
        }
    }

    println!();
    println!("=== bench e2e: modeled serial-dispatch vs overlapped recall (Llama-3.1-8B) ===");
    {
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
        let on = simulate_request(Method::FreeKv, &cm, 4, 32768, 256, &SimKnobs::default());
        let off = simulate_request(
            Method::FreeKv,
            &cm,
            4,
            32768,
            256,
            &SimKnobs { overlap: false, ..Default::default() },
        );
        let speedup = off.per_token() / on.per_token();
        println!(
            "serial  {:>7.2} ms/tok (recall exposed {:.0}% of busy)",
            off.per_token() * 1e3,
            off.recall_exposed / off.recall_busy.max(1e-12) * 100.0
        );
        println!(
            "overlap {:>7.2} ms/tok (recall exposed {:.0}% of busy)  -> {:.2}x",
            on.per_token() * 1e3,
            on.recall_exposed / on.recall_busy.max(1e-12) * 100.0,
            speedup
        );
        let mut modeled = JsonObj::new();
        modeled.insert("config", "llama-3.1-8b b=4 32k->256");
        modeled.insert("serial_ms_per_tok", off.per_token() * 1e3);
        modeled.insert("overlap_ms_per_tok", on.per_token() * 1e3);
        modeled.insert("speedup", speedup);
        modeled.insert("serial_recall_exposed_frac", off.recall_exposed / off.recall_busy.max(1e-12));
        modeled.insert("overlap_recall_exposed_frac", on.recall_exposed / on.recall_busy.max(1e-12));
        report.insert("modeled", modeled);
    }

    println!();
    println!("=== bench e2e: modeled serial vs pooled artifact dispatch (Llama-3.1-8B) ===");
    {
        // The executor-pool analog: selection scoring moves off the
        // compute stream (SimKnobs::pooled_selection), the modeled twin
        // of FreeKvParams::exec_workers on the real engine.
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
        let serial = simulate_request(Method::FreeKv, &cm, 4, 32768, 256, &SimKnobs::default());
        let pooled = simulate_request(
            Method::FreeKv,
            &cm,
            4,
            32768,
            256,
            &SimKnobs { pooled_selection: true, ..Default::default() },
        );
        let speedup = serial.per_token() / pooled.per_token();
        println!(
            "serial  {:>7.2} ms/tok (selection exposed {:>6.3} ms, on the compute stream)",
            serial.per_token() * 1e3,
            serial.selection_exposed * 1e3 / serial.steps.max(1) as f64,
        );
        println!(
            "pooled  {:>7.2} ms/tok (selection exposed {:>6.3} ms of {:>6.3} ms busy)  -> {:.2}x",
            pooled.per_token() * 1e3,
            pooled.selection_exposed * 1e3 / pooled.steps.max(1) as f64,
            pooled.selection_busy * 1e3 / pooled.steps.max(1) as f64,
            speedup
        );
        let mut modeled = JsonObj::new();
        modeled.insert("config", "llama-3.1-8b b=4 32k->256");
        modeled.insert("serial_ms_per_tok", serial.per_token() * 1e3);
        modeled.insert("pooled_ms_per_tok", pooled.per_token() * 1e3);
        modeled.insert("speedup", speedup);
        modeled.insert(
            "pooled_selection_exposed_frac",
            pooled.selection_exposed / pooled.selection_busy.max(1e-12),
        );
        report.insert("modeled_dispatch", modeled);
    }

    println!();
    println!("=== bench e2e: modeled decode lane sweep (Llama-3.1-8B, b=8) ===");
    {
        // The N-lane microbatch model: per-lane artifact streams with
        // host-side work serialized on the engine thread
        // (simulate_lane_scaling) — the modeled twin of --max-lanes.
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
        let mut rows = Vec::new();
        for lanes in [1usize, 2, 4] {
            let k = SimKnobs { decode_lanes: lanes, exec_streams: 4, ..Default::default() };
            let r = simulate_lane_scaling(&cm, 8, 128, &k);
            println!("lanes={} {:>8.2} ms/tok", lanes, r.per_token() * 1e3);
            let mut o = JsonObj::new();
            o.insert("lanes", lanes);
            o.insert("ms_per_tok", r.per_token() * 1e3);
            rows.push(Json::from(o));
        }
        report.insert("modeled_lanes", Json::Arr(rows));
    }

    println!();
    println!("=== bench e2e: shared-prefix pool memory (sim, 8 requests) ===");
    {
        // Eight requests with an identical prompt through the full
        // scheduler stack on the artifact-free SimBackend: with the
        // prefix cache on, the shared pool should hold roughly one
        // request's pages instead of eight. Peak pages come from the
        // allocator's high-water mark, so this also runs in CI's
        // bench-smoke job without artifacts.
        use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
        use freekv::coordinator::sim_backend::SimBackend;
        let run = |share: bool| -> (u64, u64) {
            let backend = SimBackend::tiny_with_pool(0, share);
            let alloc = backend.allocator();
            let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
            let mut s = Scheduler::new(backend, cfg);
            let prompt = "shared prefix workload ".repeat(8);
            for i in 1..=8u64 {
                s.submit(Request::from_text(i, &prompt, 32));
            }
            s.drain().expect("sim drain");
            let st = alloc.stats();
            (st.pages_peak, st.prefix_hits)
        };
        let (private_peak, _) = run(false);
        let (shared_peak, hits) = run(true);
        let savings = 1.0 - shared_peak as f64 / private_peak.max(1) as f64;
        println!(
            "private {:>5} pages peak | shared {:>5} pages peak | prefix hits {} | {:.0}% saved",
            private_peak,
            shared_peak,
            hits,
            savings * 100.0
        );
        let mut mem = JsonObj::new();
        mem.insert("requests", 8usize);
        mem.insert("pages_peak_private", private_peak as usize);
        mem.insert("pages_peak_shared", shared_peak as usize);
        mem.insert("prefix_hits", hits as usize);
        mem.insert("savings_frac", savings);
        report.insert("memory", mem);
    }

    println!();
    println!("=== bench e2e: persistent prefix cache (sim, 8 serialized requests) ===");
    {
        // Eight requests with an identical prompt run one at a time —
        // each fully retires before the next arrives, so a resident-only
        // prefix cache can never share (no live pages to alias). With
        // the retained tier on, every request after the first adopts the
        // whole prompt from cache instead of re-offloading it. Runs in
        // CI's bench-smoke job without artifacts.
        use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
        use freekv::coordinator::sim_backend::SimBackend;
        use freekv::kvcache::PrefixCacheMode;
        let requests = 8u64;
        let run = |mode: PrefixCacheMode| -> (u64, u64, u64, u64) {
            let backend = SimBackend::tiny_with_pool_mode(0, mode, 0);
            let alloc = backend.allocator();
            let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
            let mut s = Scheduler::new(backend, cfg);
            let prompt = "shared prefix workload ".repeat(8);
            for i in 1..=requests {
                s.submit(Request::from_text(i, &prompt, 32));
                s.drain().expect("sim drain");
            }
            let st = alloc.stats();
            let saved = s.engine.stats().prefill_tokens_saved;
            (st.retained_hits, st.prefix_hits, st.bytes_saved, saved)
        };
        let (_, resident_hits, _, _) = run(PrefixCacheMode::Resident);
        let (retained_hits, prefix_hits, bytes_saved, tokens_saved) =
            run(PrefixCacheMode::Retained);
        // prefill offloads the prompt's completed pages once per request;
        // the hit rate is the fraction of those writes the cache absorbed
        let offloads = prefix_hits.max(1) * requests / (requests - 1).max(1);
        let hit_rate = prefix_hits as f64 / offloads.max(1) as f64;
        println!(
            "resident-only hits {:>3} | retained hits {:>3} of {:>3} prefix hits \
             | {:>5} prefill tokens saved | {:>8} bytes saved | hit rate {:.0}%",
            resident_hits,
            retained_hits,
            prefix_hits,
            tokens_saved,
            bytes_saved,
            hit_rate * 100.0
        );
        let mut px = JsonObj::new();
        px.insert("requests", requests as usize);
        px.insert("resident_only_prefix_hits", resident_hits as usize);
        px.insert("retained_hits", retained_hits as usize);
        px.insert("prefix_hits", prefix_hits as usize);
        px.insert("prefill_tokens_saved", tokens_saved as usize);
        px.insert("bytes_saved", bytes_saved as usize);
        px.insert("hit_rate", hit_rate);
        report.insert("prefix", px);
    }

    println!();
    println!("=== bench e2e: KV page codecs (sim, 8 requests) ===");
    {
        // The memory-section workload re-run once per page codec, plus a
        // standalone offload/recall stream through a pool of each codec:
        // page counts stay identical across dtypes while the pool byte
        // gauges and encoded wire traffic shrink with the codec. Runs in
        // CI's bench-smoke job without artifacts.
        use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
        use freekv::coordinator::sim_backend::SimBackend;
        use freekv::kvcache::{GpuLayerCache, KvDtype, Layout, LayerPool};
        use freekv::transfer::TransferEngine;
        use freekv::util::rng::Rng;
        let mut rows = Vec::new();
        for dtype in KvDtype::all() {
            let backend = SimBackend::tiny_with_pool_dtype(0, true, dtype);
            let alloc = backend.allocator();
            let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
            let mut s = Scheduler::new(backend, cfg);
            let prompt = "shared prefix workload ".repeat(8);
            for i in 1..=8u64 {
                s.submit(Request::from_text(i, &prompt, 32));
            }
            s.drain().expect("sim drain");
            let st = alloc.stats();
            // encoded wire traffic: 6 pages offloaded, 4 recalled per head
            let (m, d, p) = (2usize, 8usize, 4usize);
            let mut pool = LayerPool::new_dtype(Layout::Hnd, 16, m, p, d, dtype);
            let mut gpu = GpuLayerCache::new(m, d, p, 1, 2, 2, 16);
            let mut sel = gpu.new_select_slots();
            let mut eng = TransferEngine::new(p, d, true);
            let mut rng = Rng::new(5);
            for _ in 0..(6 * p) {
                let k: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                if let Some(cp) = gpu.append(&k, &v) {
                    eng.offload_page(&cp, &mut pool);
                }
            }
            for page in 0..4usize {
                for head in 0..m {
                    eng.recall_page(&pool, page, head, &mut sel, page % 2);
                }
            }
            println!(
                "{:>4}: peak {:>4} pages {:>9} pool bytes  hits {:>3} | recall {:>5} B  offload {:>5} B (encoded)",
                dtype,
                st.pages_peak,
                st.cpu_bytes_peak,
                st.prefix_hits,
                eng.counters.h2d_encoded_bytes,
                eng.counters.d2h_encoded_bytes,
            );
            let mut o = JsonObj::new();
            o.insert("dtype", dtype.as_str());
            o.insert("pages_peak", st.pages_peak as usize);
            o.insert("pool_bytes_peak", st.cpu_bytes_peak as usize);
            o.insert("prefix_hits", st.prefix_hits as usize);
            o.insert("recall_encoded_bytes", eng.counters.h2d_encoded_bytes as usize);
            o.insert("recall_logical_bytes", eng.counters.h2d_bytes as usize);
            o.insert("offload_encoded_bytes", eng.counters.d2h_encoded_bytes as usize);
            rows.push(Json::from(o));
        }
        report.insert("kv_dtype", Json::Arr(rows));
    }

    println!();
    println!("=== bench e2e: allocator lock contention (sharded vs global) ===");
    {
        use freekv::kvcache::{
            KvDtype, KvLockMode, LayerPool, Layout, PageAllocator, PrefixCacheMode,
        };

        const L: usize = 8; // layers = shard count under --kv-lock=sharded
        const M: usize = 2;
        const P: usize = 16;
        const D: usize = 16;
        const PAGES: usize = 16;
        const RECALL_THREADS: usize = 4;
        const RECALL_OPS: usize = 4000;
        const WRITER_ROUNDS: usize = 8;
        const WRITER_PAGES_PER_ROUND: usize = 256;

        let key = |page: usize| 0xC0FF_EE00u128 + page as u128;
        let elems = P * M * D;
        let k: Vec<f32> = (0..elems).map(|i| (i % 251) as f32 * 0.125 - 8.0).collect();
        let v: Vec<f32> = (0..elems).map(|i| (i % 239) as f32 * 0.25 - 16.0).collect();

        // One engine-pattern writer (append + drop churn on its own
        // private pages) plus N recall workers gather-reading adopted
        // shared prefix pages on disjoint layer stripes — the decode-loop
        // shape the shard split targets. Returns total ops, wall seconds,
        // and the lock wait-count/wait-time deltas across the run.
        let run = |lock: KvLockMode, recall_threads: usize, with_writer: bool| {
            let alloc = PageAllocator::with_mode_lock(
                L,
                M,
                P,
                D,
                0,
                PrefixCacheMode::Resident,
                0,
                0xBE9C,
                KvDtype::F32,
                lock,
            );
            // Seed the shared prefix pages the recall workers adopt; the
            // seeder views stay alive through the run so the Resident
            // registrations survive.
            let mut seed: Vec<LayerPool> = (0..L)
                .map(|l| LayerPool::with_alloc(Layout::Hnd, PAGES, M, P, D, alloc.clone(), l))
                .collect();
            for pool in seed.iter_mut() {
                for page in 0..PAGES {
                    pool.write_page_keyed(page, &k, &v, Some(key(page)));
                }
            }
            let before = alloc.stats();
            let t0 = Instant::now();
            let ops: u64 = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for t in 0..recall_threads {
                    let alloc = alloc.clone();
                    handles.push(s.spawn(move || {
                        let mut pools: Vec<LayerPool> = (0..L)
                            .filter(|l| l % recall_threads == t)
                            .map(|l| {
                                LayerPool::with_alloc(Layout::Hnd, PAGES, M, P, D, alloc.clone(), l)
                            })
                            .collect();
                        for p in pools.iter_mut() {
                            for page in 0..PAGES {
                                assert!(p.try_adopt(page, key(page)));
                            }
                        }
                        let mut dst = vec![0.0f32; 2 * P * D];
                        let mut ops = 0u64;
                        for i in 0..RECALL_OPS {
                            let n_pools = pools.len();
                            let pool = &mut pools[i % n_pools];
                            let page = i % PAGES;
                            let chunks = pool.recall_chunks(page, i % M);
                            pool.copy_chunks(page, &chunks, &mut dst);
                            ops += 1;
                            if i % 64 == 63 {
                                // release/re-adopt churn on the shared slot
                                assert!(pool.try_adopt(page, key(page)));
                                ops += 1;
                            }
                        }
                        ops
                    }));
                }
                if with_writer {
                    let alloc = alloc.clone();
                    let (kr, vr) = (&k, &v);
                    handles.push(s.spawn(move || {
                        let mut ops = 0u64;
                        for _ in 0..WRITER_ROUNDS {
                            let mut pools: Vec<LayerPool> = (0..L)
                                .map(|l| {
                                    LayerPool::with_alloc(
                                        Layout::Hnd,
                                        PAGES,
                                        M,
                                        P,
                                        D,
                                        alloc.clone(),
                                        l,
                                    )
                                })
                                .collect();
                            for i in 0..WRITER_PAGES_PER_ROUND {
                                pools[i % L].write_page((i / L) % PAGES, kr, vr);
                                ops += 1;
                            }
                            // dropping the views frees the round's private
                            // pages — the release half of the lifecycle
                        }
                        ops
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).sum()
            });
            let wall = t0.elapsed().as_secs_f64();
            let after = alloc.stats();
            drop(seed);
            (
                ops,
                wall,
                after.shard_lock_waits - before.shard_lock_waits,
                after.shard_lock_wait_secs - before.shard_lock_wait_secs,
                after.meta_lock_waits - before.meta_lock_waits,
                after.meta_lock_wait_secs - before.meta_lock_wait_secs,
            )
        };

        let mut section = JsonObj::new();
        section.insert("layers", L);
        section.insert("recall_threads", RECALL_THREADS);
        let mut sharded_wait = f64::NAN;
        let mut global_wait = f64::NAN;
        for lock in KvLockMode::all() {
            let (s_ops, s_wall, ..) = run(lock, 1, false);
            let single_ops_s = s_ops as f64 / s_wall;
            let (c_ops, c_wall, sw, sws, mw, mws) = run(lock, RECALL_THREADS, true);
            let total_wait = sws + mws;
            let contended_ops_s = c_ops as f64 / c_wall;
            println!(
                "{:<7} single {:>9.0} ops/s | contended {:>9.0} ops/s | shard waits {:>6} ({:>8.4} s) meta waits {:>6} ({:>8.4} s)",
                lock.as_str(),
                single_ops_s,
                contended_ops_s,
                sw,
                sws,
                mw,
                mws,
            );
            let mut o = JsonObj::new();
            o.insert("single_thread_ops_per_sec", single_ops_s);
            o.insert("contended_ops_per_sec", contended_ops_s);
            o.insert("contended_wall_secs", c_wall);
            o.insert("shard_lock_waits", sw as usize);
            o.insert("shard_lock_wait_secs", sws);
            o.insert("meta_lock_waits", mw as usize);
            o.insert("meta_lock_wait_secs", mws);
            o.insert("total_lock_wait_secs", total_wait);
            section.insert(lock.as_str(), o);
            match lock {
                KvLockMode::Sharded => sharded_wait = total_wait,
                KvLockMode::Global => global_wait = total_wait,
            }
        }
        if global_wait > 0.0 {
            let ratio = sharded_wait / global_wait;
            println!("sharded total lock wait = {:.1}% of global", ratio * 100.0);
            section.insert("sharded_wait_over_global", ratio);
        } else {
            println!("global run saw no lock waits — wait ratio not meaningful");
            section.insert("sharded_wait_over_global", Json::Null);
        }
        report.insert("alloc_contention", section);
    }

    println!();
    println!("=== bench e2e: router tier (sim multi-replica loadtest) ===");
    {
        // The multi-replica serving tier on the artifact-free SimBackend
        // (CI's bench-smoke job records this without artifacts): a timed
        // workload replayed through N independent scheduler replicas
        // behind each dispatch policy. The long-gen burst measures pure
        // scale-out — prompts are unique, so dispatch is load-driven and
        // the replica count is the throughput lever. The repeated-prompt
        // trickle measures what prefix affinity adds on top: every
        // request opens with one shared head, and the kv-aware policy
        // should pin the repeats to the replica retaining it.
        use freekv::coordinator::router::{DispatchPolicy, KvRouterConfig};
        use freekv::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use freekv::coordinator::sim_backend::SimBackend;
        use freekv::kvcache::PrefixCacheMode;
        use freekv::workload::{generate, run_router_loadtest, Scenario, WorkloadSpec};

        let tps = 1000.0;
        let run = |spec: &WorkloadSpec, replicas: usize, kv: bool| {
            let mut scheds: Vec<Scheduler<SimBackend>> = (0..replicas)
                .map(|_| {
                    Scheduler::new(
                        SimBackend::tiny_with_pool_mode(0, PrefixCacheMode::Retained, 0),
                        SchedulerConfig { max_batch: 4, admit_below: 4, ..Default::default() },
                    )
                })
                .collect();
            let page_size = scheds[0].engine.model().page_size;
            let mut policy = if kv {
                DispatchPolicy::kv_aware(KvRouterConfig { page_size, ..Default::default() })
            } else {
                DispatchPolicy::round_robin()
            };
            run_router_loadtest(&mut scheds, &mut policy, generate(spec), tps)
                .expect("sim router loadtest")
        };

        // replica sweep: a decode-bound burst (every arrival at t≈0)
        let burst = WorkloadSpec {
            scenario: Scenario::LongGeneration,
            rate: 1e6,
            n_requests: 32,
            max_prompt: 64,
            max_output: 16,
            seed: 0xF00D,
        };
        let mut rows = Vec::new();
        let mut kv_tput_1 = f64::NAN;
        let mut kv_tput_4 = f64::NAN;
        for replicas in [1usize, 2, 4] {
            for kv in [true, false] {
                let r = run(&burst, replicas, kv);
                let name = if kv { "kv" } else { "round-robin" };
                let tput = r.modeled_throughput(tps);
                if kv && replicas == 1 {
                    kv_tput_1 = tput;
                }
                if kv && replicas == 4 {
                    kv_tput_4 = tput;
                }
                println!(
                    "long-gen burst  {:<11} replicas={} {:>8.1} tok/s  ttft p95 {:>6.3}s  completed {}/{}",
                    name,
                    replicas,
                    tput,
                    r.ttft_p95_secs,
                    r.completed,
                    burst.n_requests,
                );
                let mut o = JsonObj::new();
                o.insert("scenario", "long-gen-burst");
                o.insert("router", name);
                o.insert("replicas", replicas);
                o.insert("modeled_tok_s", tput);
                o.insert("ttft_p95_secs", r.ttft_p95_secs);
                o.insert("completed", r.completed);
                o.insert("failed", r.failed);
                o.insert("retained_hit_concentration", r.retained_hit_concentration());
                rows.push(Json::from(o));
            }
        }
        let speedup = kv_tput_4 / kv_tput_1;
        println!("kv 4-replica speedup over 1 replica (long-gen burst) = {:.2}x", speedup);

        // affinity: spaced repeated-prompt arrivals, 2 replicas, kv vs rr
        let trickle = WorkloadSpec {
            scenario: Scenario::RepeatedPrompt,
            rate: 20.0,
            n_requests: 16,
            max_prompt: 64,
            max_output: 8,
            seed: 0xF00D,
        };
        let mut affinity = JsonObj::new();
        for (label, kv) in [("kv", true), ("round_robin", false)] {
            let r = run(&trickle, 2, kv);
            println!(
                "repeated trickle {:<11} replicas=2 retained hits {:>4} (concentration {:.2})  prefill tokens saved {:>5}",
                label,
                r.retained_hits(),
                r.retained_hit_concentration(),
                r.prefill_tokens_saved(),
            );
            let mut o = JsonObj::new();
            o.insert("retained_hits", r.retained_hits() as usize);
            o.insert("retained_hit_concentration", r.retained_hit_concentration());
            o.insert("prefill_tokens_saved", r.prefill_tokens_saved() as usize);
            o.insert("modeled_tok_s", r.modeled_throughput(tps));
            o.insert("ttft_p95_secs", r.ttft_p95_secs);
            affinity.insert(label, o);
        }

        let mut section = JsonObj::new();
        section.insert("sweep", Json::Arr(rows));
        section.insert("speedup_kv_4x_vs_1x", speedup);
        section.insert("affinity_2x", affinity);
        report.insert("router", section);
    }

    println!();
    println!("=== bench e2e: real tiny-model engine throughput ===");
    if Runtime::load("artifacts").is_err() {
        println!("artifacts/ missing — run `make artifacts` (skipping real bench)");
        report.insert("real", Json::Null);
        report.insert("real_dispatch", Json::Null);
        write_report(&report);
        return;
    }
    // baseline throughput sweep (speculative overlapped mode, pooled)
    for &batch in &[1usize, 4] {
        if let Some((ms_per_step, _, _)) = real_decode(true, 2, batch, 48) {
            println!(
                "real decode: batch={} {:>6.1} ms/step  {:>6.1} tok/s",
                batch,
                ms_per_step,
                batch as f64 * 1e3 / ms_per_step
            );
        }
    }

    println!();
    println!("=== bench e2e: REAL serial vs pooled artifact dispatch (tiny, b=4) ===");
    {
        // Same recall overlap in both runs; only the execution venue of
        // selection scoring changes (engine thread vs executor pool).
        let (batch, steps) = (4usize, 48usize);
        let inline = real_decode(true, 0, batch, steps);
        let pooled = real_decode(true, 2, batch, steps);
        match (inline, pooled) {
            (Some((ser_ms, ser_st, ser_toks)), Some((pool_ms, pool_st, pool_toks))) => {
                let speedup = ser_ms / pool_ms;
                println!(
                    "serial  {:>7.2} ms/step | select exposed {:>7.2} ms (on-thread)",
                    ser_ms,
                    ser_st.select_secs * 1e3,
                );
                println!(
                    "pooled  {:>7.2} ms/step | select exposed {:>7.2} ms hidden {:>7.2} ms | {} pool jobs | {:.2}x",
                    pool_ms,
                    pool_st.select_secs * 1e3,
                    pool_st.select_hidden_secs * 1e3,
                    pool_st.exec_jobs,
                    speedup,
                );
                let identical = ser_toks == pool_toks;
                println!("outputs bit-identical across dispatch modes: {}", identical);
                let mut real = JsonObj::new();
                real.insert("model", "tiny");
                real.insert("batch", batch);
                real.insert("steps", steps);
                real.insert("serial_ms_per_step", ser_ms);
                real.insert("pooled_ms_per_step", pool_ms);
                real.insert("speedup", speedup);
                real.insert("serial_select_secs", ser_st.select_secs);
                real.insert("pooled_select_secs", pool_st.select_secs);
                real.insert("pooled_select_hidden_secs", pool_st.select_hidden_secs);
                real.insert("pooled_exec_jobs", pool_st.exec_jobs as usize);
                real.insert("outputs_identical", identical);
                report.insert("real_dispatch", real);
            }
            _ => {
                report.insert("real_dispatch", Json::Null);
            }
        }
    }

    println!();
    println!("=== bench e2e: REAL decode lane sweep (tiny) ===");
    {
        // Per-lane width pinned at 4 (one full bucket): batch grows with
        // the lane count, so the tok/s column is the lane-scaling curve.
        let steps = 32usize;
        let mut rows = Vec::new();
        let mut outputs_identical = true;
        let mut perf: Vec<(usize, usize, f64)> = Vec::new();
        for (batch, lanes) in [(4usize, 1usize), (8, 2), (16, 4)] {
            match real_lane_decode(batch, lanes, 2, steps) {
                Some((ms, toks, st)) => {
                    let tok_s = batch as f64 * 1e3 / ms;
                    println!(
                        "batch={:>2} max_lanes={} {:>8.2} ms/step {:>8.1} tok/s | lane_sets {} peak inflight {}",
                        batch, lanes, ms, tok_s, st.lane_sets, st.max_lanes_inflight,
                    );
                    // lane scheduling must not change any sequence's
                    // tokens vs single-lane dispatch of the same batch
                    // (the lanes==1 row IS its own reference)
                    if lanes > 1 {
                        match real_lane_decode(batch, 1, 2, steps) {
                            Some((_, ref_toks, _)) => outputs_identical &= ref_toks == toks,
                            None => outputs_identical = false,
                        }
                    }
                    let mut o = JsonObj::new();
                    o.insert("batch", batch);
                    o.insert("max_lanes", lanes);
                    o.insert("ms_per_step", ms);
                    o.insert("tok_s", tok_s);
                    o.insert("lane_sets", st.lane_sets as usize);
                    o.insert("max_lanes_inflight", st.max_lanes_inflight as usize);
                    rows.push(Json::from(o));
                    perf.push((batch, lanes, tok_s));
                }
                None => break,
            }
        }
        if rows.is_empty() {
            report.insert("real_lanes", Json::Null);
            report.insert("real_lanes_workers", Json::Null);
        } else {
            println!("lane outputs identical to single-lane dispatch: {}", outputs_identical);
            report.insert("real_lanes", Json::Arr(rows));
            report.insert("real_lanes_outputs_identical", outputs_identical);
            // exec-worker sweep at the best lane count: does the executor
            // pool still pay for itself once lanes already overlap compute?
            let (batch, lanes, _) = *perf
                .iter()
                .max_by(|a, b| a.2.total_cmp(&b.2))
                .expect("perf rows mirror the lane rows");
            let mut wrows = Vec::new();
            for workers in [1usize, 2, 4] {
                if let Some((ms, _, _)) = real_lane_decode(batch, lanes, workers, steps) {
                    let tok_s = batch as f64 * 1e3 / ms;
                    println!(
                        "batch={:>2} max_lanes={} exec_workers={} {:>8.2} ms/step {:>8.1} tok/s",
                        batch, lanes, workers, ms, tok_s,
                    );
                    let mut o = JsonObj::new();
                    o.insert("batch", batch);
                    o.insert("max_lanes", lanes);
                    o.insert("exec_workers", workers);
                    o.insert("ms_per_step", ms);
                    o.insert("tok_s", tok_s);
                    wrows.push(Json::from(o));
                }
            }
            report.insert("real_lanes_workers", Json::Arr(wrows));
        }
    }

    println!();
    println!("=== bench e2e: REAL serial-dispatch vs overlapped recall (tiny, b=4) ===");
    let (batch, steps) = (4usize, 48usize);
    let serial = real_decode(false, 2, batch, steps);
    let overlapped = real_decode(true, 2, batch, steps);
    match (serial, overlapped) {
        (Some((ser_ms, ser_st, ser_toks)), Some((ovl_ms, ovl_st, ovl_toks))) => {
            let speedup = ser_ms / ovl_ms;
            println!(
                "serial  {:>7.2} ms/step | recall exposed {:>7.2} ms hidden {:>7.2} ms | gather {:>7.2} ms",
                ser_ms,
                ser_st.recall_exposed_secs * 1e3,
                ser_st.recall_hidden_secs * 1e3,
                ser_st.gather_secs * 1e3,
            );
            println!(
                "overlap {:>7.2} ms/step | recall exposed {:>7.2} ms hidden {:>7.2} ms | gather {:>7.2} ms | queue depth {} | {:.2}x",
                ovl_ms,
                ovl_st.recall_exposed_secs * 1e3,
                ovl_st.recall_hidden_secs * 1e3,
                ovl_st.gather_secs * 1e3,
                ovl_st.max_queue_depth,
                speedup,
            );
            let identical = ser_toks == ovl_toks;
            println!("outputs bit-identical across modes: {}", identical);
            let mut real = JsonObj::new();
            real.insert("model", "tiny");
            real.insert("batch", batch);
            real.insert("steps", steps);
            real.insert("serial_ms_per_step", ser_ms);
            real.insert("overlap_ms_per_step", ovl_ms);
            real.insert("speedup", speedup);
            real.insert("serial_recall_exposed_secs", ser_st.recall_exposed_secs);
            real.insert("overlap_recall_exposed_secs", ovl_st.recall_exposed_secs);
            real.insert("overlap_recall_hidden_secs", ovl_st.recall_hidden_secs);
            real.insert("overlap_recall_hidden_fraction", ovl_st.recall_hidden_fraction());
            real.insert("serial_gather_secs", ser_st.gather_secs);
            real.insert("overlap_gather_secs", ovl_st.gather_secs);
            real.insert("recall_jobs", ovl_st.recall_jobs as usize);
            real.insert("max_queue_depth", ovl_st.max_queue_depth as usize);
            real.insert("outputs_identical", identical);
            report.insert("real", real);
        }
        _ => {
            report.insert("real", Json::Null);
        }
    }
    write_report(&report);
}

fn write_report(report: &JsonObj) {
    let path = "BENCH_decode.json";
    let body = Json::Obj(report.clone()).to_string_pretty();
    match std::fs::write(path, &body) {
        Ok(()) => println!("\nwrote {}", path),
        Err(e) => eprintln!("failed writing {}: {}", path, e),
    }
}
