//! Bench: end-to-end latency per method (Fig. 7 + Fig. 8 grids on the
//! paper geometries via the event simulator) plus the *real* tiny-model
//! decode throughput of the rust engine. `cargo bench --bench e2e`.

use std::time::Instant;

use freekv::config::{FreeKvParams, ModelConfig};
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::policies::latency::{simulate_request, Method, SimKnobs};
use freekv::runtime::Runtime;
use freekv::sim::{CostModel, DeviceProfile};

fn main() {
    println!("=== bench e2e: Fig. 7 grid (A100 profile, modeled) ===");
    for model in [ModelConfig::qwen25_7b(), ModelConfig::llama31_8b()] {
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), model.clone());
        for (scenario, input, output, knobs) in [
            ("long-input 32K->512", 32768usize, 512usize, SimKnobs::default()),
            ("long-gen 600->16K", 600, 16384, SimKnobs::long_generation()),
        ] {
            println!("--- {} {} ---", model.name, scenario);
            let steps = output.min(1024);
            let mut freekv_total = f64::MAX;
            let mut rows = Vec::new();
            for method in [
                Method::Razor,
                Method::RaaS,
                Method::ArkVale,
                Method::ShadowKv,
                Method::InfiniGen,
                Method::FreeKv,
            ] {
                let t0 = Instant::now();
                let r = simulate_request(method, &cm, 4, input, steps, &knobs);
                let total = r.prefill_secs + r.per_token() * output as f64;
                if method == Method::FreeKv {
                    freekv_total = total;
                }
                rows.push((method, total, t0.elapsed().as_secs_f64()));
            }
            for (method, total, sim_wall) in rows {
                println!(
                    "{:<10} b=4 modeled {:>8.2}s  ({:>5.2}x vs freekv)  [sim wall {:.2}s]",
                    method.name(),
                    total,
                    total / freekv_total,
                    sim_wall
                );
            }
        }
    }

    println!();
    println!("=== bench e2e: real tiny-model engine throughput ===");
    let Ok(rt) = Runtime::load("artifacts") else {
        println!("artifacts/ missing — run `make artifacts` (skipping real bench)");
        return;
    };
    let mut eng = Engine::new(rt, "tiny", FreeKvParams { tau: 0.9, ..Default::default() }).unwrap();
    let prompt: Vec<i32> = (0..480).map(|i| (i * 17 % 250) as i32).collect();
    for &batch in &[1usize, 4] {
        let mut seqs: Vec<_> = (0..batch)
            .map(|i| {
                eng.new_sequence(
                    i as u64,
                    prompt.clone(),
                    64,
                    SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 },
                )
            })
            .collect();
        for s in seqs.iter_mut() {
            let _ = eng.prefill(s).unwrap();
            s.tokens.push(1);
        }
        let steps = 48;
        let t0 = Instant::now();
        for _ in 0..steps {
            let mut batch_refs: Vec<&mut _> = seqs.iter_mut().collect();
            eng.decode_step(&mut batch_refs).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "real decode: batch={} {:>6.1} ms/step  {:>6.1} tok/s",
            batch,
            dt / steps as f64 * 1e3,
            (steps * batch) as f64 / dt
        );
    }
}
