//! Property tests (in-tree harness, util::proptest) over coordinator
//! invariants: KV-cache slot management, selection/page-table state,
//! transfer accounting, batching math, and the simulators.

use freekv::config::{FreeKvParams, ModelConfig, SelectVariant};
use freekv::kvcache::{GpuLayerCache, LayerPool, Layout, RequestKv};
use freekv::linalg;
use freekv::oracle::{generate, OracleParams, TaskKind, TaskSpec};
use freekv::policies::accuracy::{run_episode, AccBudget, AccKnobs};
use freekv::policies::freekv::{correction_check, select_scores};
use freekv::policies::latency::{simulate_request, Method, SimKnobs};
use freekv::sim::{CostModel, DeviceProfile, Stream, Timeline};
use freekv::transfer::TransferEngine;
use freekv::prop_assert;
use freekv::util::proptest::check;
use freekv::util::rng::Rng;

fn small_cfg(rng: &mut Rng) -> ModelConfig {
    let n_kv = [1, 2, 4][rng.below(3)];
    let g = [1, 2, 4][rng.below(3)];
    ModelConfig {
        name: "prop".into(),
        n_layers: 1 + rng.below(3),
        d_model: 32,
        n_qo: n_kv * g,
        n_kv,
        d_head: [4, 8][rng.below(2)],
        d_ffn: 64,
        vocab: 64,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        page_size: [2, 4, 8][rng.below(3)],
        max_context: 256,
        sink_pages: 1 + rng.below(2),
        window_pages: 1 + rng.below(3),
        select_pages: 1 + rng.below(6),
        kv_elem_bytes: 4,
    }
}

#[test]
fn gather_valid_count_equals_visible_tokens() {
    // Every appended token that is in sink/window/selected coverage must
    // appear exactly once per head; no token is ever double-counted.
    check("gather-valid-count", 40, |rng| {
        let cfg = small_cfg(rng);
        let mut gpu = GpuLayerCache::new(
            cfg.n_kv,
            cfg.d_head,
            cfg.page_size,
            cfg.sink_pages,
            cfg.window_pages,
            cfg.select_pages,
            cfg.n_pages_max(),
        );
        let n_tokens = 1 + rng.below(cfg.max_context - 1);
        for _ in 0..n_tokens {
            let k: Vec<f32> = (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            gpu.append(&k.clone(), &k);
        }
        let s = gpu.budget_slots();
        let mut sel = gpu.new_select_slots();
        let mut gk = vec![0.0; cfg.n_kv * s * cfg.d_head];
        let mut gv = gk.clone();
        let mut valid = vec![0.0; cfg.n_kv * s];
        gpu.gather_full(&mut sel, &mut gk, &mut gv, &mut valid);
        let per_head: f32 = valid[..s].iter().sum();
        // expected: sink tokens + window-resident tokens (no selection
        // applied). The ring holds the last `window_pages` pages that have
        // at least one token (the current page is only claimed once a
        // token lands in it).
        let last = (n_tokens - 1) / cfg.page_size;
        let mut expect = 0usize;
        for g in 0..=last {
            let in_sink = g < cfg.sink_pages;
            let in_ring = g + cfg.window_pages > last && g >= cfg.sink_pages;
            if in_sink || in_ring {
                expect += n_tokens.saturating_sub(g * cfg.page_size).min(cfg.page_size);
            }
        }
        prop_assert!(
            per_head as usize == expect,
            "visible {} expected {} (tokens {}, cfg {:?})",
            per_head,
            expect,
            n_tokens,
            (cfg.page_size, cfg.sink_pages, cfg.window_pages)
        );
        // all heads identical before selection
        for m in 1..cfg.n_kv {
            let vh: f32 = valid[m * s..(m + 1) * s].iter().sum();
            prop_assert!(vh == per_head, "head {} differs", m);
        }
        Ok(())
    });
}

#[test]
fn selection_page_table_no_duplicates_and_bounded() {
    check("selection-table", 40, |rng| {
        let cfg = small_cfg(rng);
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let tokens = cfg.page_size * (cfg.sink_pages + cfg.window_pages + 4 + rng.below(8));
        for _ in 0..tokens.min(cfg.max_context) {
            for l in 0..cfg.n_layers {
                let k: Vec<f32> =
                    (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(l, &k.clone(), &k, &mut eng);
            }
        }
        let mask = kv.layers[0].gpu.selectable_mask();
        let candidates: Vec<usize> =
            mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(i, _)| i).collect();
        if candidates.is_empty() {
            return Ok(());
        }
        for _round in 0..4 {
            let mut pages = candidates.clone();
            rng.shuffle(&mut pages);
            let take = 1 + rng.below(cfg.select_pages.min(pages.len()));
            let pages = &pages[..take];
            for head in 0..cfg.n_kv {
                kv.apply_selection(0, head, pages, &mut eng);
                let resident: Vec<usize> =
                    kv.layers[0].select().selected(head).iter().flatten().cloned().collect();
                // no duplicates
                let mut d = resident.clone();
                d.sort_unstable();
                d.dedup();
                prop_assert!(d.len() == resident.len(), "dup pages {:?}", resident);
                // bounded by slots
                prop_assert!(resident.len() <= cfg.select_pages, "overflow");
                // every requested page resident (fits by construction)
                for pg in pages {
                    prop_assert!(resident.contains(pg), "page {} missing", pg);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn reapplying_selection_is_free() {
    check("selection-idempotent", 25, |rng| {
        let cfg = small_cfg(rng);
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let tokens = cfg.page_size * (cfg.sink_pages + cfg.window_pages + 6);
        for _ in 0..tokens.min(cfg.max_context) {
            let k: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            kv.append(0, &k.clone(), &k, &mut eng);
        }
        let mask = kv.layers[0].gpu.selectable_mask();
        let pages: Vec<usize> = mask
            .iter()
            .enumerate()
            .filter(|(_, &x)| x > 0.0)
            .map(|(i, _)| i)
            .take(cfg.select_pages)
            .collect();
        if pages.is_empty() {
            return Ok(());
        }
        let first = kv.apply_selection(0, 0, &pages, &mut eng);
        prop_assert!(first == pages.len(), "first apply {} != {}", first, pages.len());
        let second = kv.apply_selection(0, 0, &pages, &mut eng);
        prop_assert!(second == 0, "idempotent apply recalled {}", second);
        Ok(())
    });
}

#[test]
fn pool_roundtrip_any_geometry() {
    check("pool-roundtrip", 40, |rng| {
        let (m, p, d) = (1 + rng.below(4), 1 + rng.below(8), 1 + rng.below(16));
        let pages = 2 + rng.below(6);
        let layout = if rng.below(2) == 0 { Layout::Hnd } else { Layout::Nhd };
        let mut pool = LayerPool::new(layout, pages, m, p, d);
        let k: Vec<f32> = (0..p * m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..p * m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let pg = rng.below(pages);
        pool.write_page(pg, &k, &v);
        for head in 0..m {
            let (kr, vr) = pool.read_page_head(pg, head);
            for tok in 0..p {
                for dim in 0..d {
                    let src = (tok * m + head) * d + dim;
                    prop_assert!(kr[tok * d + dim] == k[src], "k mismatch");
                    prop_assert!(vr[tok * d + dim] == v[src], "v mismatch");
                }
            }
            // chunk plan covers exactly the page bytes
            let total: usize = pool.recall_chunks(pg, head).iter().map(|c| c.len).sum();
            prop_assert!(total == 2 * p * d, "chunks cover {} != {}", total, 2 * p * d);
        }
        Ok(())
    });
}

#[test]
fn correction_monotone_in_tau() {
    check("correction-monotone", 50, |rng| {
        let n_kv = 1 + rng.below(4);
        let g = 1 + rng.below(4);
        let sims: Vec<f32> = (0..n_kv * g).map(|_| rng.f32()).collect();
        let mut prev = 0usize;
        for tau in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
            let p = FreeKvParams { tau, ..Default::default() };
            let d = correction_check(&sims, n_kv, &p);
            prop_assert!(
                d.corrected_heads.len() >= prev,
                "tau {} corrected {} < prev {}",
                tau,
                d.corrected_heads.len(),
                prev
            );
            prev = d.corrected_heads.len();
        }
        // max (min-sim) pooling triggers at least as often as mean
        let tau = 0.5f32;
        let mean = correction_check(&sims, n_kv, &FreeKvParams { tau, ..Default::default() });
        let maxp = correction_check(
            &sims,
            n_kv,
            &FreeKvParams { tau, correction_pool_max: true, ..Default::default() },
        );
        prop_assert!(
            maxp.corrected_heads.len() >= mean.corrected_heads.len(),
            "max pooling must be conservative"
        );
        Ok(())
    });
}

#[test]
fn rust_select_scores_rank_pages_with_aligned_summaries_first() {
    check("select-ranking", 30, |rng| {
        let (n_kv, g, d, p) = (2, 2, 8, 6);
        let q: Vec<f32> = (0..n_kv * g * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // summaries: page 0 = exact q direction per head-group mean
        let mut smin = vec![0.0f32; n_kv * p * d];
        let mut smax = vec![0.0f32; n_kv * p * d];
        for m in 0..n_kv {
            for pg in 0..p {
                for dim in 0..d {
                    let base = (m * p + pg) * d + dim;
                    let aligned = (0..g).map(|j| q[(m * g + j) * d + dim]).sum::<f32>() / g as f32;
                    let val = if pg == 0 { aligned * 3.0 } else { rng.normal_f32(0.0, 0.2) };
                    smin[base] = val - 0.05;
                    smax[base] = val + 0.05;
                }
            }
        }
        let mask = vec![1.0f32; p];
        // MaxQ is excluded: elementwise-max query pooling distorts the
        // direction (exactly the lossiness that makes it worst in the
        // paper's Table 5 ablation).
        for variant in [
            SelectVariant::MeanS,
            SelectVariant::MaxS,
            SelectVariant::MeanQK,
            SelectVariant::MaxQK,
            SelectVariant::MeanQ,
        ] {
            let scores = select_scores(&q, &smin, &smax, &mask, n_kv, n_kv * g, d, variant);
            for row in &scores {
                let top = linalg::top_k(row, 1)[0];
                prop_assert!(top == 0, "{:?} picked page {} over aligned page 0", variant, top);
            }
        }
        Ok(())
    });
}

#[test]
fn timeline_makespan_bounds() {
    check("timeline-bounds", 40, |rng| {
        let mut tl = Timeline::new();
        let streams = [Stream::Compute, Stream::H2D, Stream::D2H, Stream::Convert];
        let n = 5 + rng.below(40);
        let mut total_per_stream = std::collections::HashMap::new();
        let mut total = 0.0f64;
        let mut prev: Option<usize> = None;
        for i in 0..n {
            let s = streams[rng.below(4)];
            let dur = rng.f64() * 0.01;
            let deps: Vec<usize> = match (prev, rng.below(3)) {
                (Some(p), 0) => vec![p],
                _ => vec![],
            };
            let e = tl.schedule(s, &deps, dur, format!("op{}", i));
            prev = Some(e);
            *total_per_stream.entry(s).or_insert(0.0f64) += dur;
            total += dur;
        }
        let span = tl.makespan();
        let max_stream = total_per_stream.values().cloned().fold(0.0, f64::max);
        prop_assert!(span <= total + 1e-9, "span {} > serial {}", span, total);
        prop_assert!(span >= max_stream - 1e-9, "span {} < busiest stream {}", span, max_stream);
        // exposed never exceeds busy
        for pre in ["op", "recall"] {
            prop_assert!(
                tl.exposed(pre) <= tl.busy_labeled(pre) + 1e-9,
                "exposed > busy for {}",
                pre
            );
        }
        Ok(())
    });
}

#[test]
fn latency_sim_sane_for_all_methods() {
    check("latency-sane", 12, |rng| {
        let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
        let knobs = SimKnobs::default();
        let method = Method::all()[rng.below(9)];
        let input = 1024 * (1 + rng.below(8));
        let out = 4 + rng.below(16);
        let r = simulate_request(method, &cm, 1 + rng.below(4), input, out, &knobs);
        prop_assert!(r.decode_secs > 0.0 && r.decode_secs.is_finite(), "bad decode");
        prop_assert!(r.prefill_secs > 0.0, "bad prefill");
        prop_assert!(r.recall_exposed <= r.recall_busy + 1e-9, "exposed > busy");
        prop_assert!(r.per_token() < 10.0, "absurd per-token {}", r.per_token());
        Ok(())
    });
}

#[test]
fn accuracy_sim_scores_in_range_and_full_is_best() {
    check("accuracy-range", 8, |rng| {
        let kind = TaskKind::all()[rng.below(4)];
        let tr = generate(
            &TaskSpec::default_for(kind),
            8,
            2,
            &OracleParams::default(),
            rng.next_u64(),
        );
        let full = run_episode(
            Method::Full,
            SelectVariant::MeanS,
            &tr,
            &AccBudget::default(),
            &AccKnobs::default(),
            1,
        );
        prop_assert!(full.task_score > 0.99, "full not perfect: {}", full.task_score);
        for method in [Method::Streaming, Method::RaaS, Method::FreeKv, Method::Quest] {
            let r = run_episode(
                method,
                SelectVariant::MeanS,
                &tr,
                &AccBudget::default(),
                &AccKnobs::default(),
                2,
            );
            prop_assert!(
                (0.0..=1.0 + 1e-9).contains(&r.task_score),
                "{:?} score {}",
                method,
                r.task_score
            );
            prop_assert!(r.task_score <= full.task_score + 1e-6, "beats full");
            prop_assert!((0.0..=1.0).contains(&r.mass_recall), "mass {}", r.mass_recall);
        }
        Ok(())
    });
}
