//! Chaos and fault-injection tests for the serving stack's degradation
//! ladders, end to end over the engine loop + sim backend:
//!
//! * the chaos property — under a seeded random fault schedule
//!   ([`FaultPlan::chaos`]) every accepted request reaches **exactly
//!   one** terminal event (`Done` or `Error`), the loop never
//!   deadlocks, and the shared KV allocator's page/reservation gauges
//!   return to baseline afterwards;
//! * targeted ladders — an injected engine panic / engine-global decode
//!   error triggers a supervised restart that fails the in-flight
//!   sessions loudly and keeps serving (Degraded); an allocator-lock
//!   panic exercises poisoned-lock recovery on a live pool; an
//!   exhausted restart budget takes the loop Down and submitters see
//!   `Closed`;
//! * the zero-cost property — a present-but-disabled plan produces a
//!   bit-identical token stream to no plan at all;
//! * the router ladder — a replica that exhausts its restart budget is
//!   routed around (set Degraded, not Down), no request is silently
//!   lost, and every replica's KV gauges return to baseline on the
//!   surviving N-1; plus a set-level chaos round with an independent
//!   plan per replica.
//!
//! Seeds are fixed (CI runs the suite per-seed via `FREEKV_CHAOS_SEEDS`)
//! so failures are replayable.

use std::sync::Arc;
use std::time::{Duration, Instant};

use freekv::config::ModelConfig;
use freekv::coordinator::engine_loop::{
    EngineLoop, Health, LoopConfig, SessionEvent, SubmitError,
};
use freekv::coordinator::router::{KvAwareRouter, KvRouterConfig, Router};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
use freekv::coordinator::sim_backend::{sim_config, SimBackend};
use freekv::kvcache::PageAllocator;
use freekv::util::fault::{FaultPlan, FaultSite};

/// Spawn an engine loop whose (restartable) backend shares `alloc` and
/// `plan` across incarnations — the allocator so page gauges survive
/// restarts like the real engine's pool, the plan so fault-call indices
/// keep advancing instead of replaying the same faults forever.
fn spawn_chaos_loop(
    cfg: ModelConfig,
    alloc: Arc<PageAllocator>,
    plan: Arc<FaultPlan>,
    loop_cfg: LoopConfig,
) -> EngineLoop {
    EngineLoop::spawn(loop_cfg, move || {
        let mut b = SimBackend::with_allocator(cfg.clone(), alloc.clone());
        b.set_faults(plan.clone());
        let scfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
        Ok(Scheduler::new(b, scfg))
    })
    .expect("chaos loop spawns")
}

/// Drive a session to its terminal event with a bounded wait. Returns
/// `(tokens_seen, Ok(generated) | Err(error_msg))`; panics on a hang or
/// on a channel that closes without a terminal event (a silently lost
/// request — exactly what the supervisor must never produce).
fn collect_terminal(h: &freekv::coordinator::engine_loop::SessionHandle) -> (usize, Result<usize, String>) {
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut tokens = 0usize;
    let terminal = loop {
        assert!(Instant::now() < deadline, "session {} hung (deadlock)", h.id());
        match h.recv_timeout(Duration::from_secs(5)) {
            Ok(SessionEvent::Token { .. }) => tokens += 1,
            Ok(SessionEvent::Done(c)) => break Ok(c.generated_tokens),
            Ok(SessionEvent::Error(e)) => break Err(e),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                panic!("session {} channel closed with no terminal event", h.id())
            }
        }
    };
    // Exactly one terminal event: the loop closes the session on the
    // terminal send, so the channel must now be dead with nothing queued.
    match h.recv_timeout(Duration::from_millis(200)) {
        Err(_) => {}
        Ok(ev) => panic!("session {} got an event after its terminal: {:?}", h.id(), ev),
    }
    (tokens, terminal)
}

/// The chaos property for one seed: N requests against a seeded random
/// fault schedule; every accepted request terminates exactly once, the
/// loop stays answerable, and KV gauges return to baseline.
fn chaos_round(seed: u64) {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    let plan = Arc::new(FaultPlan::chaos(seed));
    let el = spawn_chaos_loop(
        cfg,
        alloc.clone(),
        plan.clone(),
        LoopConfig { queue_cap: 32, max_engine_restarts: 16 },
    );
    let sub = el.submitter();

    let mut handles = Vec::new();
    for i in 0..24usize {
        let prompt = format!("chaos request {} seed {} ", i, seed);
        match sub.submit_text(&prompt, 4 + (i % 8)) {
            Ok(h) => handles.push(h),
            // Busy/Draining/Closed are themselves terminal outcomes for
            // the caller — the request is refused, not lost. With a
            // 16-restart budget and cap 32 none should occur here.
            Err(e) => panic!("submit {} unexpectedly refused: {:?}", i, e),
        }
    }

    let (mut done, mut failed) = (0usize, 0usize);
    for h in &handles {
        match collect_terminal(h) {
            (_, Ok(_)) => done += 1,
            (_, Err(_)) => failed += 1,
        }
    }
    assert_eq!(done + failed, handles.len(), "every request reached one terminal event");
    assert_eq!(sub.in_flight(), 0, "all admission slots released");

    // The loop is still answering metrics queries and reporting health.
    let report = sub.metrics_report().expect("loop still answers after chaos");
    assert!(report.contains("health="), "{}", report);
    assert!(
        matches!(sub.health(), Health::Ok | Health::Degraded),
        "budget not exhausted, yet health = {:?}",
        sub.health()
    );
    if plan.fired(FaultSite::EnginePanic) + plan.fired(FaultSite::DecodeError) > 0 {
        // At least one engine-global fault actually fired mid-tick
        // whenever any request saw it; restarts only happen then.
        assert!(failed > 0 || sub.engine_restarts() == 0);
    }

    el.shutdown();
    let kv = alloc.stats();
    assert_eq!(kv.pages_used, 0, "seed {}: leaked pages: {:?}", seed, kv);
    assert_eq!(kv.pages_reserved, 0, "seed {}: leaked reservations: {:?}", seed, kv);
    // The full cross-lock invariant set must hold after recovery — the
    // AllocPanic site alternates between poisoning the metadata lock
    // and a seed-chosen shard lock, so any seed that fired it has
    // exercised poisoned-shard recovery too.
    alloc.audit_invariants();
}

#[test]
fn chaos_no_request_is_silently_lost() {
    // CI's chaos matrix overrides the seed list; locally run the fixed
    // trio so a plain `cargo test` still covers distinct schedules.
    let seeds: Vec<u64> = match std::env::var("FREEKV_CHAOS_SEEDS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => vec![11, 23, 47],
    };
    assert!(!seeds.is_empty(), "FREEKV_CHAOS_SEEDS parsed to nothing");
    for seed in seeds {
        chaos_round(seed);
    }
}

#[test]
fn engine_panic_restarts_supervised_and_keeps_serving() {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    // Panic on the third decode step: the victim request is mid-flight.
    let plan = Arc::new(FaultPlan::events(&[(FaultSite::EnginePanic, 2)]));
    let el = spawn_chaos_loop(cfg, alloc.clone(), plan, LoopConfig::default());
    let sub = el.submitter();

    let victim = sub.submit_text("doomed request ", 200).unwrap();
    let (_, outcome) = collect_terminal(&victim);
    let err = outcome.expect_err("victim must fail loudly, not complete");
    assert!(err.contains("panicked"), "terminal error names the cause: {}", err);
    assert!(err.contains("injected engine panic"), "{}", err);

    // The supervisor rebuilt the engine: a fresh request completes.
    let again = sub.submit_text("post-restart request ", 6).unwrap();
    let (tokens, outcome) = collect_terminal(&again);
    assert_eq!(outcome.expect("restarted engine serves"), 6);
    assert_eq!(tokens, 6);

    assert_eq!(sub.engine_restarts(), 1);
    assert_eq!(sub.health(), Health::Degraded, "a restarted engine reports degraded");
    let report = sub.metrics_report().unwrap();
    assert!(report.contains("engine_restarts=1"), "{}", report);
    assert!(report.contains("health=degraded"), "{}", report);
    assert!(report.contains("failed=1"), "victim counted failed: {}", report);

    el.shutdown();
    let kv = alloc.stats();
    assert_eq!((kv.pages_used, kv.pages_reserved), (0, 0), "{:?}", kv);
}

#[test]
fn engine_global_decode_error_walks_the_same_ladder() {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    let plan = Arc::new(FaultPlan::events(&[(FaultSite::DecodeError, 1)]));
    let el = spawn_chaos_loop(cfg, alloc.clone(), plan, LoopConfig::default());
    let sub = el.submitter();

    let victim = sub.submit_text("hits the decode error ", 100).unwrap();
    let (_, outcome) = collect_terminal(&victim);
    let err = outcome.expect_err("engine-global error fails the request");
    assert!(err.contains("injected engine-global decode error"), "{}", err);

    let again = sub.submit_text("recovers ", 5).unwrap();
    assert_eq!(collect_terminal(&again).1.expect("loop recovered"), 5);
    assert_eq!(sub.engine_restarts(), 1);

    el.shutdown();
    assert_eq!(alloc.stats().pages_used, 0);
}

#[test]
fn alloc_lock_panic_recovers_and_pool_stays_usable() {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    // Panic *while holding an allocator lock* on the second decode
    // step: the restart teardown and every later request must recover
    // the poisoned mutex (`lock_timed`'s `into_inner` path, audited by
    // the per-lock `poison_audit`) on the same live pool.
    let plan = Arc::new(FaultPlan::events(&[(FaultSite::AllocPanic, 1)]));
    let el = spawn_chaos_loop(cfg, alloc.clone(), plan, LoopConfig::default());
    let sub = el.submitter();

    let victim = sub.submit_text("poisons the allocator ", 50).unwrap();
    let (_, outcome) = collect_terminal(&victim);
    assert!(outcome.is_err(), "victim fails when the lock-holder panics");

    // The same allocator — poisoned mutex and all — serves new requests.
    let again = sub.submit_text("allocates after the poison ", 6).unwrap();
    assert_eq!(collect_terminal(&again).1.expect("pool usable after poison"), 6);

    el.shutdown();
    let kv = alloc.stats();
    assert_eq!((kv.pages_used, kv.pages_reserved), (0, 0), "{:?}", kv);
    alloc.audit_invariants();
}

#[test]
fn alloc_poison_covers_meta_and_shard_locks() {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    // Two lock-holder panics. The AllocPanic site picks its target from
    // the post-increment injected counter: the first firing (n=1, odd)
    // poisons a *shard* lock, the second (n=2, even) the *metadata*
    // lock — so this single schedule walks both recovery paths on one
    // live allocator. The second fire index leaves the first victim
    // enough decode steps to die and the supervisor to restart.
    let plan = Arc::new(FaultPlan::events(&[
        (FaultSite::AllocPanic, 1),
        (FaultSite::AllocPanic, 8),
    ]));
    let el = spawn_chaos_loop(
        cfg,
        alloc.clone(),
        plan.clone(),
        LoopConfig { queue_cap: 8, max_engine_restarts: 8 },
    );
    let sub = el.submitter();

    let first = sub.submit_text("poisons a shard lock ", 50).unwrap();
    assert!(collect_terminal(&first).1.is_err(), "shard-poison victim fails loudly");
    let second = sub.submit_text("poisons the metadata lock ", 50).unwrap();
    assert!(collect_terminal(&second).1.is_err(), "meta-poison victim fails loudly");
    assert_eq!(plan.fired(FaultSite::AllocPanic), 2, "both scheduled faults fired");

    // Both poisoned mutexes recovered: the same pool keeps serving.
    let again = sub.submit_text("after both poisons ", 6).unwrap();
    assert_eq!(collect_terminal(&again).1.expect("pool usable"), 6);

    el.shutdown();
    let kv = alloc.stats();
    assert_eq!((kv.pages_used, kv.pages_reserved), (0, 0), "{:?}", kv);
    alloc.audit_invariants();
}

#[test]
fn restart_budget_exhaustion_goes_down_and_closed() {
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, false);
    let plan = Arc::new(FaultPlan::events(&[(FaultSite::EnginePanic, 0)]));
    let el = spawn_chaos_loop(
        cfg,
        alloc.clone(),
        plan,
        LoopConfig { queue_cap: 4, max_engine_restarts: 0 },
    );
    let sub = el.submitter();

    let victim = sub.submit_text("no budget to restart for me ", 50).unwrap();
    let (_, outcome) = collect_terminal(&victim);
    assert!(outcome.is_err(), "in-flight request failed, not stranded");

    // Budget 0: the loop exits instead of rebuilding. Down is published
    // by the supervisor on its way out; give the thread a moment.
    let deadline = Instant::now() + Duration::from_secs(5);
    while sub.health() != Health::Down {
        assert!(Instant::now() < deadline, "loop never reported Down");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(matches!(sub.submit_text("too late ", 2), Err(SubmitError::Closed)));
    assert!(sub.metrics_report().is_err(), "metrics channel closed once down");

    // Even the unhealthy exit releases every page and reservation.
    let kv = alloc.stats();
    assert_eq!((kv.pages_used, kv.pages_reserved), (0, 0), "{:?}", kv);
    el.shutdown();
}

#[test]
fn router_routes_around_dead_replica_and_reports_degraded() {
    let cfg = sim_config();
    // replica0: a panic on its first decode step and zero restart budget
    // — the fault ladder bottoms out and the loop goes Down. replica1:
    // clean. Independent allocators, like the real ReplicaSet.
    let alloc0 = PageAllocator::for_model(&cfg, 0, false);
    let plan0 = Arc::new(FaultPlan::events(&[(FaultSite::EnginePanic, 0)]));
    let el0 = spawn_chaos_loop(
        cfg.clone(),
        alloc0.clone(),
        plan0,
        LoopConfig { queue_cap: 4, max_engine_restarts: 0 },
    );
    let alloc1 = PageAllocator::for_model(&cfg, 0, false);
    let el1 = spawn_chaos_loop(
        cfg.clone(),
        alloc1.clone(),
        Arc::new(FaultPlan::disabled()),
        LoopConfig { queue_cap: 4, max_engine_restarts: 0 },
    );
    let (sub0, sub1) = (el0.submitter(), el1.submitter());
    let router = KvAwareRouter::new(
        vec![sub0.clone(), sub1.clone()],
        KvRouterConfig { page_size: cfg.page_size, ..Default::default() },
    );

    // Both replicas idle: the first dispatch tie-breaks to replica0,
    // where the victim dies loudly — a terminal Error, never silence.
    let victim = router.submit(Request::from_text(0, "victim on replica0 ", 50)).unwrap();
    assert!(collect_terminal(&victim).1.is_err(), "victim fails loudly, not silently");

    // replica0 exits Down; the set aggregate is Degraded, not Down.
    let deadline = Instant::now() + Duration::from_secs(5);
    while sub0.health() != Health::Down {
        assert!(Instant::now() < deadline, "replica0 never reported Down");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(router.health(), Health::Degraded, "one dead replica degrades the set");
    let report = router.metrics_report().expect("surviving replica keeps metrics up");
    assert!(report.contains("alive=1"), "{}", report);
    assert!(report.contains("replica0 health=down"), "{}", report);

    // New requests route around the corpse and complete on replica1.
    for i in 0..4 {
        let h = router
            .submit(Request::from_text(0, &format!("route around {} ", i), 4))
            .expect("degraded set still admits");
        assert_eq!(collect_terminal(&h).1.expect("replica1 serves"), 4);
    }
    assert_eq!(sub1.health(), Health::Ok, "the survivor itself is unharmed");

    // Over HTTP the aggregate shows on /healthz as 200 "degraded" — a
    // load balancer must not kill an instance that still serves.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let r2 = router.clone();
    std::thread::spawn(move || {
        let _ = freekv::server::serve_listener(
            listener,
            r2,
            freekv::server::ServeOptions::default(),
        );
    });
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    {
        use std::io::{Read as _, Write as _};
        write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "{}", resp);
        assert!(resp.ends_with("degraded"), "{}", resp);
    }

    el1.shutdown();
    el0.shutdown();
    // Even the dead replica's pool drained: no page or reservation leak
    // anywhere in the set, and the cross-lock invariants hold on N-1.
    for (name, alloc) in [("replica0", &alloc0), ("replica1", &alloc1)] {
        let kv = alloc.stats();
        assert_eq!((kv.pages_used, kv.pages_reserved), (0, 0), "{}: {:?}", name, kv);
        alloc.audit_invariants();
    }
}

/// The chaos property lifted to the replica set: two replicas, each with
/// its own seeded plan, behind one kv-aware router. Every accepted
/// request reaches exactly one terminal event and every allocator
/// returns to baseline.
fn router_chaos_round(seed: u64) {
    let cfg = sim_config();
    let mut loops = Vec::new();
    let mut allocs = Vec::new();
    let mut subs = Vec::new();
    for i in 0..2u64 {
        let alloc = PageAllocator::for_model(&cfg, 0, false);
        let plan = Arc::new(FaultPlan::chaos(seed + i));
        let el = spawn_chaos_loop(
            cfg.clone(),
            alloc.clone(),
            plan,
            LoopConfig { queue_cap: 32, max_engine_restarts: 16 },
        );
        subs.push(el.submitter());
        allocs.push(alloc);
        loops.push(el);
    }
    let router = KvAwareRouter::new(
        subs.clone(),
        KvRouterConfig { page_size: cfg.page_size, ..Default::default() },
    );

    let mut handles = Vec::new();
    for i in 0..24usize {
        // A shared prompt head keeps prefix affinity engaged mid-chaos.
        let prompt = format!("router chaos shared head, seed {} request {} ", seed, i);
        match router.submit(Request::from_text(0, &prompt, 4 + (i % 8))) {
            Ok(h) => handles.push(h),
            Err(e) => panic!("submit {} unexpectedly refused: {:?}", i, e),
        }
    }
    let (mut done, mut failed) = (0usize, 0usize);
    for h in &handles {
        match collect_terminal(h) {
            (_, Ok(_)) => done += 1,
            (_, Err(_)) => failed += 1,
        }
    }
    assert_eq!(done + failed, handles.len(), "every request reached one terminal event");
    assert_eq!(router.in_flight(), 0, "all admission slots released across the set");
    assert!(
        matches!(router.health(), Health::Ok | Health::Degraded),
        "budgets not exhausted, yet set health = {:?}",
        router.health()
    );
    let report = router.metrics_report().expect("set still answers after chaos");
    assert!(report.starts_with("router=kv replicas=2"), "{}", report);

    for el in loops {
        el.shutdown();
    }
    for (i, alloc) in allocs.iter().enumerate() {
        let kv = alloc.stats();
        assert_eq!(kv.pages_used, 0, "seed {} replica {}: leaked pages: {:?}", seed, i, kv);
        assert_eq!(
            kv.pages_reserved, 0,
            "seed {} replica {}: leaked reservations: {:?}",
            seed, i, kv
        );
        alloc.audit_invariants();
    }
}

#[test]
fn router_chaos_no_request_is_silently_lost() {
    let seeds: Vec<u64> = match std::env::var("FREEKV_CHAOS_SEEDS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => vec![11, 23, 47],
    };
    assert!(!seeds.is_empty(), "FREEKV_CHAOS_SEEDS parsed to nothing");
    for seed in seeds {
        router_chaos_round(seed);
    }
}

#[test]
fn disabled_plan_is_bit_identical_to_no_plan() {
    let run = |with_disabled_plan: bool| -> String {
        let cfg = sim_config();
        let alloc = PageAllocator::for_model(&cfg, 0, false);
        let el = EngineLoop::spawn(LoopConfig::default(), move || {
            let mut b = SimBackend::with_allocator(cfg.clone(), alloc.clone());
            if with_disabled_plan {
                b.set_faults(Arc::new(FaultPlan::disabled()));
            }
            Ok(Scheduler::new(
                b,
                SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() },
            ))
        })
        .expect("loop spawns");
        let sub = el.submitter();
        let c = sub.submit_text("determinism probe ", 24).unwrap().wait().unwrap();
        let stats = sub.engine_stats().unwrap();
        assert_eq!(stats.faults_injected, 0);
        el.shutdown();
        c.text
    };
    assert_eq!(run(false), run(true), "a disabled plan changed the token stream");
}
