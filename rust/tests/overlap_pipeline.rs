//! Overlap-pipeline correctness: the background speculative-recall
//! worker must be a pure scheduling change — identical select-table
//! state, gathered tensors, transfer counters, and (on the real engine)
//! bit-identical generated tokens vs serial in-thread dispatch.

use freekv::config::{FreeKvParams, ModelConfig};
use freekv::coordinator::engine::{Engine, SampleParams, Sequence};
use freekv::kvcache::{KvDtype, KvLockMode, Layout, PageAllocator, PrefixCacheMode, RequestKv};
use freekv::transfer::{RecallJob, RecallPipeline, TransferEngine};
use freekv::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        n_layers: 3,
        d_model: 16,
        n_qo: 4,
        n_kv: 2,
        d_head: 4,
        d_ffn: 32,
        vocab: 16,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        page_size: 4,
        max_context: 128,
        sink_pages: 1,
        window_pages: 2,
        select_pages: 2,
        kv_elem_bytes: 4,
    }
}

/// Fill every layer of a RequestKv with the same deterministic stream.
fn fill(kv: &mut RequestKv, cfg: &ModelConfig, eng: &mut TransferEngine, tokens: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    for _ in 0..tokens {
        for l in 0..cfg.n_layers {
            let k: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            kv.append(l, &k, &v, &mut *eng);
        }
    }
}

#[test]
fn worker_recall_equals_inline_recall_on_request_kv() {
    worker_vs_inline(KvDtype::F32);
}

#[test]
fn worker_recall_equals_inline_recall_on_quantized_pools() {
    // Quantization happens at the pool boundary (encode on offload,
    // decode on gather) and is deterministic, so the background worker
    // must still be byte-for-byte equivalent to inline dispatch on
    // int8/int4 pools — both sides read back the same quantized values.
    worker_vs_inline(KvDtype::Int8);
    worker_vs_inline(KvDtype::Int4);
}

fn worker_vs_inline(dtype: KvDtype) {
    let cfg = tiny_cfg();
    let mut a = RequestKv::with_alloc(
        &cfg,
        Layout::Hnd,
        PageAllocator::for_model_dtype(&cfg, 0, false, dtype),
    );
    let mut b = RequestKv::with_alloc(
        &cfg,
        Layout::Hnd,
        PageAllocator::for_model_dtype(&cfg, 0, false, dtype),
    );
    let mut eng_a = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut eng_b = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut a, &cfg, &mut eng_a, 40, 77);
    fill(&mut b, &cfg, &mut eng_b, 40, 77);

    // rotating selections over the selectable pages, per head
    let mask = a.layers[0].gpu.selectable_mask();
    let cands: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
    assert!(cands.len() >= 3, "need selectable pages, got {:?}", cands);
    let rounds: Vec<Vec<Vec<usize>>> = (0..4)
        .map(|r| {
            (0..cfg.n_kv)
                .map(|h| vec![cands[(r + h) % cands.len()], cands[(r + h + 1) % cands.len()]])
                .collect()
        })
        .collect();

    let mut pipe = RecallPipeline::new(cfg.page_size, cfg.d_head);
    for (round, sels) in rounds.iter().enumerate() {
        for l in 0..cfg.n_layers {
            // inline reference on `a`
            let mut inline_pages = 0;
            for (head, pages) in sels.iter().enumerate() {
                inline_pages += a.apply_selection(l, head, pages, &mut eng_a);
            }
            // worker path on `b`
            let xfer = b.layers[l].take_xfer();
            pipe.submit(RecallJob {
                seq_uid: 9,
                layer: l,
                selections: sels.clone(),
                xfer,
            });
            let done = pipe.wait(9, l);
            assert_eq!(done.recalled_pages, inline_pages, "round {} layer {}", round, l);
            eng_b.counters = eng_b.counters.merged(&done.counters);
            b.layers[l].put_xfer(done.xfer);
            for head in 0..cfg.n_kv {
                assert_eq!(
                    a.layers[l].select().selected(head),
                    b.layers[l].select().selected(head),
                    "round {} layer {} head {}",
                    round,
                    l,
                    head
                );
            }
        }
    }
    // aggregate transfer accounting identical
    assert_eq!(eng_a.counters.recalled_pages, eng_b.counters.recalled_pages);
    assert_eq!(eng_a.counters.h2d_chunks, eng_b.counters.h2d_chunks);
    assert_eq!(eng_a.counters.h2d_bytes, eng_b.counters.h2d_bytes);
    assert_eq!(eng_a.counters.h2d_encoded_bytes, eng_b.counters.h2d_encoded_bytes);
    assert_eq!(eng_a.counters.convert_bytes, eng_b.counters.convert_bytes);

    // gathered attention operands identical
    for l in 0..cfg.n_layers {
        let s = a.layers[l].gpu.budget_slots();
        let (m, d) = (cfg.n_kv, cfg.d_head);
        let mut ga = (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
        let mut gb = ga.clone();
        {
            let (gpu, x) = a.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
        }
        {
            let (gpu, x) = b.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
        }
        assert_eq!(ga.0, gb.0, "layer {} gathered K diverged", l);
        assert_eq!(ga.1, gb.1, "layer {} gathered V diverged", l);
        assert_eq!(ga.2, gb.2, "layer {} validity diverged", l);
    }
}

#[test]
fn global_and_sharded_lock_layouts_are_bit_identical() {
    // `--kv-lock` must be a pure synchronization change. The same
    // two-request shared-prefix workload (fill, cross-layer LCP
    // adoption, rotating selections, full gathers) through a
    // Global-lock allocator and a Sharded-lock allocator must produce
    // byte-identical gathered tensors, identical transfer accounting,
    // and identical non-timing pool gauges (pages peak, prefix hits,
    // bytes saved). Lock wait counters are timing-dependent and
    // deliberately excluded from the comparison. Runs per codec.
    for dtype in KvDtype::all() {
        let cfg = tiny_cfg();
        let run = |lock: KvLockMode| {
            let alloc = PageAllocator::with_mode_lock(
                cfg.n_layers,
                cfg.n_kv,
                cfg.page_size,
                cfg.d_head,
                0,
                PrefixCacheMode::Resident,
                0,
                0x51AB,
                dtype,
                lock,
            );
            let tokens: Vec<i32> = (0..40).map(|t| 32 + t % 90).collect();
            let fill_req = |eng: &mut TransferEngine, kv: &mut RequestKv| {
                let mut rng = Rng::new(77);
                for t in 0..tokens.len() {
                    kv.feed_tokens(&tokens[..t + 1]);
                    for l in 0..cfg.n_layers {
                        let k: Vec<f32> =
                            (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        let v: Vec<f32> =
                            (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        kv.append(l, &k, &v, eng);
                    }
                }
            };
            let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
            let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
            fill_req(&mut ea, &mut a);
            let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
            let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
            fill_req(&mut eb, &mut b);
            assert!(
                eb.counters.prefix_hits > 0,
                "{}/{}: second request must adopt the shared prefix",
                dtype,
                lock
            );
            let mask = a.layers[0].gpu.selectable_mask();
            let cands: Vec<usize> =
                mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
            assert!(cands.len() >= 2, "need selectable pages");
            let mut recalled = 0usize;
            for round in 0..3 {
                for l in 0..cfg.n_layers {
                    for head in 0..cfg.n_kv {
                        let pages = vec![cands[(round + head) % cands.len()]];
                        recalled += a.apply_selection(l, head, &pages, &mut ea);
                        recalled += b.apply_selection(l, head, &pages, &mut eb);
                    }
                }
            }
            let mut gathered: Vec<Vec<f32>> = Vec::new();
            for req in [&mut a, &mut b] {
                for l in 0..cfg.n_layers {
                    let s = req.layers[l].gpu.budget_slots();
                    let (m, d) = (cfg.n_kv, cfg.d_head);
                    let mut k = vec![0.0f32; m * s * d];
                    let mut v = vec![0.0f32; m * s * d];
                    let mut valid = vec![0.0f32; m * s];
                    let (gpu, x) = req.layers[l].parts_mut();
                    gpu.gather_full(&mut x.select, &mut k, &mut v, &mut valid);
                    gathered.push(k);
                    gathered.push(v);
                    gathered.push(valid);
                }
            }
            let st = alloc.stats();
            let counters = (
                ea.counters.h2d_chunks,
                ea.counters.h2d_bytes,
                eb.counters.h2d_chunks,
                eb.counters.h2d_encoded_bytes,
                eb.counters.prefix_hits,
                eb.counters.offloaded_pages,
            );
            drop(a);
            drop(b);
            assert_eq!(
                alloc.stats().pages_used,
                0,
                "{}/{}: pool must drain once both requests retire",
                dtype,
                lock
            );
            alloc.audit_invariants();
            (gathered, recalled, counters, (st.pages_peak, st.prefix_hits, st.bytes_saved))
        };
        let g = run(KvLockMode::Global);
        let s = run(KvLockMode::Sharded);
        assert_eq!(g.0, s.0, "{}: gathered tensors diverged across lock layouts", dtype);
        assert_eq!(g.1, s.1, "{}: recalled-page counts diverged", dtype);
        assert_eq!(g.2, s.2, "{}: transfer counters diverged", dtype);
        assert_eq!(g.3, s.3, "{}: non-timing pool gauges diverged", dtype);
    }
}

#[test]
fn int8_pool_diverges_from_f32_only_within_the_quantization_bound() {
    // Documented divergence: an int8 pool does NOT gather bit-identical
    // tensors to f32 — it gathers tensors within the codec's error bound
    // (half a quantization step plus the bf16 scale rounding, per
    // element). The validity plane and selection bookkeeping stay exact.
    let cfg = tiny_cfg();
    let mut a = RequestKv::new(&cfg, Layout::Hnd); // f32 reference
    let mut b = RequestKv::with_alloc(
        &cfg,
        Layout::Hnd,
        PageAllocator::for_model_dtype(&cfg, 0, false, KvDtype::Int8),
    );
    let mut eng_a = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut eng_b = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut a, &cfg, &mut eng_a, 40, 77);
    fill(&mut b, &cfg, &mut eng_b, 40, 77);
    let mask = a.layers[0].gpu.selectable_mask();
    let cands: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
    assert!(cands.len() >= 2);
    for l in 0..cfg.n_layers {
        for head in 0..cfg.n_kv {
            let pages = vec![cands[head % cands.len()], cands[(head + 1) % cands.len()]];
            let na = a.apply_selection(l, head, &pages, &mut eng_a);
            let nb = b.apply_selection(l, head, &pages, &mut eng_b);
            assert_eq!(na, nb, "selection bookkeeping must be dtype-independent");
        }
    }
    // quantized recall moves fewer bytes over the wire
    assert_eq!(eng_a.counters.h2d_bytes, eng_b.counters.h2d_bytes, "logical bytes match");
    assert!(
        eng_b.counters.h2d_encoded_bytes * 3 < eng_a.counters.h2d_encoded_bytes,
        "int8 wire bytes {} not under a third of f32 {}",
        eng_b.counters.h2d_encoded_bytes,
        eng_a.counters.h2d_encoded_bytes
    );
    let mut max_diff = 0.0f32;
    let mut max_abs = 0.0f32;
    for l in 0..cfg.n_layers {
        let s = a.layers[l].gpu.budget_slots();
        let (m, d) = (cfg.n_kv, cfg.d_head);
        let mut ga = (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
        let mut gb = ga.clone();
        {
            let (gpu, x) = a.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
        }
        {
            let (gpu, x) = b.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
        }
        assert_eq!(ga.2, gb.2, "layer {} validity plane must stay exact", l);
        for (x, y) in ga.0.iter().chain(ga.1.iter()).zip(gb.0.iter().chain(gb.1.iter())) {
            max_abs = max_abs.max(x.abs());
            max_diff = max_diff.max((x - y).abs());
        }
    }
    assert!(max_diff > 0.0, "int8 must actually quantize (bit-identity would be suspicious)");
    // per-element bound: scale/2 (rounding) + max_abs/256 (bf16 scale),
    // with scale <= region_max/127 <= max_abs/127.
    let bound = max_abs * (0.5 / 127.0) * 1.02 + max_abs / 256.0 + 1e-6;
    assert!(max_diff <= bound, "divergence {} exceeds quantization bound {}", max_diff, bound);
}

// ---------------------------------------------------------------------
// Real-engine equivalence (requires `make artifacts`; skips otherwise —
// unless FREEKV_REQUIRE_ARTIFACTS is set, in which case skipping fails).
// ---------------------------------------------------------------------

fn engine(overlap: bool, exec_workers: usize) -> Option<Engine> {
    engine_lanes(overlap, exec_workers, 2)
}

fn engine_lanes(overlap: bool, exec_workers: usize, max_lanes: usize) -> Option<Engine> {
    let rt = freekv::runtime::load_or_skip(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    Some(
        Engine::new(
            rt,
            "tiny",
            FreeKvParams { tau: 0.9, overlap, exec_workers, max_lanes, ..Default::default() },
        )
        .expect("engine constructs once the runtime loads"),
    )
}

/// Seeded multi-sequence batch decode past the GPU budget; returns
/// (per-seq generated tokens, engine counter tuple, per-seq xfer tuple).
#[allow(clippy::type_complexity)]
fn run_batch(overlap: bool, exec_workers: usize, steps: usize) -> Option<(Vec<Vec<i32>>, (u64, u64, u64, u64), Vec<(u64, u64, u64)>)> {
    let mut eng = engine(overlap, exec_workers)?;
    let mut seqs: Vec<Sequence> = (0..2)
        .map(|i| {
            let prompt: Vec<i32> = (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
            eng.new_sequence(
                i as u64,
                prompt,
                steps + 1,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    for _ in 0..steps {
        let mut batch: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut batch).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    let toks = seqs.iter().map(|s| s.generated().to_vec()).collect();
    let stats = (
        eng.stats.recalled_pages,
        eng.stats.corrections,
        eng.stats.correction_checks,
        eng.stats.speculative_hits,
    );
    let xfers = seqs
        .iter()
        .map(|s| {
            (
                s.xfer.counters.recalled_pages,
                s.xfer.counters.h2d_bytes,
                s.xfer.counters.offloaded_pages,
            )
        })
        .collect();
    Some((toks, stats, xfers))
}

#[test]
fn overlapped_engine_bit_identical_to_serial() {
    let (Some(serial), Some(overlapped)) = (run_batch(false, 0, 24), run_batch(true, 0, 24))
    else {
        eprintln!("artifacts/ missing — skipping real-engine overlap equivalence test");
        return;
    };
    assert_eq!(serial.0, overlapped.0, "generated tokens diverged between dispatch modes");
    assert_eq!(serial.1, overlapped.1, "recall/correction counters diverged");
    assert_eq!(serial.2, overlapped.2, "per-sequence transfer counters diverged");
    // sanity: the workload genuinely exercised recall + speculation
    assert!(serial.1 .0 > 0, "no pages recalled — test not exercising the pipeline");
    assert!(serial.1 .2 > 0, "no correction checks happened");
}

#[test]
fn pooled_dispatch_bit_identical_to_inline_dispatch() {
    // The executor pool is a pure scheduling change: selection scored on
    // a pool worker (recall overlap active in both runs) must leave
    // tokens, recall/correction counters, and per-sequence transfer
    // accounting exactly as inline execution does.
    let (Some(inline), Some(pooled)) = (run_batch(true, 0, 24), run_batch(true, 2, 24)) else {
        eprintln!("artifacts/ missing — skipping pooled-dispatch equivalence test");
        return;
    };
    assert_eq!(inline.0, pooled.0, "generated tokens diverged between dispatch modes");
    assert_eq!(inline.1, pooled.1, "recall/correction counters diverged");
    assert_eq!(inline.2, pooled.2, "per-sequence transfer counters diverged");
    assert!(inline.1 .0 > 0, "no pages recalled — test not exercising the pipeline");
}

/// Decode `n_seqs` seeded sequences for `steps` steps through
/// `decode_step_lanes`, feeding the engine a deliberately uneven caller
/// partition (alternating 2/3-wide lanes) — the engine re-plans it
/// bucket-aware. Returns per-seq tokens plus (lane_sets,
/// max_lanes_inflight) stats.
#[allow(clippy::type_complexity)]
fn run_lanes(
    exec_workers: usize,
    max_lanes: usize,
    n_seqs: usize,
    steps: usize,
) -> Option<(Vec<Vec<i32>>, (u64, u64))> {
    let mut eng = engine_lanes(true, exec_workers, max_lanes)?;
    let mut seqs: Vec<Sequence> = (0..n_seqs)
        .map(|i| {
            let prompt: Vec<i32> = (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
            eng.new_sequence(
                i as u64,
                prompt,
                steps + 1,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    for step in 0..steps {
        // uneven caller partition, varied per step: the engine must be
        // partition-agnostic
        let mut lanes: Vec<Vec<&mut Sequence>> = Vec::new();
        let mut it = seqs.iter_mut();
        let mut take = if step % 2 == 0 { 2 } else { 3 };
        loop {
            let lane: Vec<&mut Sequence> = it.by_ref().take(take).collect();
            if lane.is_empty() {
                break;
            }
            lanes.push(lane);
            take = if take == 2 { 3 } else { 2 };
        }
        eng.decode_step_lanes(&mut lanes).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    if exec_workers > 0 && max_lanes >= 2 {
        assert!(eng.stats.exec_jobs > 0, "pool not exercised");
    }
    let stats = (eng.stats.lane_sets, eng.stats.max_lanes_inflight);
    Some((seqs.iter().map(|s| s.generated().to_vec()).collect(), stats))
}

#[test]
fn lane_scheduler_bit_identical_across_lane_counts_and_dispatch_modes() {
    // Eleven sequences exceed two full buckets (cap 4), so the planner
    // runs three lanes (4/4/3 — genuinely uneven). The same workload
    // must produce identical tokens under serial dispatch, pooled
    // dispatch with concurrency 1, 2, 3, and 4 — lane scheduling is a
    // pure wall-clock change.
    let steps = 8;
    let Some((serial, _)) = run_lanes(0, 2, 11, steps) else {
        eprintln!("artifacts/ missing — skipping lane-scheduler equivalence test");
        return;
    };
    for max_lanes in 1..=4usize {
        let (pooled, (lane_sets, inflight)) =
            run_lanes(2, max_lanes, 11, steps).expect("backend available");
        assert_eq!(
            serial, pooled,
            "lane tokens diverged from serial dispatch at max_lanes={}",
            max_lanes
        );
        if max_lanes >= 2 {
            assert!(lane_sets > 0, "lane scheduler not exercised at max_lanes={}", max_lanes);
            assert_eq!(
                inflight,
                max_lanes.min(3) as u64,
                "concurrency should cap at min(max_lanes, planned lanes)"
            );
        }
    }
}

#[test]
fn lane_plan_merges_when_splitting_would_not_shrink_the_bucket() {
    // Two lanes of two sequences both pad to bucket 4 — identical to
    // the joint batch's bucket — so the planner must decode them as ONE
    // joint step instead of doubling artifact compute.
    let Some(mut eng) = engine(true, 2) else {
        eprintln!("artifacts/ missing — skipping lane-merge test");
        return;
    };
    let mut seqs: Vec<Sequence> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (0..120).map(|t| ((t * 11 + i * 5) % 250) as i32).collect();
            eng.new_sequence(i as u64, prompt, 4, SampleParams::greedy())
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    {
        let (front, back) = seqs.split_at_mut(2);
        let mut lanes: Vec<Vec<&mut Sequence>> = vec![
            front.iter_mut().collect(),
            back.iter_mut().collect(),
        ];
        eng.decode_step_lanes(&mut lanes).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    assert_eq!(eng.stats.lane_sets, 0, "same-bucket split must merge, not run lanes");
    assert_eq!(eng.stats.steps, 1, "merged lanes decode as one joint step");
    assert_eq!(eng.stats.max_batch_lanes, 4, "joint step carries all four lanes");
}

#[test]
fn weight_uploads_bounded_by_weight_workers_not_pool_size() {
    // Four pool workers, one designated weight worker (the default):
    // after multi-lane decode routes weight-bearing artifacts through
    // the pool, at most `weight_workers + 1` runtimes (engine thread +
    // weight workers) may ever have uploaded the blob — NOT one per
    // worker, which was the old `(workers + 1)x` memory cliff.
    let Some(mut eng) = engine_lanes(true, 4, 2) else {
        eprintln!("artifacts/ missing — skipping weight-upload bound test");
        return;
    };
    let mut seqs: Vec<Sequence> = (0..6)
        .map(|i| {
            let prompt: Vec<i32> = (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
            eng.new_sequence(i as u64, prompt, 8, SampleParams::greedy())
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    for _ in 0..4 {
        let (front, back) = seqs.split_at_mut(3);
        let mut lanes: Vec<Vec<&mut Sequence>> =
            vec![front.iter_mut().collect(), back.iter_mut().collect()];
        eng.decode_step_lanes(&mut lanes).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    assert!(eng.stats.lane_sets > 0, "lane path not exercised");
    assert!(eng.stats.weight_uploads >= 1, "no weight upload recorded at all");
    assert!(
        eng.stats.weight_uploads <= 2,
        "weight uploads {} exceed weight_workers + 1 = 2 (pool has 4 workers)",
        eng.stats.weight_uploads
    );
}

#[test]
fn chunked_prefill_overlaps_decode_and_matches_sync_prefill() {
    // A prefill begun while six sequences decode as two lanes must (a)
    // make progress on the pool during the decode steps (EngineStats
    // proof), and (b) produce exactly the logits the synchronous
    // prefill path computes — chunking is a pure scheduling change.
    let Some(mut eng) = engine_lanes(true, 2, 2) else {
        eprintln!("artifacts/ missing — skipping chunked-prefill overlap test");
        return;
    };
    let mut seqs: Vec<Sequence> = (0..6)
        .map(|i| {
            let prompt: Vec<i32> = (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
            eng.new_sequence(
                i as u64,
                prompt,
                64,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    // the newcomer's prompt, prefilled asynchronously under decode
    let late_prompt: Vec<i32> = (0..480).map(|t| ((t * 19 + 3) % 250) as i32).collect();
    let late = eng.new_sequence(99, late_prompt.clone(), 8, SampleParams::greedy());
    assert!(eng.prefill_begin(late).is_none(), "pooled engine prefills asynchronously");
    let mut async_done = None;
    for _ in 0..24 {
        {
            let mut lanes: Vec<Vec<&mut Sequence>> = Vec::new();
            let (front, back) = seqs.split_at_mut(3);
            lanes.push(front.iter_mut().collect());
            lanes.push(back.iter_mut().collect());
            eng.decode_step_lanes(&mut lanes).unwrap();
        }
        if async_done.is_none() {
            if let Some(done) = eng.prefill_poll().into_iter().next() {
                async_done = Some(done);
            }
        }
    }
    let done = match async_done {
        Some(d) => d,
        None => eng.prefill_wait().into_iter().next().expect("prefill completes"),
    };
    assert_eq!(done.seq.id, 99);
    let async_logits = done.result.expect("chunked prefill succeeds");
    assert!(
        eng.stats.prefill_overlap_chunks > 0,
        "no prefill chunk completed while decode lanes were in flight"
    );
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }

    // reference: synchronous prefill of the same prompt on a fresh engine
    let Some(mut reference) = engine_lanes(true, 0, 2) else { return };
    let mut ref_seq = reference.new_sequence(99, late_prompt, 8, SampleParams::greedy());
    let sync_logits = reference.prefill(&mut ref_seq).unwrap();
    assert_eq!(async_logits, sync_logits, "chunked prefill changed the logits");
}

#[test]
fn shared_pool_capacity_and_prefix_cache_keep_outputs_identical() {
    // The shared page allocator must be invisible to the data path:
    // (a) a capacity-bounded pool produces bit-identical tokens to the
    // unbounded default, and (b) with the prefix cache on, two requests
    // with the same prompt alias prompt pages (fewer distinct pool
    // pages, prefix hits > 0) while still producing identical tokens.
    let run = |kv_pool_pages: usize, prefix_cache: bool| -> Option<(Vec<Vec<i32>>, u64, u64)> {
        let rt = freekv::runtime::load_or_skip(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
        let params = FreeKvParams {
            tau: 0.9,
            overlap: true,
            exec_workers: 2,
            kv_pool_pages,
            prefix_cache: if prefix_cache {
                freekv::kvcache::PrefixCacheMode::Resident
            } else {
                freekv::kvcache::PrefixCacheMode::Off
            },
            ..Default::default()
        };
        let mut eng = Engine::new(rt, "tiny", params).expect("engine constructs");
        let steps = 12usize;
        // identical prompts so the prefix cache has something to share
        let prompt: Vec<i32> = (0..600usize).map(|t| ((t * 13) % 250) as i32).collect();
        let mut seqs: Vec<Sequence> = (0..2)
            .map(|i| {
                eng.new_sequence(
                    i as u64,
                    prompt.clone(),
                    steps + 1,
                    SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
                )
            })
            .collect();
        for s in seqs.iter_mut() {
            let lg = eng.prefill(s).unwrap();
            let tok =
                freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
            s.tokens.push(tok);
        }
        for _ in 0..steps {
            let mut batch: Vec<&mut Sequence> = seqs.iter_mut().collect();
            eng.decode_step(&mut batch).unwrap();
        }
        for s in seqs.iter_mut() {
            eng.drain_sequence(s);
        }
        let st = eng.kv_pool_stats();
        let toks = seqs.iter().map(|s| s.generated().to_vec()).collect();
        Some((toks, st.pages_used, st.prefix_hits))
    };
    let Some((base, _, _)) = run(0, false) else {
        eprintln!("artifacts/ missing — skipping shared-pool equivalence test");
        return;
    };
    let (capped, capped_used, capped_hits) = run(4096, false).expect("backend available");
    assert_eq!(base, capped, "a capacity-bounded pool changed decode outputs");
    assert_eq!(capped_hits, 0, "sharing off must never alias pages");
    let (shared, shared_used, hits) = run(0, true).expect("backend available");
    assert_eq!(base, shared, "prefix sharing changed decode outputs");
    assert!(hits > 0, "identical prompts must share prefix pages");
    assert!(
        shared_used < capped_used,
        "sharing must reduce distinct pool pages ({} vs {})",
        shared_used,
        capped_used
    );
}

#[test]
fn overlapped_engine_matches_blocking_when_budget_covers_context() {
    // With the whole context resident, speculation cannot lose pages, so
    // blocking and overlapped speculative decode must produce identical
    // tokens (the seed's guarantee, now with the worker in the loop).
    let Some(mut eng) = engine(true, 2) else {
        eprintln!("artifacts/ missing — skipping");
        return;
    };
    let prompt: Vec<i32> = (0..48).map(|i| (i * 7 % 250) as i32).collect();
    let run = |eng: &mut Engine, blocking: bool| -> Vec<i32> {
        eng.blocking_mode = blocking;
        let mut seq = eng.new_sequence(3, prompt.clone(), 6, SampleParams::greedy());
        eng.generate(&mut seq).unwrap();
        eng.drain_sequence(&mut seq);
        seq.generated().to_vec()
    };
    let spec = run(&mut eng, false);
    let Some(mut eng2) = engine(true, 2) else { return };
    let block = run(&mut eng2, true);
    assert_eq!(spec, block);
}
