//! Overlap-pipeline correctness: the background speculative-recall
//! worker must be a pure scheduling change — identical select-table
//! state, gathered tensors, transfer counters, and (on the real engine)
//! bit-identical generated tokens vs serial in-thread dispatch.

use freekv::config::{FreeKvParams, ModelConfig};
use freekv::coordinator::engine::{Engine, SampleParams, Sequence};
use freekv::kvcache::{Layout, RequestKv};
use freekv::transfer::{RecallJob, RecallPipeline, TransferEngine};
use freekv::util::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "t".into(),
        n_layers: 3,
        d_model: 16,
        n_qo: 4,
        n_kv: 2,
        d_head: 4,
        d_ffn: 32,
        vocab: 16,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        page_size: 4,
        max_context: 128,
        sink_pages: 1,
        window_pages: 2,
        select_pages: 2,
        kv_elem_bytes: 4,
    }
}

/// Fill every layer of a RequestKv with the same deterministic stream.
fn fill(kv: &mut RequestKv, cfg: &ModelConfig, eng: &mut TransferEngine, tokens: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    for _ in 0..tokens {
        for l in 0..cfg.n_layers {
            let k: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            kv.append(l, &k, &v, &mut *eng);
        }
    }
}

#[test]
fn worker_recall_equals_inline_recall_on_request_kv() {
    let cfg = tiny_cfg();
    let (mut a, mut b) = (RequestKv::new(&cfg, Layout::Hnd), RequestKv::new(&cfg, Layout::Hnd));
    let mut eng_a = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut eng_b = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut a, &cfg, &mut eng_a, 40, 77);
    fill(&mut b, &cfg, &mut eng_b, 40, 77);

    // rotating selections over the selectable pages, per head
    let mask = a.layers[0].gpu.selectable_mask();
    let cands: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
    assert!(cands.len() >= 3, "need selectable pages, got {:?}", cands);
    let rounds: Vec<Vec<Vec<usize>>> = (0..4)
        .map(|r| {
            (0..cfg.n_kv)
                .map(|h| vec![cands[(r + h) % cands.len()], cands[(r + h + 1) % cands.len()]])
                .collect()
        })
        .collect();

    let mut pipe = RecallPipeline::new(cfg.page_size, cfg.d_head);
    for (round, sels) in rounds.iter().enumerate() {
        for l in 0..cfg.n_layers {
            // inline reference on `a`
            let mut inline_pages = 0;
            for (head, pages) in sels.iter().enumerate() {
                inline_pages += a.apply_selection(l, head, pages, &mut eng_a);
            }
            // worker path on `b`
            let xfer = b.layers[l].take_xfer();
            pipe.submit(RecallJob {
                seq_uid: 9,
                layer: l,
                selections: sels.clone(),
                xfer,
            });
            let done = pipe.wait(9, l);
            assert_eq!(done.recalled_pages, inline_pages, "round {} layer {}", round, l);
            eng_b.counters = eng_b.counters.merged(&done.counters);
            b.layers[l].put_xfer(done.xfer);
            for head in 0..cfg.n_kv {
                assert_eq!(
                    a.layers[l].select().selected(head),
                    b.layers[l].select().selected(head),
                    "round {} layer {} head {}",
                    round,
                    l,
                    head
                );
            }
        }
    }
    // aggregate transfer accounting identical
    assert_eq!(eng_a.counters.recalled_pages, eng_b.counters.recalled_pages);
    assert_eq!(eng_a.counters.h2d_chunks, eng_b.counters.h2d_chunks);
    assert_eq!(eng_a.counters.h2d_bytes, eng_b.counters.h2d_bytes);
    assert_eq!(eng_a.counters.convert_bytes, eng_b.counters.convert_bytes);

    // gathered attention operands identical
    for l in 0..cfg.n_layers {
        let s = a.layers[l].gpu.budget_slots();
        let (m, d) = (cfg.n_kv, cfg.d_head);
        let mut ga = (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
        let mut gb = ga.clone();
        {
            let (gpu, x) = a.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
        }
        {
            let (gpu, x) = b.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
        }
        assert_eq!(ga.0, gb.0, "layer {} gathered K diverged", l);
        assert_eq!(ga.1, gb.1, "layer {} gathered V diverged", l);
        assert_eq!(ga.2, gb.2, "layer {} validity diverged", l);
    }
}

// ---------------------------------------------------------------------
// Real-engine equivalence (requires `make artifacts`; skips otherwise —
// unless FREEKV_REQUIRE_ARTIFACTS is set, in which case skipping fails).
// ---------------------------------------------------------------------

fn engine(overlap: bool, exec_workers: usize) -> Option<Engine> {
    let rt = freekv::runtime::load_or_skip(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    Some(
        Engine::new(rt, "tiny", FreeKvParams { tau: 0.9, overlap, exec_workers, ..Default::default() })
            .expect("engine constructs once the runtime loads"),
    )
}

/// Seeded multi-sequence batch decode past the GPU budget; returns
/// (per-seq generated tokens, engine counter tuple, per-seq xfer tuple).
#[allow(clippy::type_complexity)]
fn run_batch(overlap: bool, exec_workers: usize, steps: usize) -> Option<(Vec<Vec<i32>>, (u64, u64, u64, u64), Vec<(u64, u64, u64)>)> {
    let mut eng = engine(overlap, exec_workers)?;
    let mut seqs: Vec<Sequence> = (0..2)
        .map(|i| {
            let prompt: Vec<i32> = (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
            eng.new_sequence(
                i as u64,
                prompt,
                steps + 1,
                SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
            )
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    for _ in 0..steps {
        let mut batch: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut batch).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    let toks = seqs.iter().map(|s| s.generated().to_vec()).collect();
    let stats = (
        eng.stats.recalled_pages,
        eng.stats.corrections,
        eng.stats.correction_checks,
        eng.stats.speculative_hits,
    );
    let xfers = seqs
        .iter()
        .map(|s| {
            (
                s.xfer.counters.recalled_pages,
                s.xfer.counters.h2d_bytes,
                s.xfer.counters.offloaded_pages,
            )
        })
        .collect();
    Some((toks, stats, xfers))
}

#[test]
fn overlapped_engine_bit_identical_to_serial() {
    let (Some(serial), Some(overlapped)) = (run_batch(false, 0, 24), run_batch(true, 0, 24))
    else {
        eprintln!("artifacts/ missing — skipping real-engine overlap equivalence test");
        return;
    };
    assert_eq!(serial.0, overlapped.0, "generated tokens diverged between dispatch modes");
    assert_eq!(serial.1, overlapped.1, "recall/correction counters diverged");
    assert_eq!(serial.2, overlapped.2, "per-sequence transfer counters diverged");
    // sanity: the workload genuinely exercised recall + speculation
    assert!(serial.1 .0 > 0, "no pages recalled — test not exercising the pipeline");
    assert!(serial.1 .2 > 0, "no correction checks happened");
}

#[test]
fn pooled_dispatch_bit_identical_to_inline_dispatch() {
    // The executor pool is a pure scheduling change: selection scored on
    // a pool worker (recall overlap active in both runs) must leave
    // tokens, recall/correction counters, and per-sequence transfer
    // accounting exactly as inline execution does.
    let (Some(inline), Some(pooled)) = (run_batch(true, 0, 24), run_batch(true, 2, 24)) else {
        eprintln!("artifacts/ missing — skipping pooled-dispatch equivalence test");
        return;
    };
    assert_eq!(inline.0, pooled.0, "generated tokens diverged between dispatch modes");
    assert_eq!(inline.1, pooled.1, "recall/correction counters diverged");
    assert_eq!(inline.2, pooled.2, "per-sequence transfer counters diverged");
    assert!(inline.1 .0 > 0, "no pages recalled — test not exercising the pipeline");
}

#[test]
fn microbatch_pair_bit_identical_across_dispatch_modes() {
    // Six sequences split 3/3: the joint batch exceeds the largest
    // compiled decode bucket (4), so the pair path genuinely runs two
    // bucket-4 lanes — this is the configuration where microbatching
    // extends the servable batch size. Pipelined (pooled) and
    // sequential (serial) dispatch must produce identical outputs.
    let run_pair = |exec_workers: usize, steps: usize| -> Option<Vec<Vec<i32>>> {
        let mut eng = engine(true, exec_workers)?;
        let mut seqs: Vec<Sequence> = (0..6)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..600).map(|t| ((t * 13 + i * 7) % 250) as i32).collect();
                eng.new_sequence(
                    i as u64,
                    prompt,
                    steps + 1,
                    SampleParams { temperature: 0.8, top_p: 0.95, seed: 11 + i as u64 },
                )
            })
            .collect();
        for s in seqs.iter_mut() {
            let lg = eng.prefill(s).unwrap();
            let tok =
                freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
            s.tokens.push(tok);
        }
        for _ in 0..steps {
            let (front, back) = seqs.split_at_mut(3);
            let mut a: Vec<&mut Sequence> = front.iter_mut().collect();
            let mut b: Vec<&mut Sequence> = back.iter_mut().collect();
            eng.decode_step_pair(&mut a, &mut b).unwrap();
        }
        for s in seqs.iter_mut() {
            eng.drain_sequence(s);
        }
        if exec_workers > 0 {
            assert!(eng.stats.microbatch_pairs > 0, "pair path not exercised");
            assert!(eng.stats.exec_jobs > 0, "pool not exercised");
        }
        Some(seqs.iter().map(|s| s.generated().to_vec()).collect())
    };
    let (Some(serial), Some(pooled)) = (run_pair(0, 12), run_pair(2, 12)) else {
        eprintln!("artifacts/ missing — skipping microbatch pair equivalence test");
        return;
    };
    assert_eq!(serial, pooled, "paired microbatch tokens diverged between dispatch modes");
}

#[test]
fn pair_merges_when_splitting_would_not_shrink_the_bucket() {
    // Two lanes of two sequences both pad to bucket 4 — identical to
    // the joint batch's bucket — so decode_step_pair must decode them
    // as ONE joint step instead of doubling artifact compute.
    let Some(mut eng) = engine(true, 2) else {
        eprintln!("artifacts/ missing — skipping pair-merge test");
        return;
    };
    let mut seqs: Vec<Sequence> = (0..4)
        .map(|i| {
            let prompt: Vec<i32> = (0..120).map(|t| ((t * 11 + i * 5) % 250) as i32).collect();
            eng.new_sequence(i as u64, prompt, 4, SampleParams::greedy())
        })
        .collect();
    for s in seqs.iter_mut() {
        let lg = eng.prefill(s).unwrap();
        let tok = freekv::coordinator::engine::sample_token(&lg, &s.sample.clone(), &mut s.rng);
        s.tokens.push(tok);
    }
    {
        let (front, back) = seqs.split_at_mut(2);
        let mut a: Vec<&mut Sequence> = front.iter_mut().collect();
        let mut b: Vec<&mut Sequence> = back.iter_mut().collect();
        eng.decode_step_pair(&mut a, &mut b).unwrap();
    }
    for s in seqs.iter_mut() {
        eng.drain_sequence(s);
    }
    assert_eq!(eng.stats.microbatch_pairs, 0, "same-bucket split must merge, not pair");
    assert_eq!(eng.stats.steps, 1, "merged pair decodes as one joint step");
    assert_eq!(eng.stats.max_batch_lanes, 4, "joint step carries all four lanes");
}

#[test]
fn overlapped_engine_matches_blocking_when_budget_covers_context() {
    // With the whole context resident, speculation cannot lose pages, so
    // blocking and overlapped speculative decode must produce identical
    // tokens (the seed's guarantee, now with the worker in the loop).
    let Some(mut eng) = engine(true, 2) else {
        eprintln!("artifacts/ missing — skipping");
        return;
    };
    let prompt: Vec<i32> = (0..48).map(|i| (i * 7 % 250) as i32).collect();
    let run = |eng: &mut Engine, blocking: bool| -> Vec<i32> {
        eng.blocking_mode = blocking;
        let mut seq = eng.new_sequence(3, prompt.clone(), 6, SampleParams::greedy());
        eng.generate(&mut seq).unwrap();
        eng.drain_sequence(&mut seq);
        seq.generated().to_vec()
    };
    let spec = run(&mut eng, false);
    let Some(mut eng2) = engine(true, 2) else { return };
    let block = run(&mut eng2, true);
    assert_eq!(spec, block);
}
