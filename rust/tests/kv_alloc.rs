//! Shared KV page allocator, end to end and property-tested: refcount
//! hygiene (everything frees on drop, double-free is impossible by
//! construction), copy-on-write never mutates a page another view still
//! references, the allocator-backed pool is behaviorally identical to
//! the old private-per-request layout when sharing is off, and prefix
//! sharing measurably shrinks the pool for shared-prompt workloads
//! while leaving every token stream unchanged.

use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig, StepEvent};
use freekv::coordinator::sim_backend::{sim_config, SimBackend};
use freekv::kvcache::{LayerPool, Layout, PageAllocator, RequestKv};
use freekv::prop_assert;
use freekv::transfer::TransferEngine;
use freekv::util::proptest::check;
use freekv::util::rng::Rng;

#[test]
#[allow(clippy::type_complexity)]
fn allocator_invariants_under_random_share_write_drop() {
    // Random interleavings of keyed writes, adoptions, and private
    // (CoW) rewrites across several views; after every step each view
    // must read back exactly what it last wrote or adopted, and after
    // the views drop (in random order) the allocator must be empty.
    // A double-free or refcount leak fires the allocator's own asserts.
    check("kv-alloc-invariants", 25, |rng| {
        let (m, p, d) = (1 + rng.below(3), 2 + rng.below(4), 4 + rng.below(8));
        let n_layers = 1 + rng.below(2);
        let n_pages = 6usize;
        let n_views = 2 + rng.below(3);
        let alloc = PageAllocator::new(n_layers, m, p, d, 0, true, rng.next_u64());
        let page_elems = p * m * d;
        let canon = |g: usize| -> Vec<f32> {
            (0..page_elems).map(|i| (g * 31 + i) as f32).collect()
        };
        let mine = |v: usize| -> Vec<f32> {
            (0..page_elems).map(|i| 0.5 + (v * 977 + i) as f32).collect()
        };
        let mut views: Vec<Option<Vec<LayerPool>>> = (0..n_views)
            .map(|_| {
                Some(
                    (0..n_layers)
                        .map(|l| {
                            LayerPool::with_alloc(Layout::Hnd, n_pages, m, p, d, alloc.clone(), l)
                        })
                        .collect(),
                )
            })
            .collect();
        let mut content: Vec<Vec<Vec<Option<Vec<f32>>>>> =
            vec![vec![vec![None; n_pages]; n_layers]; n_views];
        for _step in 0..30 {
            let v = rng.below(n_views);
            let l = rng.below(n_layers);
            let g = rng.below(n_pages);
            let key = (g as u128 + 1) * 1000;
            let pools = views[v].as_mut().expect("views live during the write phase");
            match rng.below(3) {
                0 => {
                    let c = canon(g);
                    pools[l].write_page_keyed(g, &c, &c, Some(key));
                    content[v][l][g] = Some(c);
                }
                1 => {
                    if pools[l].try_adopt(g, key) {
                        content[v][l][g] = Some(canon(g));
                    }
                }
                _ => {
                    let c = mine(v);
                    pools[l].write_page(g, &c, &c);
                    content[v][l][g] = Some(c);
                }
            }
            // every view's recorded pages must read back intact —
            // aliasing and CoW must never leak one view's write into
            // another view
            for (vi, slot) in views.iter().enumerate() {
                let pools = slot.as_ref().unwrap();
                for (li, pool) in pools.iter().enumerate() {
                    for (gi, want) in content[vi][li].iter().enumerate() {
                        let Some(want) = want else { continue };
                        let (k_read, v_read) = pool.read_page_head(gi, 0);
                        for tok in 0..p {
                            for dim in 0..d {
                                let src = (tok * m) * d + dim;
                                prop_assert!(
                                    k_read[tok * d + dim] == want[src]
                                        && v_read[tok * d + dim] == want[src],
                                    "view {} layer {} page {} diverged at tok {} dim {}",
                                    vi,
                                    li,
                                    gi,
                                    tok,
                                    dim
                                );
                            }
                        }
                    }
                }
            }
            let st = alloc.stats();
            prop_assert!(
                st.pages_used <= (n_views * n_layers * n_pages) as u64,
                "used {} exceeds every view full",
                st.pages_used
            );
        }
        // drop the views in random order: refcounts must reach zero
        let mut order: Vec<usize> = (0..n_views).collect();
        rng.shuffle(&mut order);
        for idx in order {
            views[idx] = None;
        }
        let st = alloc.stats();
        prop_assert!(st.pages_used == 0, "leaked {} pages", st.pages_used);
        prop_assert!(st.pages_shared == 0, "shared gauge leaked {}", st.pages_shared);
        Ok(())
    });
}

#[test]
fn shared_allocator_pool_matches_private_pool_bit_for_bit() {
    // The same append/selection schedule through a private-allocator
    // RequestKv and a shared-allocator one (sharing enabled, tokens
    // fed, but no other request to share with) must leave identical
    // select tables and identical gathered attention operands — the
    // allocator swap is invisible to the data path.
    let cfg = sim_config();
    let shared = PageAllocator::for_model(&cfg, 0, true);
    let mut a = RequestKv::new(&cfg, Layout::Hnd);
    let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, shared.clone());
    let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut rng = Rng::new(42);
    let tokens: Vec<i32> = (0..40).map(|t| 32 + t % 90).collect();
    for t in 0..tokens.len() {
        b.feed_tokens(&tokens[..t + 1]);
        for l in 0..cfg.n_layers {
            let k: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            a.append(l, &k, &v, &mut ea);
            b.append(l, &k, &v, &mut eb);
        }
    }
    assert_eq!(ea.counters.offloaded_pages, eb.counters.offloaded_pages);
    assert_eq!(eb.counters.prefix_hits, 0, "nothing to share against");
    // rotating selections, then compare gathered tensors layer by layer
    let mask = a.layers[0].gpu.selectable_mask();
    let cands: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
    assert!(cands.len() >= 2, "need selectable pages");
    for round in 0..3 {
        for l in 0..cfg.n_layers {
            for head in 0..cfg.n_kv {
                let pages = vec![cands[(round + head) % cands.len()]];
                let na = a.apply_selection(l, head, &pages, &mut ea);
                let nb = b.apply_selection(l, head, &pages, &mut eb);
                assert_eq!(na, nb, "round {} layer {} head {}", round, l, head);
            }
        }
    }
    assert_eq!(ea.counters.h2d_chunks, eb.counters.h2d_chunks);
    assert_eq!(ea.counters.h2d_bytes, eb.counters.h2d_bytes);
    for l in 0..cfg.n_layers {
        let s = a.layers[l].gpu.budget_slots();
        let (m, d) = (cfg.n_kv, cfg.d_head);
        let mut ga = (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
        let mut gb = ga.clone();
        {
            let (gpu, x) = a.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
        }
        {
            let (gpu, x) = b.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
        }
        assert_eq!(ga.0, gb.0, "layer {} gathered K diverged", l);
        assert_eq!(ga.1, gb.1, "layer {} gathered V diverged", l);
        assert_eq!(ga.2, gb.2, "layer {} validity diverged", l);
    }
    drop(b);
    assert_eq!(shared.stats().pages_used, 0);
}

/// Drive N identical-prompt requests through the full scheduler stack;
/// returns (completion texts, peak pool pages, prefix hits).
fn run_shared_prompt(n: u64, prefix_cache: bool) -> (Vec<String>, u64, u64) {
    let backend = SimBackend::tiny_with_pool(0, prefix_cache);
    let alloc = backend.allocator();
    let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
    let mut s = Scheduler::new(backend, cfg);
    let prompt = "the shared prompt prefix every tenant sends ".repeat(3);
    for i in 1..=n {
        s.submit(Request::from_text(i, &prompt, 24));
    }
    while s.pending() > 0 {
        for ev in s.tick().expect("sim tick") {
            if let StepEvent::Failed { id, error } = ev {
                panic!("request {} failed: {}", id, error);
            }
        }
    }
    let texts: Vec<String> = (1..=n).map(|i| s.take_completion(i).unwrap().text).collect();
    let st = alloc.stats();
    (texts, st.pages_peak, st.prefix_hits)
}

#[test]
fn prefix_sharing_saves_pages_and_keeps_tokens_identical() {
    let n = 6u64;
    let (texts_off, peak_off, hits_off) = run_shared_prompt(n, false);
    let (texts_on, peak_on, hits_on) = run_shared_prompt(n, true);
    assert_eq!(hits_off, 0);
    assert_eq!(
        texts_off, texts_on,
        "prefix sharing must not change any request's token stream"
    );
    assert!(hits_on > 0, "identical prompts must hit the prefix cache");
    assert!(
        peak_on * 2 < peak_off,
        "sharing should at least halve peak pool pages ({} vs {})",
        peak_on,
        peak_off
    );
}

#[test]
fn prefix_sharing_survives_the_sharer_leaving() {
    // A adopts nothing; B aliases A's pages; A finishes and drops —
    // B's aliased pages must stay readable (refcount keeps them alive)
    // and still free once B drops.
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, true);
    let tokens: Vec<i32> = (0..16).map(|t| 40 + t).collect();
    let kv_row = vec![1.5f32; cfg.n_kv * cfg.d_head];
    let fill = |kv: &mut RequestKv, eng: &mut TransferEngine| {
        for t in 0..tokens.len() {
            kv.feed_tokens(&tokens[..t + 1]);
            for l in 0..cfg.n_layers {
                kv.append(l, &kv_row, &kv_row, eng);
            }
        }
    };
    let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
    let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut a, &mut ea);
    let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
    let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut b, &mut eb);
    assert!(eb.counters.prefix_hits > 0);
    let before = alloc.stats().pages_used;
    drop(a);
    assert_eq!(alloc.stats().pages_used, before, "b keeps adopted pages alive");
    // adopted pages are still recallable through b
    let n = b.apply_selection(0, 0, &[1], &mut eb);
    assert_eq!(n, 1);
    drop(b);
    assert_eq!(alloc.stats().pages_used, 0);
}
