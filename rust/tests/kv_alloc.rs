//! Shared KV page allocator, end to end and property-tested: refcount
//! hygiene (everything frees on drop, double-free is impossible by
//! construction), copy-on-write never mutates a page another view still
//! references, the allocator-backed pool is behaviorally identical to
//! the old private-per-request layout when sharing is off, and prefix
//! sharing measurably shrinks the pool for shared-prompt workloads
//! while leaving every token stream unchanged. The whole invariant
//! suite runs once per page codec (f32 / int8 / int4): quantization is
//! deterministic, so "reads back exactly what it last wrote" becomes
//! "reads back exactly what a reference pool of the same codec returns
//! for that content".

use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig, StepEvent};
use freekv::coordinator::sim_backend::{sim_config, SimBackend};
use freekv::kvcache::{
    KvDtype, KvLockMode, LayerPool, Layout, PageAllocator, PrefixCacheMode, RequestKv,
};
use freekv::prop_assert;
use freekv::transfer::TransferEngine;
use freekv::util::proptest::check;
use freekv::util::rng::Rng;

#[test]
#[allow(clippy::type_complexity)]
fn allocator_invariants_under_random_share_write_drop() {
    // Random interleavings of keyed writes, adoptions, and private
    // (CoW) rewrites across several views; after every step each view
    // must read back exactly what it last wrote or adopted (through the
    // pool's codec), and after the views drop (in random order) the
    // allocator must be empty. A double-free or refcount leak fires the
    // allocator's own asserts. Runs once per codec.
    for dtype in KvDtype::all() {
        check(&format!("kv-alloc-invariants-{}", dtype.as_str()), 25, |rng| {
            let (m, p, d) = (1 + rng.below(3), 2 + rng.below(4), 4 + rng.below(8));
            let n_layers = 1 + rng.below(2);
            let n_pages = 6usize;
            let n_views = 2 + rng.below(3);
            let alloc =
                PageAllocator::with_dtype(n_layers, m, p, d, 0, true, rng.next_u64(), dtype);
            let page_elems = p * m * d;
            let canon = |g: usize| -> Vec<f32> {
                (0..page_elems).map(|i| (g * 31 + i) as f32).collect()
            };
            let mine = |v: usize| -> Vec<f32> {
                (0..page_elems).map(|i| 0.5 + (v * 977 + i) as f32).collect()
            };
            // What a read of head 0 must return for `c` under this codec:
            // quantization is deterministic, so a scratch pool of the
            // same geometry is an exact reference (bit-identity for f32).
            let expected = |c: &[f32]| -> (Vec<f32>, Vec<f32>) {
                let mut scratch = LayerPool::new_dtype(Layout::Hnd, 1, m, p, d, dtype);
                scratch.write_page(0, c, c);
                scratch.read_page_head(0, 0)
            };
            let mut views: Vec<Option<Vec<LayerPool>>> = (0..n_views)
                .map(|_| {
                    Some(
                        (0..n_layers)
                            .map(|l| {
                                LayerPool::with_alloc(
                                    Layout::Hnd,
                                    n_pages,
                                    m,
                                    p,
                                    d,
                                    alloc.clone(),
                                    l,
                                )
                            })
                            .collect(),
                    )
                })
                .collect();
            let mut content: Vec<Vec<Vec<Option<(Vec<f32>, Vec<f32>)>>>> =
                vec![vec![vec![None; n_pages]; n_layers]; n_views];
            for _step in 0..30 {
                let v = rng.below(n_views);
                let l = rng.below(n_layers);
                let g = rng.below(n_pages);
                let key = (g as u128 + 1) * 1000;
                let pools = views[v].as_mut().expect("views live during the write phase");
                match rng.below(3) {
                    0 => {
                        let c = canon(g);
                        pools[l].write_page_keyed(g, &c, &c, Some(key));
                        content[v][l][g] = Some(expected(&c));
                    }
                    1 => {
                        if pools[l].try_adopt(g, key) {
                            content[v][l][g] = Some(expected(&canon(g)));
                        }
                    }
                    _ => {
                        let c = mine(v);
                        pools[l].write_page(g, &c, &c);
                        content[v][l][g] = Some(expected(&c));
                    }
                }
                // every view's recorded pages must read back intact —
                // aliasing and CoW must never leak one view's write into
                // another view
                for (vi, slot) in views.iter().enumerate() {
                    let pools = slot.as_ref().unwrap();
                    for (li, pool) in pools.iter().enumerate() {
                        for (gi, want) in content[vi][li].iter().enumerate() {
                            let Some((want_k, want_v)) = want else { continue };
                            let (k_read, v_read) = pool.read_page_head(gi, 0);
                            prop_assert!(
                                &k_read == want_k && &v_read == want_v,
                                "view {} layer {} page {} diverged ({})",
                                vi,
                                li,
                                gi,
                                dtype
                            );
                        }
                    }
                }
                let st = alloc.stats();
                prop_assert!(
                    st.pages_used <= (n_views * n_layers * n_pages) as u64,
                    "used {} exceeds every view full",
                    st.pages_used
                );
            }
            // drop the views in random order: refcounts must reach zero
            let mut order: Vec<usize> = (0..n_views).collect();
            rng.shuffle(&mut order);
            for idx in order {
                views[idx] = None;
            }
            let st = alloc.stats();
            prop_assert!(st.pages_used == 0, "leaked {} pages", st.pages_used);
            prop_assert!(st.pages_shared == 0, "shared gauge leaked {}", st.pages_shared);
            Ok(())
        });
    }
}

#[test]
fn shared_allocator_pool_matches_private_pool_bit_for_bit() {
    // The same append/selection schedule through a private-allocator
    // RequestKv and a shared-allocator one (sharing enabled, tokens
    // fed, but no other request to share with) must leave identical
    // select tables and identical gathered attention operands — the
    // allocator swap is invisible to the data path.
    let cfg = sim_config();
    let shared = PageAllocator::for_model(&cfg, 0, true);
    let mut a = RequestKv::new(&cfg, Layout::Hnd);
    let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, shared.clone());
    let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    let mut rng = Rng::new(42);
    let tokens: Vec<i32> = (0..40).map(|t| 32 + t % 90).collect();
    for t in 0..tokens.len() {
        b.feed_tokens(&tokens[..t + 1]);
        for l in 0..cfg.n_layers {
            let k: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> =
                (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            a.append(l, &k, &v, &mut ea);
            b.append(l, &k, &v, &mut eb);
        }
    }
    assert_eq!(ea.counters.offloaded_pages, eb.counters.offloaded_pages);
    assert_eq!(eb.counters.prefix_hits, 0, "nothing to share against");
    // rotating selections, then compare gathered tensors layer by layer
    let mask = a.layers[0].gpu.selectable_mask();
    let cands: Vec<usize> =
        mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
    assert!(cands.len() >= 2, "need selectable pages");
    for round in 0..3 {
        for l in 0..cfg.n_layers {
            for head in 0..cfg.n_kv {
                let pages = vec![cands[(round + head) % cands.len()]];
                let na = a.apply_selection(l, head, &pages, &mut ea);
                let nb = b.apply_selection(l, head, &pages, &mut eb);
                assert_eq!(na, nb, "round {} layer {} head {}", round, l, head);
            }
        }
    }
    assert_eq!(ea.counters.h2d_chunks, eb.counters.h2d_chunks);
    assert_eq!(ea.counters.h2d_bytes, eb.counters.h2d_bytes);
    for l in 0..cfg.n_layers {
        let s = a.layers[l].gpu.budget_slots();
        let (m, d) = (cfg.n_kv, cfg.d_head);
        let mut ga = (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
        let mut gb = ga.clone();
        {
            let (gpu, x) = a.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
        }
        {
            let (gpu, x) = b.layers[l].parts_mut();
            gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
        }
        assert_eq!(ga.0, gb.0, "layer {} gathered K diverged", l);
        assert_eq!(ga.1, gb.1, "layer {} gathered V diverged", l);
        assert_eq!(ga.2, gb.2, "layer {} validity diverged", l);
    }
    drop(b);
    assert_eq!(shared.stats().pages_used, 0);
}

#[test]
fn quantized_shared_pool_matches_quantized_private_pool() {
    // The allocator swap must stay invisible to the data path for
    // quantized codecs too: the same append/selection schedule through
    // a sharing int8 pool and a private int8 pool gathers identical
    // (deterministically quantized) tensors.
    for dtype in [KvDtype::Int8, KvDtype::Int4] {
        let cfg = sim_config();
        let shared = PageAllocator::for_model_dtype(&cfg, 0, true, dtype);
        let private = PageAllocator::for_model_dtype(&cfg, 0, false, dtype);
        let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, private);
        let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, shared.clone());
        let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut rng = Rng::new(7);
        let tokens: Vec<i32> = (0..24).map(|t| 32 + t % 90).collect();
        for t in 0..tokens.len() {
            b.feed_tokens(&tokens[..t + 1]);
            for l in 0..cfg.n_layers {
                let k: Vec<f32> =
                    (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> =
                    (0..cfg.n_kv * cfg.d_head).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                a.append(l, &k, &v, &mut ea);
                b.append(l, &k, &v, &mut eb);
            }
        }
        assert_eq!(ea.counters.offloaded_pages, eb.counters.offloaded_pages);
        let mask = a.layers[0].gpu.selectable_mask();
        let cands: Vec<usize> =
            mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
        assert!(!cands.is_empty(), "need selectable pages");
        for l in 0..cfg.n_layers {
            for head in 0..cfg.n_kv {
                let pages = vec![cands[head % cands.len()]];
                let na = a.apply_selection(l, head, &pages, &mut ea);
                let nb = b.apply_selection(l, head, &pages, &mut eb);
                assert_eq!(na, nb, "{} layer {} head {}", dtype, l, head);
            }
        }
        assert_eq!(ea.counters.h2d_encoded_bytes, eb.counters.h2d_encoded_bytes);
        for l in 0..cfg.n_layers {
            let s = a.layers[l].gpu.budget_slots();
            let (m, d) = (cfg.n_kv, cfg.d_head);
            let mut ga =
                (vec![0.0f32; m * s * d], vec![0.0f32; m * s * d], vec![0.0f32; m * s]);
            let mut gb = ga.clone();
            {
                let (gpu, x) = a.layers[l].parts_mut();
                gpu.gather_full(&mut x.select, &mut ga.0, &mut ga.1, &mut ga.2);
            }
            {
                let (gpu, x) = b.layers[l].parts_mut();
                gpu.gather_full(&mut x.select, &mut gb.0, &mut gb.1, &mut gb.2);
            }
            assert_eq!(ga, gb, "{} layer {} gathered tensors diverged", dtype, l);
        }
        drop(b);
        assert_eq!(shared.stats().pages_used, 0);
    }
}

/// Drive N identical-prompt requests through the full scheduler stack;
/// returns (completion texts, peak pool pages, prefix hits).
fn run_shared_prompt(n: u64, prefix_cache: bool) -> (Vec<String>, u64, u64) {
    let (texts, stats) = run_shared_prompt_dtype(n, prefix_cache, KvDtype::F32);
    (texts, stats.pages_peak, stats.prefix_hits)
}

/// [`run_shared_prompt`] with an explicit page codec; returns the full
/// allocator stats so byte gauges can be compared across codecs.
fn run_shared_prompt_dtype(
    n: u64,
    prefix_cache: bool,
    dtype: KvDtype,
) -> (Vec<String>, freekv::kvcache::KvPoolStats) {
    let backend = SimBackend::tiny_with_pool_dtype(0, prefix_cache, dtype);
    let alloc = backend.allocator();
    let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
    let mut s = Scheduler::new(backend, cfg);
    let prompt = "the shared prompt prefix every tenant sends ".repeat(3);
    for i in 1..=n {
        s.submit(Request::from_text(i, &prompt, 24));
    }
    while s.pending() > 0 {
        for ev in s.tick().expect("sim tick") {
            if let StepEvent::Failed { id, error } = ev {
                panic!("request {} failed: {}", id, error);
            }
        }
    }
    let texts: Vec<String> = (1..=n).map(|i| s.take_completion(i).unwrap().text).collect();
    (texts, alloc.stats())
}

#[test]
fn every_codec_serves_and_int8_pool_is_under_30_percent_of_f32() {
    // The full scheduler stack runs unchanged on every codec: token
    // streams are identical (sim decode never reads KV back), prefix
    // sharing still hits under dtype-qualified keys, page counts match,
    // and the CPU byte gauges shrink with the codec — int8 to <=30% of
    // f32 at the same page count (the issue's acceptance bar), int4
    // strictly below int8.
    let n = 6u64;
    let (texts_f32, st_f32) = run_shared_prompt_dtype(n, true, KvDtype::F32);
    let (texts_i8, st_i8) = run_shared_prompt_dtype(n, true, KvDtype::Int8);
    let (texts_i4, st_i4) = run_shared_prompt_dtype(n, true, KvDtype::Int4);
    assert_eq!(texts_f32, texts_i8);
    assert_eq!(texts_f32, texts_i4);
    for st in [&st_i8, &st_i4] {
        assert!(st.prefix_hits > 0, "prefix cache must still hit on quantized pools");
        assert_eq!(st.pages_peak, st_f32.pages_peak, "page counts are codec-independent");
    }
    assert!(
        st_i8.cpu_bytes_peak * 10 <= st_f32.cpu_bytes_peak * 3,
        "int8 pool bytes {} not <= 30% of f32 {}",
        st_i8.cpu_bytes_peak,
        st_f32.cpu_bytes_peak
    );
    assert!(st_i4.cpu_bytes_peak < st_i8.cpu_bytes_peak);
}

#[test]
fn prefix_sharing_saves_pages_and_keeps_tokens_identical() {
    let n = 6u64;
    let (texts_off, peak_off, hits_off) = run_shared_prompt(n, false);
    let (texts_on, peak_on, hits_on) = run_shared_prompt(n, true);
    assert_eq!(hits_off, 0);
    assert_eq!(
        texts_off, texts_on,
        "prefix sharing must not change any request's token stream"
    );
    assert!(hits_on > 0, "identical prompts must hit the prefix cache");
    assert!(
        peak_on * 2 < peak_off,
        "sharing should at least halve peak pool pages ({} vs {})",
        peak_on,
        peak_off
    );
}

/// Drive the shared-prompt workload one request at a time: each fully
/// retires (its `Sequence` drops) before the next is submitted, so any
/// prefix hit can only come from the retained tier — there are never
/// live pages to alias. Returns (texts, final stats, the allocator).
fn run_serialized_prompt_mode(
    n: u64,
    mode: PrefixCacheMode,
    pool_pages: u64,
    dtype: KvDtype,
) -> (Vec<String>, freekv::kvcache::KvPoolStats, std::sync::Arc<PageAllocator>) {
    let backend = SimBackend::tiny_with_pool_mode_dtype(pool_pages, mode, 0, dtype);
    let alloc = backend.allocator();
    let cfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
    let mut s = Scheduler::new(backend, cfg);
    let prompt = "the shared prompt prefix every tenant sends ".repeat(3);
    for i in 1..=n {
        s.submit(Request::from_text(i, &prompt, 24));
        drain_scheduler(&mut s);
    }
    let texts: Vec<String> = (1..=n).map(|i| s.take_completion(i).unwrap().text).collect();
    (texts, alloc.stats(), alloc)
}

fn drain_scheduler(s: &mut Scheduler<SimBackend>) {
    while s.pending() > 0 {
        for ev in s.tick().expect("sim tick") {
            if let StepEvent::Failed { id, error } = ev {
                panic!("request {} failed: {}", id, error);
            }
        }
    }
}

#[test]
fn retained_tier_serves_fully_retired_prefixes_bit_identically() {
    // Every request runs alone — by the time request i+1 arrives,
    // request i's pages have zero live references. A resident-only
    // cache therefore can never hit, while the retained tier adopts the
    // whole prompt; either way the token streams must be identical to
    // sharing off (adoption only skips pool writes, never GPU compute).
    // Runs per codec: retained pages are revived through the same codec
    // that wrote them, so quantized reruns stay deterministic too.
    for dtype in KvDtype::all() {
        let n = 4u64;
        let (texts_off, st_off, _) = run_serialized_prompt_mode(n, PrefixCacheMode::Off, 0, dtype);
        let (texts_res, st_res, _) =
            run_serialized_prompt_mode(n, PrefixCacheMode::Resident, 0, dtype);
        let (texts_ret, st_ret, _) =
            run_serialized_prompt_mode(n, PrefixCacheMode::Retained, 0, dtype);
        assert_eq!(texts_off, texts_res, "{}: resident sharing changed tokens", dtype);
        assert_eq!(texts_off, texts_ret, "{}: retained adoption changed tokens", dtype);
        assert_eq!(st_off.prefix_hits, 0);
        assert_eq!(
            st_res.prefix_hits, 0,
            "{}: resident-only sharing cannot hit across retirements",
            dtype
        );
        assert!(st_ret.retained_hits > 0, "{}: no retained-tier hits", dtype);
        assert_eq!(
            st_ret.prefix_hits, st_ret.retained_hits,
            "{}: every hit here must be a retained revival",
            dtype
        );
        assert!(st_ret.bytes_saved > 0);
        assert!(st_ret.pages_retained > 0, "{}: last request's pages stay cached", dtype);
    }
}

#[test]
fn retained_gauges_return_to_baseline_after_cache_drop() {
    let (_, st, alloc) = run_serialized_prompt_mode(3, PrefixCacheMode::Retained, 0, KvDtype::F32);
    // every request has retired: the only pages left are the cache's
    assert!(st.pages_retained > 0);
    assert_eq!(st.pages_used, st.pages_retained, "live pages after all requests retired");
    let dropped = alloc.drop_retained();
    assert_eq!(dropped, st.pages_retained);
    let after = alloc.stats();
    assert_eq!(after.pages_retained, 0);
    assert_eq!(after.pages_used, 0, "dropping the cache must empty the pool");
    assert_eq!(after.pages_shared, 0);
    assert_eq!(after.retained_evictions, st.retained_evictions + dropped);
    // counters (not gauges) survive the drop untouched
    assert_eq!(after.retained_hits, st.retained_hits);
    assert_eq!(after.bytes_saved, st.bytes_saved);
}

#[test]
fn admission_treats_retained_pages_as_reclaimable_capacity() {
    // Wait => progress liveness under retention: request A retires and
    // its retained pages fill most of a bounded pool; request B (a
    // different prompt, so nothing to adopt) must still be admitted —
    // the ledger counts retained pages as reclaimable — and complete by
    // evicting A's cache under pressure, never wedging in Wait.
    use freekv::kvcache::alloc::worst_case_pages;
    let cfg = sim_config();
    let prompt_a = "the shared prompt prefix every tenant sends ".repeat(3);
    let prompt_b = "an entirely different prompt from the second tenant ".repeat(3);
    // capacity ~ one request's worst case (with decode slack): far less
    // than A's cache plus B's working set together
    let capacity = worst_case_pages(&cfg, prompt_a.len().max(prompt_b.len()) + 40);
    let backend = SimBackend::tiny_with_pool_mode(capacity, PrefixCacheMode::Retained, 0);
    let alloc = backend.allocator();
    let scfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
    let mut s = Scheduler::new(backend, scfg);
    s.submit(Request::from_text(1, &prompt_a, 24));
    drain_scheduler(&mut s);
    assert!(s.take_completion(1).is_some());
    let st = alloc.stats();
    assert!(st.pages_retained > 0, "A's pages must enter the retained tier");
    s.submit(Request::from_text(2, &prompt_b, 24));
    drain_scheduler(&mut s);
    assert!(s.take_completion(2).is_some(), "B must complete, not wait forever");
    let st2 = alloc.stats();
    assert!(
        st2.retained_evictions > 0,
        "B's pages must come from evicting A's retained pages (capacity {})",
        capacity
    );
    assert_eq!(st2.retained_hits, 0, "different prompts must not alias");
}

#[test]
fn retention_cap_bounds_the_cache_through_the_scheduler() {
    let cfg = sim_config();
    let cap = cfg.n_layers as u64 * 2;
    let backend = SimBackend::tiny_with_pool_mode(0, PrefixCacheMode::Retained, cap);
    let alloc = backend.allocator();
    let scfg = SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() };
    let mut s = Scheduler::new(backend, scfg);
    let prompt = "the shared prompt prefix every tenant sends ".repeat(3);
    for i in 1..=3u64 {
        s.submit(Request::from_text(i, &prompt, 24));
        drain_scheduler(&mut s);
    }
    let st = alloc.stats();
    assert!(st.pages_retained > 0);
    assert!(
        st.pages_retained <= cap,
        "retained tier {} exceeds --kv-retain-pages {}",
        st.pages_retained,
        cap
    );
}

/// Seeds for the concurrency stress suite. CI's contention matrix runs
/// one seed per job via `FREEKV_CHAOS_SEEDS` (the chaos suite's
/// convention); a plain `cargo test` covers the fixed trio.
fn stress_seeds() -> Vec<u64> {
    match std::env::var("FREEKV_CHAOS_SEEDS") {
        Ok(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
        Err(_) => vec![11, 23, 47],
    }
}

/// Canonical content for the page shared under key `g`: a pure function
/// of the key, so adopting a page some other thread wrote yields bytes
/// identical to writing it yourself (quantization is deterministic).
fn canon_page(g: usize, page_elems: usize) -> Vec<f32> {
    (0..page_elems).map(|i| ((g * 37 + i) % 113) as f32 * 0.25 - 7.0).collect()
}

#[test]
fn concurrent_share_write_adopt_drop_matches_sequential_replay() {
    // N threads hammer one allocator with random keyed writes,
    // adoptions, private (CoW) rewrites, and whole-view drop/recreate
    // cycles. Shared content is a pure function of the prefix key, so
    // each thread knows exactly what every one of its pages must hold
    // regardless of interleaving. After the run, every surviving page
    // must read back byte-equal to a single-threaded replay of the same
    // final content through a private reference pool of the same codec;
    // the allocator's full invariant audit must pass; and dropping every
    // view must drain the pool to zero. Runs per codec and per lock
    // layout (`--kv-lock=global|sharded`) on every seed.
    for dtype in KvDtype::all() {
        for lock in KvLockMode::all() {
            for seed in stress_seeds() {
                stress_round(dtype, lock, seed);
            }
        }
    }
}

#[allow(clippy::type_complexity)]
fn stress_round(dtype: KvDtype, lock: KvLockMode, seed: u64) {
    const THREADS: usize = 4;
    const ITERS: usize = 200;
    let (n_layers, m, p, d) = (4usize, 2usize, 4usize, 8usize);
    let n_pages = 8usize;
    let alloc = PageAllocator::with_mode_lock(
        n_layers,
        m,
        p,
        d,
        0,
        PrefixCacheMode::Resident,
        0,
        seed,
        dtype,
        lock,
    );
    let page_elems = p * m * d;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let alloc = alloc.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(seed ^ (0xA5A5_0000 + t as u64));
                let mut pools: Vec<LayerPool> = (0..n_layers)
                    .map(|l| {
                        LayerPool::with_alloc(Layout::Hnd, n_pages, m, p, d, alloc.clone(), l)
                    })
                    .collect();
                // what each of this thread's pages must hold right now
                let mut content: Vec<Vec<Option<Vec<f32>>>> = vec![vec![None; n_pages]; n_layers];
                for step in 0..ITERS {
                    let l = rng.below(n_layers);
                    let g = rng.below(n_pages);
                    let key = (g as u128 + 1) * 0x9E37;
                    match rng.below(8) {
                        0..=2 => {
                            // keyed canonical write: shareable with every
                            // other thread under the same key
                            let c = canon_page(g, page_elems);
                            pools[l].write_page_keyed(g, &c, &c, Some(key));
                            content[l][g] = Some(c);
                        }
                        3..=4 => {
                            // adopt if some thread has published the key;
                            // otherwise publish it ourselves — either way
                            // the page holds the canonical bytes
                            if !pools[l].try_adopt(g, key) {
                                let c = canon_page(g, page_elems);
                                pools[l].write_page_keyed(g, &c, &c, Some(key));
                            }
                            content[l][g] = Some(canon_page(g, page_elems));
                        }
                        5..=6 => {
                            // private rewrite: forces CoW off any alias
                            let c: Vec<f32> = (0..page_elems)
                                .map(|i| 0.5 + ((t * 1009 + step * 131 + i) % 97) as f32)
                                .collect();
                            pools[l].write_page(g, &c, &c);
                            content[l][g] = Some(c);
                        }
                        _ => {
                            // drop one layer's whole view and start over:
                            // release/free churn concurrent with sharing
                            pools[l] = LayerPool::with_alloc(
                                Layout::Hnd,
                                n_pages,
                                m,
                                p,
                                d,
                                alloc.clone(),
                                l,
                            );
                            content[l] = vec![None; n_pages];
                        }
                    }
                    // periodic reads interleave with other threads'
                    // writes and frees on the same shard
                    if step % 16 == 0 && content[l][g].is_some() {
                        let _ = pools[l].read_page_head(g, 0);
                    }
                }
                // sequential replay: the same final content through a
                // fresh private pool of the same codec must match the
                // concurrent pool byte for byte
                for l in 0..n_layers {
                    let mut reference = LayerPool::new_dtype(Layout::Hnd, n_pages, m, p, d, dtype);
                    for g in 0..n_pages {
                        let Some(c) = &content[l][g] else { continue };
                        reference.write_page(g, c, c);
                        for head in 0..m {
                            let want = reference.read_page_head(g, head);
                            let got = pools[l].read_page_head(g, head);
                            assert_eq!(
                                got,
                                want,
                                "{}/{} seed {}: thread {} layer {} page {} head {} diverged",
                                dtype,
                                lock,
                                seed,
                                t,
                                l,
                                g,
                                head
                            );
                        }
                    }
                }
            });
        }
    });
    // all views dropped with their threads: the pool must be empty and
    // internally consistent (refcounts, free list, gauges, registry)
    alloc.audit_invariants();
    let st = alloc.stats();
    assert_eq!(st.pages_used, 0, "{}/{} seed {}: leaked pages", dtype, lock, seed);
    assert_eq!(st.pages_shared, 0, "{}/{} seed {}: shared gauge leaked", dtype, lock, seed);
}

#[test]
fn prefix_sharing_survives_the_sharer_leaving() {
    // A adopts nothing; B aliases A's pages; A finishes and drops —
    // B's aliased pages must stay readable (refcount keeps them alive)
    // and still free once B drops.
    let cfg = sim_config();
    let alloc = PageAllocator::for_model(&cfg, 0, true);
    let tokens: Vec<i32> = (0..16).map(|t| 40 + t).collect();
    let kv_row = vec![1.5f32; cfg.n_kv * cfg.d_head];
    let fill = |kv: &mut RequestKv, eng: &mut TransferEngine| {
        for t in 0..tokens.len() {
            kv.feed_tokens(&tokens[..t + 1]);
            for l in 0..cfg.n_layers {
                kv.append(l, &kv_row, &kv_row, eng);
            }
        }
    };
    let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
    let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut a, &mut ea);
    let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
    let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
    fill(&mut b, &mut eb);
    assert!(eb.counters.prefix_hits > 0);
    let before = alloc.stats().pages_used;
    drop(a);
    assert_eq!(alloc.stats().pages_used, before, "b keeps adopted pages alive");
    // adopted pages are still recallable through b
    let n = b.apply_selection(0, 0, &[1], &mut eb);
    assert_eq!(n, 1);
    drop(b);
    assert_eq!(alloc.stats().pages_used, 0);
}
