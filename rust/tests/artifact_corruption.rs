//! Artifact-corruption tests: the runtime must fail loudly and precisely
//! on corrupted artifacts, never segfault or silently misload. (Runtime
//! fault-injection for the *serving* stack — chaos schedules, restart
//! ladders, the router tier — lives in `tests/fault_injection.rs`.)

use std::fs;

use freekv::runtime::{HostTensor, Manifest, Runtime};

fn artifacts_src() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Copy a minimal artifact set into a temp dir we can corrupt.
fn stage(tmp: &std::path::Path, corrupt: impl Fn(&std::path::Path)) -> anyhow::Result<Runtime> {
    fs::create_dir_all(tmp)?;
    for f in ["manifest.json", "weights_tiny.bin", "golden_tiny.json"] {
        fs::copy(artifacts_src().join(f), tmp.join(f))?;
    }
    for entry in fs::read_dir(artifacts_src())? {
        let p = entry?.path();
        if p.extension().map_or(false, |e| e == "txt") {
            fs::copy(&p, tmp.join(p.file_name().unwrap()))?;
        }
    }
    corrupt(tmp);
    Ok(Runtime::load(tmp)?)
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("freekv-failinj-{}-{}", tag, std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

#[test]
fn missing_artifacts_dir_is_a_clean_error() {
    let err = Runtime::load("/nonexistent/freekv-artifacts").err().unwrap();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "{}", msg);
    assert!(msg.contains("make artifacts"), "actionable hint expected: {}", msg);
}

#[test]
fn truncated_manifest_is_a_parse_error() {
    let d = tmpdir("trunc-manifest");
    let res = stage(&d, |p| {
        let m = fs::read_to_string(p.join("manifest.json")).unwrap();
        fs::write(p.join("manifest.json"), &m[..m.len() / 2]).unwrap();
    });
    let msg = format!("{:#}", res.err().unwrap());
    assert!(msg.to_lowercase().contains("pars"), "{}", msg);
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn corrupted_hlo_text_fails_at_compile_with_artifact_name() {
    let d = tmpdir("bad-hlo");
    let rt = stage(&d, |p| {
        fs::write(p.join("tiny_embed_b1.hlo.txt"), "HloModule garbage\nnot hlo at all").unwrap();
    })
    .unwrap();
    let err = rt
        .run("tiny_embed_b1", &[HostTensor::I32(vec![1], vec![1])], None)
        .err()
        .unwrap();
    let msg = format!("{err:#}");
    assert!(msg.contains("tiny_embed_b1"), "error must name the artifact: {}", msg);
    // other artifacts still work (isolation)
    let ok = rt.run("tiny_logits_b1", &[HostTensor::F32(vec![0.0; 256], vec![1, 256])], None);
    assert!(ok.is_ok());
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn truncated_weights_blob_is_rejected() {
    let d = tmpdir("short-weights");
    let rt = stage(&d, |p| {
        let w = fs::read(p.join("weights_tiny.bin")).unwrap();
        fs::write(p.join("weights_tiny.bin"), &w[..w.len() / 2]).unwrap();
    })
    .unwrap();
    let err = rt
        .run("tiny_embed_b1", &[HostTensor::I32(vec![1], vec![1])], None)
        .err()
        .unwrap();
    // must be an error (range panic is prevented by slicing checks inside
    // Vec indexing -> we accept any Err, but not a success)
    let _ = err;
    let _ = fs::remove_dir_all(&d);
}

#[test]
fn unknown_artifact_and_config_errors_name_the_key() {
    let rt = Runtime::load(artifacts_src()).unwrap();
    let e1 = format!("{:#}", rt.run("tiny_nonexistent", &[], None).err().unwrap());
    assert!(e1.contains("tiny_nonexistent"));
    let e2 = format!("{:#}", rt.manifest.config("llama-70b").err().unwrap());
    assert!(e2.contains("llama-70b"));
    let e3 = format!("{:#}", rt.weight_buffers("nope").err().unwrap());
    assert!(e3.contains("nope"));
}

#[test]
fn manifest_survives_unknown_extra_fields() {
    // forward-compat: a manifest with extra keys still loads.
    let d = tmpdir("extra-fields");
    fs::create_dir_all(&d).unwrap();
    for entry in fs::read_dir(artifacts_src()).unwrap() {
        let p = entry.unwrap().path();
        fs::copy(&p, d.join(p.file_name().unwrap())).unwrap();
    }
    let m = fs::read_to_string(d.join("manifest.json")).unwrap();
    let patched = m.replacen('{', "{\n \"future_field\": {\"x\": [1,2,3]},", 1);
    fs::write(d.join("manifest.json"), patched).unwrap();
    let man = Manifest::load(&d).unwrap();
    assert!(man.configs.contains_key("tiny"));
    let _ = fs::remove_dir_all(&d);
}
