//! Event-driven serving API, end to end over HTTP, artifact-free: these
//! tests run the full stack — per-connection server threads → cloneable
//! `Submitter` → engine loop → continuous-batching scheduler — against
//! the deterministic `SimBackend`, so they exercise real concurrency on
//! any host (no PJRT needed).
//!
//! Covered: N simultaneous HTTP clients decoding in shared batches,
//! streaming that yields the first token long before the last,
//! mid-generation cancellation (client disconnect) releasing KV and the
//! admission slot, 429 backpressure when the queue cap is hit, and
//! per-token TTFT/ITL percentiles on `/metrics`. The router tier rides
//! the same seam: `--replicas 1` bit-identity vs a bare `Submitter`,
//! prefix-affinity concentration of retained hits across replicas, and
//! per-replica gauge labels on the aggregated `/metrics`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use freekv::coordinator::engine_loop::{EngineLoop, LoopConfig, SubmitError};
use freekv::coordinator::router::{
    KvAwareRouter, KvRouterConfig, RoundRobinRouter, Router, SingleRouter,
};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
use freekv::coordinator::sim_backend::{sim_next_token, SimBackend};
use freekv::coordinator::tokenizer;
use freekv::kvcache::PrefixCacheMode;
use freekv::server::{serve_listener, ServeOptions};
use freekv::util::json::Json;

fn spawn_sim_loop(step_delay_ms: u64, queue_cap: usize) -> EngineLoop {
    EngineLoop::spawn(LoopConfig { queue_cap, ..Default::default() }, move || {
        let mut b = SimBackend::tiny();
        b.step_delay = Duration::from_millis(step_delay_ms);
        Ok(Scheduler::new(
            b,
            SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() },
        ))
    })
    .expect("sim engine loop spawns without artifacts")
}

/// A sim loop whose allocator runs the retained prefix-cache tier —
/// the backend shape the prefix-affinity router is built for.
fn spawn_retained_loop() -> EngineLoop {
    EngineLoop::spawn(LoopConfig { queue_cap: 8, ..Default::default() }, || {
        Ok(Scheduler::new(
            SimBackend::tiny_with_pool_mode(0, PrefixCacheMode::Retained, 0),
            SchedulerConfig { max_batch: 8, admit_below: 8, ..Default::default() },
        ))
    })
    .expect("retained sim loop spawns")
}

/// Serve on an OS-assigned port; returns the address. The server thread
/// exits once `max_requests` generations complete (or runs detached).
fn serve_sim(el: &EngineLoop, max_requests: Option<usize>) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sub = el.submitter();
    thread::spawn(move || {
        serve_listener(listener, sub, ServeOptions { max_requests, ..Default::default() }).unwrap();
    });
    addr
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").unwrap_or((resp.as_str(), ""));
    let status: u16 =
        head.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    (status, body.to_string())
}

fn get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {} HTTP/1.1\r\nHost: t\r\n\r\n", path).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").unwrap_or((resp.as_str(), ""));
    let status: u16 =
        head.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    (status, body.to_string())
}

#[test]
fn concurrent_http_requests_decode_in_shared_batches() {
    // 5ms per decode step × 40 tokens ≈ 200ms per request: four clients
    // fired together overlap for almost their whole lifetime, so the
    // engine must see multi-lane decode steps.
    let el = spawn_sim_loop(5, 64);
    let addr = serve_sim(&el, Some(4));
    let clients: Vec<_> = (0..4)
        .map(|i| {
            thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt":"concurrent client {} ","max_tokens":40}}"#,
                    i
                );
                post_generate(addr, &body)
            })
        })
        .collect();
    for c in clients {
        let (status, body) = c.join().unwrap();
        assert_eq!(status, 200, "{}", body);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("generated").as_usize(), Some(40));
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
        assert_eq!(j.get("text").as_str().unwrap().len(), 40);
    }
    let stats = el.submitter().engine_stats().unwrap();
    assert!(
        stats.batched_steps > 1 && stats.max_batch_lanes >= 2,
        "requests serialized: {} batched steps, widest batch {}",
        stats.batched_steps,
        stats.max_batch_lanes
    );
    // per-token percentiles are live on /metrics
    let report = el.submitter().metrics_report().unwrap();
    assert!(report.contains("ttft p50="), "{}", report);
    assert!(report.contains("itl p50="), "{}", report);
    assert!(report.contains("completed=4"), "{}", report);
    el.shutdown();
}

#[test]
fn streaming_yields_first_token_before_the_last() {
    let el = spawn_sim_loop(4, 8);
    let addr = serve_sim(&el, Some(1));
    let mut s = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt":"stream me ","max_tokens":50,"stream":true}"#;
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();

    let mut reader = BufReader::new(s);
    let mut head = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.trim_end().is_empty() {
            break;
        }
        head.push_str(&line);
    }
    assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
    assert!(head.to_lowercase().contains("text/event-stream"), "{}", head);
    assert!(head.to_lowercase().contains("chunked"), "{}", head);

    // Read SSE events as they arrive, timestamping each data line.
    let mut first_token_at: Option<Instant> = None;
    let mut token_events = 0usize;
    let mut done: Option<Json> = None;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap() > 0 {
        let t = line.trim_end().to_string();
        line.clear();
        let Some(payload) = t.strip_prefix("data: ") else { continue };
        let j = Json::parse(payload).unwrap();
        match j.get("event").as_str() {
            Some("token") => {
                assert_eq!(j.get("index").as_usize(), Some(token_events));
                first_token_at.get_or_insert_with(Instant::now);
                token_events += 1;
            }
            Some("done") => {
                done = Some(j);
                break;
            }
            other => panic!("unexpected event {:?} in {}", other, payload),
        }
    }
    let first_at = first_token_at.expect("token events before done");
    let done = done.expect("terminal done event");
    // 49 decode steps × 4ms ≈ 200ms separate the first token from the
    // last; well over any scheduling jitter.
    assert!(
        first_at.elapsed() >= Duration::from_millis(50),
        "first token must arrive while generation is still running ({:?})",
        first_at.elapsed()
    );
    assert_eq!(token_events, 50, "one SSE event per sampled token");
    assert_eq!(done.get("generated").as_usize(), Some(50));
    assert_eq!(done.get("finish_reason").as_str(), Some("length"));
    assert_eq!(done.get("text").as_str().unwrap().len(), 50);
    el.shutdown();
}

#[test]
fn client_disconnect_cancels_the_session() {
    let el = spawn_sim_loop(5, 8);
    let addr = serve_sim(&el, None);
    let sub = el.submitter();
    {
        let mut s = TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt":"abandoned stream ","max_tokens":1000,"stream":true}"#;
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        // read until the first token event so the session is mid-flight
        let mut reader = BufReader::new(&s);
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            if line.starts_with("data: ") {
                break;
            }
            line.clear();
        }
        assert_eq!(sub.in_flight(), 1);
        // dropping the socket here is the client vanishing
    }
    let t0 = Instant::now();
    while sub.in_flight() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "disconnect never cancelled the session"
        );
        thread::sleep(Duration::from_millis(20));
    }
    // 1000 tokens at 5ms/step would take 5s; the engine going idle this
    // fast proves decode stopped early.
    let steps_then = sub.engine_stats().unwrap().steps;
    thread::sleep(Duration::from_millis(100));
    assert_eq!(sub.engine_stats().unwrap().steps, steps_then, "decode kept running after cancel");
    let report = sub.metrics_report().unwrap();
    assert!(report.contains("cancelled=1"), "{}", report);
    el.shutdown();
}

#[test]
fn admission_queue_full_returns_429() {
    // queue_cap 1: the first (slow) request occupies the only slot; the
    // second is rejected with 429 instead of queueing unboundedly.
    let el = spawn_sim_loop(40, 1);
    let addr = serve_sim(&el, None);
    let occupant = thread::spawn(move || {
        post_generate(addr, r#"{"prompt":"slow occupant ","max_tokens":30}"#)
    });
    // wait until the occupant holds the admission slot
    let sub = el.submitter();
    let t0 = Instant::now();
    while sub.in_flight() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "occupant never admitted");
        thread::sleep(Duration::from_millis(10));
    }
    let (status, body) = post_generate(addr, r#"{"prompt":"rejected ","max_tokens":4}"#);
    assert_eq!(status, 429, "{}", body);
    assert!(body.contains("busy"), "{}", body);
    let (status, body) = occupant.join().unwrap();
    assert_eq!(status, 200, "{}", body);
    // slot released: the same request is admitted now
    let (status, _) = post_generate(addr, r#"{"prompt":"admitted ","max_tokens":2}"#);
    assert_eq!(status, 200);
    el.shutdown();
}

#[test]
fn connection_cap_answers_503_instead_of_spawning_threads() {
    // Two slow streaming sessions occupy the whole connection budget;
    // an extra connection must be answered 503 by the acceptor, and the
    // budget must be released once a session ends.
    let el = spawn_sim_loop(10, 8);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sub = el.submitter();
    thread::spawn(move || {
        serve_listener(
            listener,
            sub,
            ServeOptions { max_connections: 2, ..Default::default() },
        )
        .unwrap();
    });

    // Hold two streaming connections open mid-generation.
    let mut held = Vec::new();
    for i in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        let body = format!(
            r#"{{"prompt":"occupy slot {} ","max_tokens":200,"stream":true}}"#,
            i
        );
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        // wait for the first token so the connection is surely serving
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            if line.starts_with("data: ") {
                break;
            }
            line.clear();
        }
        held.push((s, reader));
    }

    // Third connection: saturated edge answers 503 for generation...
    let (status, body) = post_generate(addr, r#"{"prompt":"no room ","max_tokens":2}"#);
    assert_eq!(status, 503, "{}", body);
    assert!(body.contains("connection limit"), "{}", body);
    // ...but probes still work (saturation must not look like a dead
    // engine loop to an orchestrator).
    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200, "healthz must survive saturation: {}", body);

    // Release one slot (client disconnect cancels the session)...
    held.pop();
    // ...and the edge accepts again once the handler thread exits.
    let t0 = Instant::now();
    loop {
        let (status, _) = post_generate(addr, r#"{"prompt":"room now ","max_tokens":2}"#);
        if status == 200 {
            break;
        }
        assert_eq!(status, 503, "unexpected status {}", status);
        assert!(t0.elapsed() < Duration::from_secs(5), "connection slot never released");
        thread::sleep(Duration::from_millis(20));
    }
    el.shutdown();
}

#[test]
fn stop_strings_and_sampling_come_from_request_json() {
    // The sim stream is a pure function of the previous token, so the
    // expected text is computable client-side; a stop string cut from it
    // must truncate the completion at its first occurrence.
    let prompt = "stop over http ";
    let mut last = *tokenizer::encode(prompt).last().unwrap();
    let mut expected = String::new();
    for _ in 0..30 {
        last = sim_next_token(last);
        expected.push(last as u8 as char);
    }
    let stop = &expected[10..13];
    let cut = expected.find(stop).unwrap();

    let el = spawn_sim_loop(0, 8);
    let addr = serve_sim(&el, Some(1));
    let body = format!(
        r#"{{"prompt":"{}","max_tokens":30,"stop":{},"seed":7}}"#,
        prompt,
        Json::from(stop).to_string_compact()
    );
    let (status, resp) = post_generate(addr, &body);
    assert_eq!(status, 200, "{}", resp);
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("finish_reason").as_str(), Some("stop"));
    assert_eq!(j.get("text").as_str().unwrap(), &expected[..cut]);
    assert!(j.get("generated").as_usize().unwrap() < 30);
    el.shutdown();
}

#[test]
fn dead_engine_flips_healthz_to_503_and_stops_the_server() {
    let el = spawn_sim_loop(0, 8);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sub = el.submitter();
    let server = thread::spawn(move || serve_listener(listener, sub, ServeOptions::default()));
    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200, "{}", body);
    el.shutdown();
    // health is honest: a dead engine loop turns this instance unhealthy
    let (status, _) = get(addr, "/healthz");
    assert_eq!(status, 503);
    // and the acceptor notices on its next pass and exits with an error
    let result = server.join().unwrap();
    assert!(result.is_err(), "server must stop once the engine loop is gone");
}

/// Read one HTTP response (status line + headers + Content-Length body)
/// off a persistent reader, leaving the stream positioned at the next
/// response — the keep-alive client half.
fn read_one_response<R: BufRead>(reader: &mut R) -> (u16, String, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut headers = String::new();
    let mut content_len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = line.trim_end().split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
        headers.push_str(&line);
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8_lossy(&body).into_owned())
}

#[test]
fn keep_alive_serves_multiple_generations_on_one_connection() {
    let el = spawn_sim_loop(0, 8);
    let addr = serve_sim(&el, None);
    let mut s = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    for i in 0..3 {
        let body = format!(r#"{{"prompt":"keep alive {} ","max_tokens":4}}"#, i);
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let (status, headers, resp) = read_one_response(&mut reader);
        assert_eq!(status, 200, "request {} on the shared connection: {}", i, resp);
        assert!(
            headers.to_lowercase().contains("connection: keep-alive"),
            "response must advertise keep-alive: {}",
            headers
        );
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("generated").as_usize(), Some(4));
    }
    // pipelined: both requests written before reading either response —
    // the connection-spanning reader must not drop the second one's
    // bytes (they arrive as readahead while request one is parsed)
    for tag in ["one", "two"] {
        let body = format!(r#"{{"prompt":"pipelined {} ","max_tokens":3}}"#, tag);
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
    }
    for i in 0..2 {
        let (status, _, resp) = read_one_response(&mut reader);
        assert_eq!(status, 200, "pipelined response {}: {}", i, resp);
    }
    // probes ride the same connection too
    write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n").unwrap();
    let (status, _, metrics) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    assert!(metrics.contains("completed=5"), "{}", metrics);
    assert!(metrics.contains("kv_pages_total="), "{}", metrics);
    // asking for close actually closes
    write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    assert!(headers.to_lowercase().contains("connection: close"), "{}", headers);
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "server closed after Connection: close");
    el.shutdown();
}

#[test]
fn idle_keep_alive_connection_releases_its_slot() {
    // max_connections 1: the whole budget is one slot. A kept-alive
    // connection parked between requests must not pin it for the
    // keep_alive_idle window — the slot is released while parked and
    // re-acquired when the next request line arrives.
    let el = spawn_sim_loop(5, 8);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sub = el.submitter();
    thread::spawn(move || {
        serve_listener(
            listener,
            sub,
            ServeOptions { max_connections: 1, ..Default::default() },
        )
        .unwrap();
    });

    // A: keep-alive connection, one quick generation, then parked idle.
    let mut a = TcpStream::connect(addr).unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    let send = |a: &mut TcpStream, body: &str| {
        write!(
            a,
            "POST /generate HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
    };
    send(&mut a, r#"{"prompt":"keep alive slot ","max_tokens":2}"#);
    let (status, _, resp) = read_one_response(&mut a_reader);
    assert_eq!(status, 200, "{}", resp);

    // While A idles, another client must be able to take the only slot.
    // (Brief retry: the release happens when A's handler loops back to
    // park after writing its response.)
    let t0 = Instant::now();
    loop {
        let (status, body) =
            post_generate(addr, r#"{"prompt":"uses the slot ","max_tokens":2}"#);
        if status == 200 {
            break;
        }
        assert_eq!(status, 503, "{}", body);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "idle keep-alive connection still pins the only connection slot"
        );
        thread::sleep(Duration::from_millis(20));
    }

    // The parked connection re-acquires a slot and keeps serving.
    send(&mut a, r#"{"prompt":"woke up ","max_tokens":2}"#);
    let (status, _, resp) = read_one_response(&mut a_reader);
    assert_eq!(status, 200, "parked connection must re-acquire a slot: {}", resp);

    // Saturate the edge with a long streaming session, then wake A: the
    // re-acquire must observe saturation and refuse with 503 (headroom
    // slots serve no generations).
    let t1 = Instant::now();
    let _held = loop {
        let mut s = TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt":"occupy ","max_tokens":500,"stream":true}"#;
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        if line.starts_with("HTTP/1.1 200") {
            // first token = the session surely holds its slot
            let mut l = String::new();
            while r.read_line(&mut l).unwrap() > 0 {
                if l.starts_with("data: ") {
                    break;
                }
                l.clear();
            }
            break (s, r);
        }
        assert!(t1.elapsed() < Duration::from_secs(10), "stream never admitted");
        thread::sleep(Duration::from_millis(20));
    };
    send(&mut a, r#"{"prompt":"no slot left ","max_tokens":2}"#);
    let (status, _, resp) = read_one_response(&mut a_reader);
    assert_eq!(status, 503, "re-acquire under saturation must refuse: {}", resp);
    assert!(resp.contains("connection limit"), "{}", resp);
    el.shutdown();
}

#[test]
fn shutdown_flag_stops_the_acceptor_and_drains_inflight_sessions() {
    // The signal handler's contract with the server: flipping the flag
    // (plus a wake connection) stops the acceptor, which begins the
    // graceful drain — running sessions finish, new ones get refused.
    let el = spawn_sim_loop(5, 8);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let sub = el.submitter();
    let opts = ServeOptions {
        drain: Duration::from_secs(10),
        shutdown: Some(stop.clone()),
        ..Default::default()
    };
    let server = thread::spawn(move || serve_listener(listener, sub, opts));
    // a streaming session mid-generation when the "signal" lands
    let mut s = TcpStream::connect(addr).unwrap();
    let body = r#"{"prompt":"drain me ","max_tokens":30,"stream":true}"#;
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap() > 0 {
        if line.starts_with("data: ") {
            break;
        }
        line.clear();
    }
    // the "signal": set the flag, poke the listener awake
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    server.join().unwrap().expect("acceptor exits cleanly on shutdown");
    // drain has begun: new sessions are refused...
    assert!(matches!(el.submitter().submit_text("late ", 2), Err(SubmitError::Draining)));
    // ...but the in-flight stream runs to its natural completion
    let mut done = None;
    let mut l = String::new();
    while reader.read_line(&mut l).unwrap() > 0 {
        if let Some(payload) = l.trim_end().strip_prefix("data: ") {
            let j = Json::parse(payload).unwrap();
            if j.get("event").as_str() == Some("done") {
                done = Some(j);
                break;
            }
        }
        l.clear();
    }
    let done = done.expect("drained session completes");
    assert_eq!(done.get("finish_reason").as_str(), Some("length"));
    assert_eq!(done.get("generated").as_usize(), Some(30));
    el.shutdown_graceful(Duration::from_secs(5));
}

#[test]
fn malformed_requests_get_400_not_garbage_parsing() {
    let el = spawn_sim_loop(0, 8);
    let addr = serve_sim(&el, None);
    // bad JSON body
    let (status, body) = post_generate(addr, "this is not json");
    assert_eq!(status, 400, "{}", body);
    // missing prompt
    let (status, _) = post_generate(addr, r#"{"max_tokens":4}"#);
    assert_eq!(status, 400);
    // garbage request line
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"NONSENSE\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 400"), "{}", resp);
    // oversized declared body
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"POST /generate HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 413"), "{}", resp);
    // unknown path still routes
    let (status, _) = get(addr, "/nope");
    assert_eq!(status, 404);
    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body, "ok");
    el.shutdown();
}

// ---------------------------------------------------------------- router tier

/// Serve an arbitrary router implementation on an OS-assigned port.
fn serve_router<R: Router + 'static>(
    router: R,
    max_requests: Option<usize>,
) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    thread::spawn(move || {
        serve_listener(listener, router, ServeOptions { max_requests, ..Default::default() })
            .unwrap();
    });
    addr
}

#[test]
fn router_single_replica_is_bit_identical_to_bare_submitter() {
    // The same deterministic backend behind both seams: a bare
    // `Submitter` (the pre-router path) and a `SingleRouter` wrapping an
    // identical replica. Every byte on the wire must match.
    let bare = spawn_sim_loop(0, 8);
    let routed = spawn_sim_loop(0, 8);
    let addr_a = serve_sim(&bare, None);
    let addr_b = serve_router(SingleRouter::new(routed.submitter()), None);
    for i in 0..3 {
        let body = format!(r#"{{"prompt":"bit identity {} ","max_tokens":12}}"#, i);
        let (status_a, body_a) = post_generate(addr_a, &body);
        let (status_b, body_b) = post_generate(addr_b, &body);
        assert_eq!(status_a, 200, "{}", body_a);
        assert_eq!(
            (status_a, &body_a),
            (status_b, &body_b),
            "single-replica router changed the wire format"
        );
    }
    assert_eq!(get(addr_a, "/healthz"), (200, "ok".to_string()));
    assert_eq!(get(addr_b, "/healthz"), (200, "ok".to_string()));
    bare.shutdown();
    routed.shutdown();
}

#[test]
fn router_affinity_concentrates_retained_hits_round_robin_spreads_them() {
    let prompt = "the shared system preamble that every single request repeats verbatim ";
    let run = |router: &dyn Router| {
        for _ in 0..6 {
            let h = router.submit(Request::from_text(0, prompt, 2)).unwrap();
            h.wait().expect("request completes");
        }
    };

    // kv-aware: after the first dispatch records the boundary hashes,
    // every repeat follows them to the replica retaining the prefix.
    let (a, b) = (spawn_retained_loop(), spawn_retained_loop());
    let (sa, sb) = (a.submitter(), b.submitter());
    let kv = KvAwareRouter::new(
        vec![sa.clone(), sb.clone()],
        KvRouterConfig { page_size: 4, ..Default::default() },
    );
    run(&kv);
    let (stats_a, stats_b) = (sa.engine_stats().unwrap(), sb.engine_stats().unwrap());
    let kv_hits = [stats_a.kv_retained_hits, stats_b.kv_retained_hits];
    let kv_saved = stats_a.prefill_tokens_saved + stats_b.prefill_tokens_saved;
    assert!(kv_hits.iter().sum::<u64>() > 0, "retained tier never hit: {:?}", kv_hits);
    assert_eq!(
        kv_hits.iter().filter(|&&h| h > 0).count(),
        1,
        "kv-aware routing must concentrate retained hits on one replica: {:?}",
        kv_hits
    );
    let counters = kv.counters();
    assert!(counters.affinity_hits > 0, "no affinity hits recorded: {:?}", counters);
    a.shutdown();
    b.shutdown();

    // round-robin ablation: the same workload alternates replicas, so
    // the retained hits split and the total prefill saving drops.
    let (a, b) = (spawn_retained_loop(), spawn_retained_loop());
    let (sa, sb) = (a.submitter(), b.submitter());
    let rr = RoundRobinRouter::new(vec![sa.clone(), sb.clone()]);
    run(&rr);
    let (stats_a, stats_b) = (sa.engine_stats().unwrap(), sb.engine_stats().unwrap());
    let rr_saved = stats_a.prefill_tokens_saved + stats_b.prefill_tokens_saved;
    assert!(
        stats_a.kv_retained_hits > 0 && stats_b.kv_retained_hits > 0,
        "round-robin should spread the repeats across both replicas: {} / {}",
        stats_a.kv_retained_hits,
        stats_b.kv_retained_hits
    );
    assert!(
        rr_saved < kv_saved,
        "prefix affinity must out-save round-robin: rr {} vs kv {}",
        rr_saved,
        kv_saved
    );
    a.shutdown();
    b.shutdown();
}

#[test]
fn router_metrics_report_per_replica_gauges_over_http() {
    let (a, b) = (spawn_retained_loop(), spawn_retained_loop());
    let router = KvAwareRouter::new(
        vec![a.submitter(), b.submitter()],
        KvRouterConfig { page_size: 4, ..Default::default() },
    );
    let addr = serve_router(router, None);
    let (status, body) =
        post_generate(addr, r#"{"prompt":"router metrics probe ","max_tokens":4}"#);
    assert_eq!(status, 200, "{}", body);
    let (status, body) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(body.starts_with("router=kv replicas=2 alive=2"), "{}", body);
    for label in ["replica0", "replica1", "affinity_hits=", "affinity_misses="] {
        assert!(body.contains(label), "missing {} in {}", label, body);
    }
    assert_eq!(get(addr, "/healthz"), (200, "ok".to_string()));
    a.shutdown();
    b.shutdown();
}

#[test]
fn router_drain_fans_out_to_every_replica() {
    let (a, b) = (spawn_sim_loop(0, 8), spawn_sim_loop(0, 8));
    let (sa, sb) = (a.submitter(), b.submitter());
    let router = KvAwareRouter::new(
        vec![sa.clone(), sb.clone()],
        KvRouterConfig { page_size: 4, ..Default::default() },
    );
    Router::drain(&router, Duration::from_secs(5));
    assert!(matches!(sa.submit_text("late a ", 2), Err(SubmitError::Draining)));
    assert!(matches!(sb.submit_text("late b ", 2), Err(SubmitError::Draining)));
    a.shutdown();
    b.shutdown();
}
