//! Scheduler + server integration over the *real* engine: continuous
//! batching, request lifecycle invariants, and the HTTP edge end-to-end.
//!
//! These tests need compiled artifacts plus a native PJRT client; on
//! hosts without them (e.g. the stub `xla` backend) they skip with a
//! note instead of failing — the artifact-free serving tests live in
//! `tests/serving_api.rs` and always run.

use std::io::{Read, Write};
use std::net::TcpStream;

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::coordinator::engine_loop::{EngineLoop, LoopConfig};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig, StepEvent};
use freekv::coordinator::tokenizer;
use freekv::runtime::Runtime;
use freekv::server::ServeOptions;
use freekv::util::json::Json;

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn scheduler_with(scfg: SchedulerConfig) -> Option<Scheduler> {
    let rt = freekv::runtime::load_or_skip(artifacts_dir())?;
    let eng = Engine::new(rt, "tiny", FreeKvParams { tau: 0.9, ..Default::default() }).ok()?;
    Some(Scheduler::new(eng, scfg))
}

fn scheduler() -> Option<Scheduler> {
    scheduler_with(SchedulerConfig { max_batch: 4, admit_below: 4, ..Default::default() })
}

#[test]
fn continuous_batching_completes_all_requests() {
    let Some(mut sched) = scheduler() else { return };
    let n = 6;
    for i in 0..n {
        let mut req = Request::from_text(i as u64 + 1, "hello freekv batching ", 10 + i);
        req.sample = SampleParams { temperature: 0.7, top_p: 0.9, seed: i as u64 };
        sched.submit(req);
    }
    let mut token_events = 0usize;
    let mut finished = Vec::new();
    while sched.pending() > 0 {
        for ev in sched.tick().unwrap() {
            match ev {
                StepEvent::Token { .. } => token_events += 1,
                StepEvent::Finished { id } => finished.push(id),
                StepEvent::Failed { id, error } => panic!("req {} failed: {}", id, error),
            }
        }
    }
    finished.sort_unstable();
    finished.dedup();
    assert_eq!(finished.len(), n);
    let mut total_tokens = 0usize;
    for id in 1..=n as u64 {
        let c = sched.take_completion(id).expect("completion claimable once");
        assert!(c.generated_tokens <= 10 + (id as usize - 1));
        assert!(c.generated_tokens >= 1);
        total_tokens += c.generated_tokens;
        assert!(sched.take_completion(id).is_none());
    }
    assert_eq!(token_events, total_tokens, "one Token event per sampled token");
    assert_eq!(sched.metrics.completed, n as u64);
    assert!(sched.metrics.throughput_tok_s() > 0.0);
    assert_eq!(sched.metrics.ttft.count(), n as u64);
    assert_eq!(sched.pending(), 0);
    assert_eq!(sched.running_kv_bytes(), 0);
}

#[test]
fn batched_and_sequential_scheduling_agree_for_greedy() {
    // One greedy request must produce identical text whether it runs
    // alone or interleaved with other requests (isolation invariant).
    let prompt = "determinism check: ";
    let solo = {
        let Some(mut sched) = scheduler() else { return };
        sched.submit(Request::from_text(1, prompt, 12));
        sched.drain().unwrap();
        sched.take_completion(1).unwrap().text
    };
    let batched = {
        let Some(mut sched) = scheduler() else { return };
        sched.submit(Request::from_text(1, prompt, 12));
        for i in 2..5 {
            let mut r = Request::from_text(i, "interference traffic ", 12);
            r.sample = SampleParams { temperature: 1.0, top_p: 0.9, seed: i };
            sched.submit(r);
        }
        sched.drain().unwrap();
        sched.take_completion(1).unwrap().text
    };
    assert_eq!(solo, batched);
}

#[test]
fn microbatched_real_decode_matches_bucketed_scheduling() {
    // Decode buckets top out at 4, so a running set of 6 can only be
    // served jointly by rotating 4-deep batches — or, with
    // microbatching, by splitting into two 3-wide lanes per tick
    // (decode_step_lanes). Per-lane computation is independent, so
    // every request must generate the same greedy text either way.
    let run = |max_batch: usize, microbatch_min: usize| -> Option<Vec<String>> {
        let mut sched = scheduler_with(SchedulerConfig {
            max_batch,
            admit_below: 6,
            microbatch_min,
            ..Default::default()
        })?;
        for i in 1..=6u64 {
            // distinct prompts so per-lane results are distinguishable
            sched.submit(Request::from_text(i, &format!("microbatch real engine {} ", i), 8));
        }
        sched.drain().unwrap();
        Some((1..=6u64).map(|i| sched.take_completion(i).unwrap().text).collect())
    };
    // baseline: joint 4-deep batches, no splitting
    let Some(joint) = run(4, 0) else { return };
    // microbatched: 6-deep decode set split into two pipelined lanes
    let Some(split) = run(8, 2) else { return };
    assert_eq!(joint, split, "microbatched decode diverged from bucketed scheduling");
    // and the lane path genuinely ran (joint bucket for 6 doesn't
    // exist, so the engine cannot have merged the lanes)
    let mut sched = scheduler_with(SchedulerConfig {
        max_batch: 8,
        admit_below: 6,
        microbatch_min: 2,
        ..Default::default()
    })
    .expect("backend available");
    for i in 1..=6u64 {
        sched.submit(Request::from_text(i, &format!("count the lane sets {} ", i), 6));
    }
    sched.drain().unwrap();
    assert!(
        sched.engine.stats().lane_sets > 0,
        "running set of 6 never took the lane path"
    );
}

#[test]
fn three_lane_real_scheduling_matches_bucketed_and_overlaps_prefill() {
    // Nine concurrent requests exceed two full decode buckets, so the
    // lane planner runs three lanes per tick; results must match the
    // rotating joint-batch baseline, and — because the pooled engine
    // prefills in chunks — some prefill work must complete while decode
    // lanes are in flight (the EngineStats overlap proof).
    let run = |max_batch: usize,
               microbatch_min: usize,
               max_lanes: usize|
     -> Option<(Vec<String>, u64, u64)> {
        let rt = freekv::runtime::load_or_skip(artifacts_dir())?;
        let eng = Engine::new(
            rt,
            "tiny",
            FreeKvParams { tau: 0.9, max_lanes, ..Default::default() },
        )
        .ok()?;
        let mut sched = Scheduler::new(
            eng,
            SchedulerConfig {
                max_batch,
                admit_below: 9,
                microbatch_min,
                max_lanes,
                ..Default::default()
            },
        );
        for i in 1..=9u64 {
            sched.submit(Request::from_text(i, &format!("nine lanes {} ", i), 6));
        }
        sched.drain().unwrap();
        let texts: Vec<String> =
            (1..=9u64).map(|i| sched.take_completion(i).unwrap().text).collect();
        let st = sched.engine.stats();
        Some((texts, st.lane_sets, st.prefill_overlap_chunks))
    };
    let Some((joint, _, _)) = run(4, 0, 2) else { return };
    let Some((split, lane_sets, overlap_chunks)) = run(9, 2, 3) else { return };
    assert_eq!(joint, split, "three-lane scheduling diverged from bucketed scheduling");
    assert!(lane_sets > 0, "9-deep running set never took the lane path");
    assert!(overlap_chunks > 0, "no prefill chunk completed under in-flight decode lanes");
}

#[test]
fn kv_lock_layouts_produce_identical_text_through_the_scheduler() {
    // `--kv-lock` is a pure synchronization change: the same
    // shared-prompt sampled workload through a global-lock and a
    // sharded-lock allocator must complete with identical texts and
    // identical non-timing pool gauges. Lock wait counters are
    // timing-dependent and deliberately excluded from the comparison.
    let run = |lock: freekv::kvcache::KvLockMode| -> Option<(Vec<String>, (u64, u64))> {
        let rt = freekv::runtime::load_or_skip(artifacts_dir())?;
        let eng = Engine::new(
            rt,
            "tiny",
            FreeKvParams {
                tau: 0.9,
                prefix_cache: freekv::kvcache::PrefixCacheMode::Resident,
                kv_lock: lock,
                ..Default::default()
            },
        )
        .ok()?;
        let mut sched = Scheduler::new(
            eng,
            SchedulerConfig { max_batch: 4, admit_below: 4, ..Default::default() },
        );
        for i in 1..=6u64 {
            let mut r = Request::from_text(i, "the shared prompt every lock layout sees ", 10);
            r.sample = SampleParams { temperature: 0.8, top_p: 0.9, seed: i };
            sched.submit(r);
        }
        sched.drain().unwrap();
        let texts: Vec<String> =
            (1..=6u64).map(|i| sched.take_completion(i).unwrap().text).collect();
        let st = sched.engine.kv_pool_stats();
        Some((texts, (st.pages_peak, st.prefix_hits)))
    };
    let Some(global) = run(freekv::kvcache::KvLockMode::Global) else {
        eprintln!("artifacts/ missing — skipping kv-lock scheduler equivalence test");
        return;
    };
    let sharded = run(freekv::kvcache::KvLockMode::Sharded).expect("backend available");
    assert_eq!(global.0, sharded.0, "kv-lock layout changed generated text");
    assert_eq!(global.1, sharded.1, "non-timing pool gauges diverged across lock layouts");
    assert!(sharded.1 .1 > 0, "identical prompts must hit the prefix cache");
}

#[test]
fn cancel_mid_generation_frees_kv_on_the_real_engine() {
    let Some(mut sched) = scheduler() else { return };
    sched.submit(Request::from_text(1, "cancel on the real engine ", 64));
    sched.submit(Request::from_text(2, "and keep this one ", 8));
    for _ in 0..3 {
        sched.tick().unwrap();
    }
    assert_eq!(sched.running_len(), 2);
    let with_two = sched.running_kv_bytes();
    assert!(sched.cancel(1), "mid-flight cancel");
    assert!(sched.running_kv_bytes() < with_two, "cancelled KV released");
    let c = sched.take_completion(1).unwrap();
    assert!(c.generated_tokens >= 1);
    sched.drain().unwrap();
    assert!(sched.take_completion(2).is_some());
    assert_eq!(sched.running_kv_bytes(), 0, "all KV back to baseline");
}

#[test]
fn http_server_generates_over_the_wire() {
    // The engine is constructed on the loop thread (the PJRT runtime is
    // deliberately single-threaded); spawning fails cleanly without
    // artifacts.
    let el = match EngineLoop::spawn(LoopConfig::default(), || {
        let rt = Runtime::load(artifacts_dir())?;
        let eng = Engine::new(rt, "tiny", FreeKvParams { tau: 0.9, ..Default::default() })?;
        Ok(Scheduler::new(
            eng,
            SchedulerConfig { max_batch: 4, admit_below: 4, ..Default::default() },
        ))
    }) {
        Ok(el) => el,
        Err(e) => {
            // same skip-or-hard-fail contract as runtime::load_or_skip
            let _ = freekv::runtime::require_or_skip::<()>(Err(e));
            return;
        }
    };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sub = el.submitter();
    let h = std::thread::spawn(move || {
        freekv::server::serve_listener(
            listener,
            sub,
            ServeOptions { max_requests: Some(2), ..Default::default() },
        )
        .unwrap();
    });

    let call = |body: &str| -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let (head, body) = resp.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    };

    let (head, body) = call(r#"{"prompt":"over the wire ","max_tokens":8}"#);
    assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("generated").as_usize().unwrap() >= 1);
    assert!(j.get("text").as_str().is_some());
    let reason = j.get("finish_reason").as_str().unwrap();
    assert!(reason == "length" || reason == "eos", "{}", reason);

    let (head2, _) = call(r#"{"prompt":"second request","max_tokens":4}"#);
    assert!(head2.starts_with("HTTP/1.1 200"));
    h.join().unwrap();
    el.shutdown();
    let _ = tokenizer::VOCAB;
}
