//! Scheduler + server integration: continuous batching over the real
//! engine, request lifecycle invariants, and the HTTP edge end-to-end.

use std::io::{Read, Write};
use std::net::TcpStream;

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Engine, SampleParams};
use freekv::coordinator::scheduler::{Request, Scheduler, SchedulerConfig};
use freekv::coordinator::tokenizer;
use freekv::runtime::Runtime;
use freekv::util::json::Json;

fn scheduler() -> Scheduler {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let rt = Runtime::load(dir).expect("run `make artifacts` first");
    let eng = Engine::new(rt, "tiny", FreeKvParams { tau: 0.9, ..Default::default() }).unwrap();
    Scheduler::new(eng, SchedulerConfig { max_batch: 4, admit_below: 4 })
}

#[test]
fn continuous_batching_completes_all_requests() {
    let mut sched = scheduler();
    let n = 6;
    for i in 0..n {
        let mut req = Request::from_text(i as u64 + 1, "hello freekv batching ", 10 + i);
        req.sample = SampleParams { temperature: 0.7, top_p: 0.9, seed: i as u64 };
        sched.submit(req);
    }
    sched.drain().unwrap();
    assert_eq!(sched.completions.len(), n);
    // each request got exactly its token budget (no EOS in random model
    // is unlikely but possible; allow <=)
    for c in &sched.completions {
        assert!(c.generated_tokens <= 10 + (c.id as usize - 1));
        assert!(c.generated_tokens >= 1);
    }
    // ids unique
    let mut ids: Vec<u64> = sched.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    assert_eq!(sched.metrics.completed, n as u64);
    assert!(sched.metrics.throughput_tok_s() > 0.0);
    assert_eq!(sched.pending(), 0);
}

#[test]
fn batched_and_sequential_scheduling_agree_for_greedy() {
    // One greedy request must produce identical text whether it runs
    // alone or interleaved with other requests (isolation invariant).
    let prompt = "determinism check: ";
    let solo = {
        let mut sched = scheduler();
        sched.submit(Request::from_text(1, prompt, 12));
        sched.drain().unwrap();
        sched.completions[0].text.clone()
    };
    let batched = {
        let mut sched = scheduler();
        sched.submit(Request::from_text(1, prompt, 12));
        for i in 2..5 {
            let mut r = Request::from_text(i, "interference traffic ", 12);
            r.sample = SampleParams { temperature: 1.0, top_p: 0.9, seed: i };
            sched.submit(r);
        }
        sched.drain().unwrap();
        sched.completions.iter().find(|c| c.id == 1).unwrap().text.clone()
    };
    assert_eq!(solo, batched);
}

#[test]
fn http_server_generates_over_the_wire() {
    // pick a free port by binding then dropping
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{}", port);
    let addr2 = addr.clone();
    // The PJRT runtime is deliberately single-threaded (Rc everywhere),
    // so the engine thread constructs its own scheduler.
    let h = std::thread::spawn(move || {
        let sched = scheduler();
        freekv::server::serve(sched, &addr2, Some(2)).unwrap();
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let call = |body: &str| -> (String, String) {
        let mut s = TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let (head, body) = resp.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    };

    let (head, body) = call(r#"{"prompt":"over the wire ","max_tokens":8}"#);
    assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("generated").as_usize().unwrap() >= 1);
    assert!(j.get("text").as_str().is_some());

    let (head2, _) = call(r#"{"prompt":"second request","max_tokens":4}"#);
    assert!(head2.starts_with("HTTP/1.1 200"));
    h.join().unwrap();
    let _ = tokenizer::VOCAB;
}
