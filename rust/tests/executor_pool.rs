//! Executor-pool mechanics, artifact-free: the pool is generic over
//! `ExecBackend`, so scheduling, result routing, panic containment,
//! shutdown/drain, and the failure ladder (retry once → route around a
//! dead worker → respawn → degrade to failed tickets) are all testable
//! with host-side backends on any host. The PJRT-backed equivalence
//! tests (pooled selection bit-identical to serial dispatch on the real
//! engine) live in `tests/overlap_pipeline.rs`.

// Tests may use bare `Mutex::lock().unwrap()`; the disallowed-methods
// lint (clippy.toml) polices src/, where poisoning must be *handled*.
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};
use freekv::runtime::{ExecBackend, ExecCounters, ExecJob, ExecTicket, ExecutorPool, HostTensor};
use freekv::util::fault::{FaultPlan, FaultSite};

/// Deterministic host backend: output = inputs scaled by (layer + 2);
/// artifact names trigger special behaviour (`panic!`, error, sleep).
struct HostBackend {
    worker: usize,
    delay: Duration,
}

impl ExecBackend for HostBackend {
    fn run(
        &mut self,
        name: &str,
        args: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        match name {
            "explode" => panic!("deliberate panic on worker {}", self.worker),
            "fail" => Err(anyhow!("deliberate failure")),
            _ => {
                let k = (layer.unwrap_or(0) + 2) as f32;
                Ok(args
                    .iter()
                    .map(|t| match t {
                        HostTensor::F32(d, s) => {
                            HostTensor::F32(d.iter().map(|x| x * k).collect(), s.clone())
                        }
                        HostTensor::I32(d, s) => HostTensor::I32(d.clone(), s.clone()),
                    })
                    .collect())
            }
        }
    }
}

fn pool(workers: usize, delay_ms: u64) -> ExecutorPool {
    ExecutorPool::spawn(workers, move |worker| {
        Ok(HostBackend { worker, delay: Duration::from_millis(delay_ms) })
    })
    .expect("host pool spawns")
}

fn f32s(v: &[f32]) -> HostTensor {
    HostTensor::F32(v.to_vec(), vec![v.len()])
}

fn job(i: usize) -> ExecJob {
    ExecJob::Raw { name: format!("job{}", i), layer: Some(i), args: vec![f32s(&[i as f32, 1.0])] }
}

fn expected(i: usize) -> Vec<HostTensor> {
    let k = (i + 2) as f32;
    vec![f32s(&[i as f32 * k, k])]
}

#[test]
fn pooled_results_match_inline_execution_joined_out_of_order() {
    // Reference: execute every job inline on one backend.
    let mut inline = HostBackend { worker: 0, delay: Duration::ZERO };
    let reference: Vec<Vec<HostTensor>> = (0..24)
        .map(|i| {
            let (name, layer, args) = job(i).into_parts();
            inline.run(&name, &args, layer).unwrap()
        })
        .collect();

    // Pool: submit everything, join in reverse order.
    let p = pool(4, 0);
    let tickets: Vec<ExecTicket> = (0..24).map(|i| p.submit(job(i))).collect();
    let mut results: Vec<Option<Vec<HostTensor>>> = (0..24).map(|_| None).collect();
    for (i, t) in tickets.into_iter().enumerate().rev() {
        let done = t.wait().unwrap();
        assert_eq!(done.inputs, vec![f32s(&[i as f32, 1.0])], "inputs returned for reuse");
        assert!(done.worker < 4);
        results[i] = Some(done.outputs);
    }
    for (i, r) in results.into_iter().enumerate() {
        assert_eq!(r.unwrap(), reference[i], "job {} diverged from inline execution", i);
        assert_eq!(reference[i], expected(i));
    }
    assert_eq!(p.jobs_submitted(), 24);
}

#[test]
fn panic_in_worker_propagates_to_the_ticket_and_pool_survives() {
    // Single worker so the panicking job and the follow-up share one
    // backend: the catch_unwind must leave the worker serving.
    let p = pool(1, 0);
    let bad = p.submit(ExecJob::Raw { name: "explode".into(), layer: None, args: vec![] });
    let err = bad.wait().expect_err("panic must surface as an error");
    let msg = format!("{err:#}");
    assert!(msg.contains("panic") && msg.contains("explode"), "{}", msg);

    // Plain execution errors are distinguishable from panics.
    let failing = p.submit(ExecJob::Raw { name: "fail".into(), layer: None, args: vec![] });
    let err = format!("{:#}", failing.wait().unwrap_err());
    assert!(err.contains("deliberate failure"), "{}", err);

    // The worker survived both: a normal job still completes.
    let ok = p.submit(job(3)).wait().unwrap();
    assert_eq!(ok.outputs, expected(3));
}

#[test]
fn worker_startup_failure_aborts_spawn_cleanly() {
    let attempts = Arc::new(AtomicUsize::new(0));
    let seen = attempts.clone();
    let err = ExecutorPool::spawn(3, move |worker| {
        seen.fetch_add(1, Ordering::SeqCst);
        if worker == 2 {
            Err(anyhow!("backend unavailable on worker 2"))
        } else {
            Ok(HostBackend { worker, delay: Duration::ZERO })
        }
    })
    .map(|_| ())
    .expect_err("pool with a failing worker must not spawn");
    let msg = format!("{err:#}");
    assert!(msg.contains("backend unavailable on worker 2"), "{}", msg);
    assert_eq!(attempts.load(Ordering::SeqCst), 3, "every worker ran its factory");
}

#[test]
fn drop_drains_queued_jobs_without_leaking_tickets() {
    // More slow jobs than workers, then drop the pool immediately: every
    // already-submitted job must still execute and resolve its ticket
    // (drain-on-shutdown), and the drop must block until workers finish.
    let tickets: Vec<ExecTicket> = {
        let p = pool(2, 5);
        (0..10).map(|i| p.submit(job(i))).collect()
        // `p` drops here: queue closes, workers drain, threads join.
    };
    for (i, t) in tickets.into_iter().enumerate() {
        let done = t.wait().expect("queued job resolved after shutdown");
        assert_eq!(done.outputs, expected(i));
    }
}

#[test]
fn warmup_broadcast_resolves_per_worker() {
    // One warm job per worker, all awaited; HostBackend's default
    // warmup is a no-op, so this covers routing + completion shape.
    let p = pool(3, 0);
    let warmed = p.warmup("tiny").expect("warmup jobs resolve");
    assert_eq!(warmed, 3);
    assert_eq!(p.jobs_submitted(), 3);
    // pool still serves normal jobs afterwards
    assert_eq!(p.submit(job(1)).wait().unwrap().outputs, expected(1));
}

#[test]
fn weight_routing_confines_weight_jobs_and_uploads() {
    // A backend that "uploads weights" the first time it executes a
    // weight-bearing job: with 4 workers and 1 weight worker, every
    // weight job must land on worker 0 and exactly one upload happens
    // pool-wide, no matter how many workers exist.
    struct Counting {
        runs: u64,
        uploaded: bool,
    }
    impl ExecBackend for Counting {
        fn run(
            &mut self,
            name: &str,
            args: &[HostTensor],
            _layer: Option<usize>,
        ) -> Result<Vec<HostTensor>> {
            self.runs += 1;
            if name.starts_with('w') {
                self.uploaded = true;
            }
            Ok(args.to_vec())
        }
        fn counters(&self) -> ExecCounters {
            ExecCounters { compiled: self.runs, weight_uploads: u64::from(self.uploaded) }
        }
    }
    let p = ExecutorPool::spawn_routed(4, 1, |_| Ok(Counting { runs: 0, uploaded: false }))
        .expect("routed pool spawns");
    assert_eq!(p.weight_workers(), 1);
    let weight: Vec<ExecTicket> = (0..6)
        .map(|i| {
            p.submit(ExecJob::Qkv { name: format!("w{}", i), layer: 0, args: vec![f32s(&[1.0])] })
        })
        .collect();
    let free: Vec<ExecTicket> = (0..6)
        .map(|i| p.submit(ExecJob::Selection { name: format!("s{}", i), args: vec![f32s(&[1.0])] }))
        .collect();
    for t in weight {
        assert_eq!(t.wait().unwrap().worker, 0, "weight job escaped the weight worker");
    }
    for t in free {
        assert!(t.wait().unwrap().worker < 4);
    }
    let c = p.counters();
    assert_eq!(c.weight_uploads, 1, "exactly one worker uploaded weights");
    assert_eq!(c.compiled, 12, "every executed job was counted");
}

#[test]
fn route_aware_warmup_filters_non_weight_workers() {
    use std::sync::Mutex;
    // Warm-up must reach every worker, with weight_free_only set
    // exactly on the workers that can never be routed a weight job.
    struct Warming {
        worker: usize,
        seen: Arc<Mutex<Vec<(usize, bool)>>>,
    }
    impl ExecBackend for Warming {
        fn run(
            &mut self,
            _name: &str,
            args: &[HostTensor],
            _layer: Option<usize>,
        ) -> Result<Vec<HostTensor>> {
            Ok(args.to_vec())
        }
        fn warmup(&mut self, _config: &str, weight_free_only: bool) -> Result<usize> {
            self.seen.lock().unwrap().push((self.worker, weight_free_only));
            Ok(0)
        }
    }
    let seen: Arc<Mutex<Vec<(usize, bool)>>> = Arc::new(Mutex::new(Vec::new()));
    let record = seen.clone();
    let p = ExecutorPool::spawn_routed(3, 1, move |worker| {
        Ok(Warming { worker, seen: record.clone() })
    })
    .expect("routed pool spawns");
    assert_eq!(p.warmup("tiny").expect("warmup resolves"), 3);
    let mut got = seen.lock().unwrap().clone();
    got.sort_unstable();
    assert_eq!(
        got,
        vec![(0, false), (1, true), (2, true)],
        "weight worker warms everything; the rest warm weight-free only"
    );
}

#[test]
fn injected_transient_error_is_retried_once_and_succeeds() {
    let p = pool(1, 0);
    p.set_faults(Arc::new(FaultPlan::events(&[(FaultSite::ExecJobError, 0)])));
    let done = p.submit(job(2)).wait().expect("transient failure absorbed by the retry");
    assert_eq!(done.outputs, expected(2));
    assert_eq!(p.health().retries, 1, "the retry was counted");
}

#[test]
fn back_to_back_injected_errors_surface_with_retry_context() {
    let p = pool(1, 0);
    // Both the attempt and its one retry fail: the ticket error must
    // carry the first failure too, so operators see it was persistent.
    p.set_faults(Arc::new(FaultPlan::events(&[
        (FaultSite::ExecJobError, 0),
        (FaultSite::ExecJobError, 1),
    ])));
    let err = format!("{:#}", p.submit(job(2)).wait().unwrap_err());
    assert!(err.contains("after one retry"), "{}", err);
    assert!(err.contains("injected transient failure"), "{}", err);
    assert_eq!(p.health().retries, 1, "exactly one retry, not a loop");
    // the worker is unharmed: the next job executes first-try
    assert_eq!(p.submit(job(3)).wait().unwrap().outputs, expected(3));
}

#[test]
fn injected_worker_death_resolves_every_queued_ticket_then_respawns() {
    // Slow single worker: jobs 1..4 queue behind job 0; the worker dies
    // picking up job 1 and must drain the queue with errors — a ticket
    // to a dead worker never blocks.
    let p = pool(1, 20);
    p.set_faults(Arc::new(FaultPlan::events(&[(FaultSite::ExecWorkerDeath, 1)])));
    let tickets: Vec<ExecTicket> = (0..4).map(|i| p.submit(job(i))).collect();
    let mut outcomes = tickets.into_iter().map(|t| t.wait());
    let first = outcomes.next().unwrap().expect("job before the death completes");
    assert_eq!(first.outputs, expected(0));
    for (i, r) in outcomes.enumerate() {
        let err = format!("{:#}", r.expect_err("jobs behind the death fail, never block"));
        assert!(err.contains("died") || err.contains("shut down"), "job {}: {}", i + 1, err);
    }
    assert_eq!(p.health().alive, 0, "routing sees the worker as dead");
    // The next submission revives the slot in place (same index).
    let done = p.submit(job(7)).wait().expect("respawned worker serves");
    assert_eq!(done.outputs, expected(7));
    let h = p.health();
    assert_eq!((h.alive, h.respawns), (1, 1), "{:?}", h);
}

#[test]
fn respawn_budget_exhaustion_degrades_to_failed_tickets_and_drop_does_not_hang() {
    // The worker dies on every job it ever receives: the first death is
    // free, the next two submissions each spend one unit of the respawn
    // budget, and after that the pool degrades — submissions return
    // already-failed tickets, ready_for() says inline, drop still joins.
    let p = pool(1, 0);
    p.set_faults(Arc::new(FaultPlan::events(&[
        (FaultSite::ExecWorkerDeath, 0),
        (FaultSite::ExecWorkerDeath, 1),
        (FaultSite::ExecWorkerDeath, 2),
    ])));
    for i in 0..3usize {
        let err = format!("{:#}", p.submit(job(i)).wait().unwrap_err());
        assert!(err.contains("died (injected fault)"), "death {}: {}", i, err);
    }
    let h = p.health();
    assert_eq!((h.alive, h.respawns), (0, 2), "{:?}", h);
    assert!(!p.ready_for(&job(9)), "engine's cue to execute inline");
    let err = format!("{:#}", p.submit(job(9)).wait().unwrap_err());
    assert!(err.contains("respawn budget exhausted"), "{}", err);
    // Dropping a pool whose only worker is dead must not hang: its
    // JoinHandle resolves immediately. (A hang fails via test timeout.)
    drop(p);
}

#[test]
fn handles_submit_from_other_threads() {
    let p = pool(2, 0);
    let h = p.handle();
    let t = std::thread::spawn(move || {
        let tickets: Vec<ExecTicket> = (0..8).map(|i| h.submit(job(i))).collect();
        tickets
            .into_iter()
            .enumerate()
            .map(|(i, t)| (i, t.wait().unwrap().outputs))
            .collect::<Vec<_>>()
        // the cloned handle drops with this thread, releasing the queue
    });
    for (i, out) in t.join().unwrap() {
        assert_eq!(out, expected(i));
    }
    assert_eq!(p.jobs_submitted(), 8);
}
