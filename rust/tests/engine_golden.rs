//! End-to-end engine correctness: the rust decode pipeline (prefill +
//! paged decode with speculative retrieval) must reproduce the python
//! reference model's greedy generation (artifacts/golden_tiny.json) while
//! the budget covers the whole context, and stay numerically close on
//! the final logits.

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{sample_token, Engine, SampleParams};
use freekv::util::json::Json;

/// Engine over the real backend, or a skip (hard failure when the CI
/// real-backend job sets FREEKV_REQUIRE_ARTIFACTS).
fn engine() -> Option<Engine> {
    let rt = freekv::runtime::load_or_skip(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    Some(Engine::new(rt, "tiny", FreeKvParams::default()).unwrap())
}

fn golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden_tiny.json");
    Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

#[test]
fn reproduces_golden_greedy_trace() {
    let Some(mut eng) = engine() else { return };
    let g = golden();
    let prompt: Vec<i32> = g.get("prompt").as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();
    let want: Vec<i32> =
        g.get("generated").as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();
    let final_logits: Vec<f32> = g
        .get("final_logits")
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();

    let mut seq = eng.new_sequence(1, prompt.clone(), want.len(), SampleParams::greedy());
    let lg = eng.prefill(&mut seq).unwrap();
    let mut toks = vec![sample_token(&lg, &SampleParams::greedy(), &mut seq.rng.clone())];
    seq.tokens.push(toks[0]);
    let mut last_logits = lg;
    while seq.generated().len() < want.len() {
        let mut batch = [&mut seq];
        eng.decode_step(&mut batch).unwrap();
        toks.push(*seq.tokens.last().unwrap());
        let _ = &mut last_logits;
    }
    assert_eq!(toks, want, "greedy token trace diverged from python reference");

    // Re-derive final-step logits by checking the last generated token is
    // the argmax of the reference final logits.
    let ref_argmax = final_logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    assert_eq!(*toks.last().unwrap(), ref_argmax);
}

#[test]
fn speculative_and_blocking_agree_when_budget_covers_context() {
    // With the whole context resident, speculation cannot lose pages, so
    // both modes must produce identical tokens.
    if engine().is_none() {
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g.get("prompt").as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();

    let run = |blocking: bool| -> Vec<i32> {
        let mut eng = engine().expect("backend available");
        eng.blocking_mode = blocking;
        let mut seq = eng.new_sequence(7, prompt.clone(), 6, SampleParams::greedy());
        eng.generate(&mut seq).unwrap();
        seq.generated().to_vec()
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn long_generation_exceeding_budget_stays_stable() {
    // Generate past the GPU budget (tiny budget = 512 slots): pages get
    // offloaded and recalled; tokens must stay in-vocab and the engine
    // must report selection/recall activity.
    let Some(mut eng) = engine() else { return };
    let prompt: Vec<i32> = (0..600).map(|i| (i * 7 % 256) as i32).collect();
    let mut seq = eng.new_sequence(2, prompt, 64, SampleParams { temperature: 0.8, top_p: 0.95, seed: 3 });
    eng.generate(&mut seq).unwrap();
    assert_eq!(seq.generated().len(), 64);
    assert!(seq.generated().iter().all(|&t| (0..260).contains(&t)));
    assert!(seq.xfer.counters.offloaded_pages > 0, "pages should offload");
    assert!(eng.stats.recalled_pages > 0, "selection should recall pages");
    assert!(eng.stats.correction_checks > 0);
    // speculation should mostly hit (high query similarity in practice)
    assert!(eng.stats.speculative_hits > 0);
}

#[test]
fn batched_decode_matches_single_sequence() {
    // The same prompt decoded alone and inside a padded batch must agree
    // (greedy, deterministic artifacts).
    let Some(mut eng) = engine() else { return };
    let g = golden();
    let prompt: Vec<i32> = g.get("prompt").as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect();

    let mut a = eng.new_sequence(1, prompt.clone(), 4, SampleParams::greedy());
    eng.generate(&mut a).unwrap();

    let mut eng2 = engine().expect("backend available");
    let mut s1 = eng2.new_sequence(10, prompt.clone(), 4, SampleParams::greedy());
    let mut s2 = eng2.new_sequence(11, prompt.clone(), 4, SampleParams::greedy());
    // prefill both, then batch-decode them together (bucket 4, padded)
    let lg1 = eng2.prefill(&mut s1).unwrap();
    let t1 = sample_token(&lg1, &SampleParams::greedy(), &mut s1.rng.clone());
    s1.tokens.push(t1);
    let lg2 = eng2.prefill(&mut s2).unwrap();
    let t2 = sample_token(&lg2, &SampleParams::greedy(), &mut s2.rng.clone());
    s2.tokens.push(t2);
    for _ in 0..3 {
        let mut batch = vec![&mut s1, &mut s2];
        eng2.decode_step(&mut batch).unwrap();
    }
    assert_eq!(a.generated(), s1.generated());
    assert_eq!(a.generated(), s2.generated());
}
