//! Integration smoke tests: load real artifacts, compile on the PJRT CPU
//! client, execute, and check numerics against the python-side contract.
//! Without artifacts + a native PJRT client these skip with a note; the
//! CI real-backend job sets FREEKV_REQUIRE_ARTIFACTS so a skip there is
//! a failure.

use freekv::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Runtime> {
    freekv::runtime::load_or_skip(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[test]
fn embed_then_logits_runs() {
    let Some(rt) = runtime() else { return };
    let out = rt
        .run("tiny_embed_b1", &[HostTensor::I32(vec![65], vec![1])], None)
        .unwrap();
    assert_eq!(out.len(), 1);
    let h = &out[0];
    assert_eq!(h.shape(), &[1, 256]);
    let lg = rt
        .run("tiny_logits_b1", &[h.clone()], None)
        .unwrap();
    assert_eq!(lg[0].shape(), &[1, 260]);
    let v = lg[0].f32s().unwrap();
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn embed_matches_weight_row() {
    // embed(t) must equal row t of the embedding matrix in the blob.
    let Some(rt) = runtime() else { return };
    let tok = 123usize;
    let out = rt
        .run("tiny_embed_b1", &[HostTensor::I32(vec![tok as i32], vec![1])], None)
        .unwrap();
    let h = out[0].f32s().unwrap();

    let spec = &rt.manifest.weights["tiny"];
    let ent = spec.tensors.iter().find(|t| t.name == "embed").unwrap();
    let blob = std::fs::read(rt.manifest.dir.join(&spec.file)).unwrap();
    let d = ent.shape[1];
    let start = (ent.offset + tok * d) * 4;
    let row: Vec<f32> = blob[start..start + d * 4]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    for (a, b) in h.iter().zip(&row) {
        assert!((a - b).abs() < 1e-6, "{} vs {}", a, b);
    }
}

#[test]
fn layer_qkv_shapes_and_determinism() {
    let Some(rt) = runtime() else { return };
    let h = HostTensor::F32(vec![0.1; 256], vec![1, 256]);
    let pos = HostTensor::I32(vec![7], vec![1]);
    let out1 = rt.run("tiny_layer_qkv_b1", &[h.clone(), pos.clone()], Some(0)).unwrap();
    assert_eq!(out1.len(), 3);
    assert_eq!(out1[0].shape(), &[1, 8, 32]); // q
    assert_eq!(out1[1].shape(), &[1, 2, 32]); // k_new
    assert_eq!(out1[2].shape(), &[1, 2, 32]); // v_new
    let out2 = rt.run("tiny_layer_qkv_b1", &[h, pos], Some(0)).unwrap();
    assert_eq!(out1[0], out2[0]);

    // Different layers bind different weights -> different q.
    let h = HostTensor::F32(vec![0.1; 256], vec![1, 256]);
    let pos = HostTensor::I32(vec![7], vec![1]);
    let out3 = rt.run("tiny_layer_qkv_b1", &[h, pos], Some(1)).unwrap();
    assert_ne!(out1[0], out3[0]);
}

#[test]
fn select_returns_valid_page_indices() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let p = cfg.n_pages_max();
    let (qo, m, dh, k) = (cfg.n_qo, cfg.n_kv, cfg.d_head, cfg.select_pages);
    let q = HostTensor::F32((0..qo * dh).map(|i| (i as f32 * 0.37).sin()).collect(), vec![1, qo, dh]);
    let smin = HostTensor::F32(vec![-0.5; m * p * dh], vec![1, m, p, dh]);
    let smax = HostTensor::F32(vec![0.5; m * p * dh], vec![1, m, p, dh]);
    // Only pages 4..20 selectable.
    let mut mask = vec![0.0f32; p];
    for pg in 4..20 {
        mask[pg] = 1.0;
    }
    let out = rt
        .run(
            "tiny_select_means_b1",
            &[q, smin, smax, HostTensor::F32(mask, vec![1, p])],
            None,
        )
        .unwrap();
    assert_eq!(out[0].shape(), &[1, m, p]); // scores
    assert_eq!(out[1].shape(), &[1, m, k]); // indices
    for &idx in out[1].i32s().unwrap() {
        assert!((4..20).contains(&(idx as usize)), "selected masked page {}", idx);
    }
}

#[test]
fn wrong_shape_is_rejected() {
    let Some(rt) = runtime() else { return };
    let bad = rt.run("tiny_embed_b1", &[HostTensor::I32(vec![1, 2], vec![2])], None);
    assert!(bad.is_err());
    let badty = rt.run("tiny_embed_b1", &[HostTensor::F32(vec![1.0], vec![1])], None);
    assert!(badty.is_err());
}

#[test]
fn stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let _ = rt
        .run("tiny_embed_b1", &[HostTensor::I32(vec![1], vec![1])], None)
        .unwrap();
    let st = rt.stats.borrow();
    assert!(st.executions >= 1);
    assert!(st.compiled >= 1);
    assert!(st.h2d_bytes > 0);
}
