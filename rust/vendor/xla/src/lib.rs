//! Offline stub of the PJRT/XLA binding surface used by the runtime.
//!
//! The image this repo builds in does not vendor the native XLA/PJRT
//! closure, so this crate provides the exact type-and-method surface
//! `runtime::client` compiles against. Everything type-checks; at run
//! time [`PjRtClient::cpu`] fails with a clear message, so artifact-
//! driven paths degrade into an explicit "backend unavailable" error
//! while the (much larger) pure-host portion of the crate — simulators,
//! kv-cache, transfer pipeline, policies — builds and tests everywhere.
//!
//! Replace this path dependency with the real binding crate to run the
//! AOT artifacts; no source changes in `freekv` are needed.

use std::fmt;
use std::path::Path;

/// Error type mirroring the binding crate's displayable errors.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{}: PJRT backend not vendored in this build (stub vendor/xla); \
         link the real xla crate to execute artifacts",
        what
    ))
}

/// Element types a literal/shape can carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
    Tuple,
}

/// Host-visible element types transferable to/from device buffers.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

/// Parsed HLO module text (held verbatim by the stub).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. The stub validates readability only;
    /// compilation is where the missing backend surfaces.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError(format!("reading {}: {}", path.as_ref().display(), e)))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: HloModuleProto { text: proto.text.clone() } }
    }
}

/// Device-resident buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("execute_b"))
    }
}

/// The PJRT client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// In the real binding this boots the PJRT CPU plugin; the stub
    /// reports the backend as unavailable so callers fail fast with a
    /// useful message instead of at first execution.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Array shape: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (never constructible in the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        Err(unavailable("array_shape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("to_tuple"))
    }

    pub fn size_bytes(&self) -> usize {
        0
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not produce a client");
        assert!(err.to_string().contains("not vendored"));
    }

    #[test]
    fn hlo_text_roundtrip() {
        let dir = std::env::temp_dir().join("xla_stub_test.hlo");
        std::fs::write(&dir, "HloModule test").unwrap();
        let proto = HloModuleProto::from_text_file(&dir).unwrap();
        let _comp = XlaComputation::from_proto(&proto);
        assert!(HloModuleProto::from_text_file("/definitely/missing/file.hlo").is_err());
        let _ = std::fs::remove_file(&dir);
    }
}
