//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the exact API subset the repo uses: `anyhow::Error`,
//! `anyhow::Result`, the `anyhow!` / `bail!` macros, and the `Context`
//! extension trait for `Result` and `Option`. Error values carry a
//! context chain (outermost first); `{}` prints the outermost message,
//! `{:#}` the full `a: b: c` chain, matching real anyhow's formatting.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error with a context chain (outermost message first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {}: {}", i, c)?;
            }
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as real
// anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn formatting_matches_anyhow_conventions() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{}", e), "outer");
        assert_eq!(format!("{:#}", e), "outer: middle: root");
        let dbg = format!("{:?}", e);
        assert!(dbg.contains("Caused by:"));
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{:#}", e), "reading x: missing file");
        let o: Option<u32> = None;
        let e = o.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("value {}", n);
        assert_eq!(b.to_string(), "value 3");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn question_mark_conversion() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing file");
    }
}
