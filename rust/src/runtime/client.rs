//! PJRT runtime: compile HLO-text artifacts once, bind weight buffers
//! once, execute from the decode hot path with zero python involvement.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::artifacts::{ArtifactSpec, DType, Manifest};

/// Host-side tensor passed to / returned from artifact executions.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// f32 data plus its shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data plus its shape.
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// Tensor shape, row-major.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }
    /// Borrow the f32 data (error if the tensor is i32).
    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }
    /// Borrow the i32 data (error if the tensor is f32).
    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }
    /// Take ownership of the f32 data (error if the tensor is i32).
    pub fn into_f32s(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }
}

/// Cumulative runtime counters (reported by `freekv serve --stats` and the
/// perf harness).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    /// Artifact executions completed.
    pub executions: u64,
    /// Wall-clock seconds spent inside executions.
    pub exec_secs: f64,
    /// Bytes uploaded host-to-device.
    pub h2d_bytes: u64,
    /// Bytes downloaded device-to-host.
    pub d2h_bytes: u64,
    /// Wall-clock seconds spent compiling artifacts.
    pub compile_secs: f64,
    /// Artifacts compiled (each compiles at most once).
    pub compiled: u64,
    /// Weight-blob device uploads (one per config whose weights became
    /// resident on this client). The executor pool aggregates this
    /// across workers to prove weight memory tracks `weight_workers`,
    /// not the pool size.
    pub weight_uploads: u64,
}

/// Owns the PJRT client, lazily-compiled executables, and resident weight
/// buffers for every model config in the manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The artifact manifest this runtime serves.
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// per config: tensor name -> device buffer.
    weights: RefCell<HashMap<String, Rc<HashMap<String, xla::PjRtBuffer>>>>,
    /// Cumulative execution/transfer/compile counters.
    pub stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Runtime over an already-loaded manifest, with a fresh PJRT CPU client.
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Load the manifest under `dir` and build a runtime for it.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Runtime::new(Manifest::load(dir)?)
    }

    /// Compile (or fetch cached) an artifact executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", name))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compile_secs += t0.elapsed().as_secs_f64();
            st.compiled += 1;
        }
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact of a config (avoids first-request
    /// latency spikes; used by `freekv serve --warmup`).
    pub fn warmup(&self, config: &str) -> Result<usize> {
        self.warmup_filtered(config, false)
    }

    /// Eagerly compile only the artifacts of a config that bind no
    /// weights (selection scoring). Non-weight executor-pool workers
    /// warm with this: they can never be routed a weight-bearing job,
    /// so compiling the rest would be pure waste.
    pub fn warmup_weight_free(&self, config: &str) -> Result<usize> {
        self.warmup_filtered(config, true)
    }

    fn warmup_filtered(&self, config: &str, weight_free_only: bool) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .values()
            .filter(|a| a.config == config)
            .filter(|a| !weight_free_only || !a.args.iter().any(|arg| arg.weight))
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Load the weight blob of a config into device buffers (idempotent).
    pub fn weight_buffers(&self, config: &str) -> Result<Rc<HashMap<String, xla::PjRtBuffer>>> {
        if let Some(w) = self.weights.borrow().get(config) {
            return Ok(w.clone());
        }
        let spec = self
            .manifest
            .weights
            .get(config)
            .ok_or_else(|| anyhow!("no weights for config `{}`", config))?
            .clone();
        let path = self.manifest.dir.join(&spec.file);
        // The host-side blob is shared across every runtime cloned from
        // this manifest (engine + pool workers) while readers overlap,
        // and freed once they all drop it. Device residency stays per
        // client — that is what the weight-worker routing bounds.
        let blob = self
            .manifest
            .read_blob(&spec.file)
            .map_err(|e| e.context(format!("reading weights {}", path.display())))?;
        let floats: &[f32] = bytemuck_cast_f32(&blob)?;
        let needed: usize = spec.tensors.iter().map(|t| t.offset + t.size).max().unwrap_or(0);
        if floats.len() < needed {
            return Err(anyhow!(
                "weights blob {} truncated: {} floats, manifest expects {}",
                path.display(),
                floats.len(),
                needed
            ));
        }
        let mut map = HashMap::new();
        for t in &spec.tensors {
            let data = &floats[t.offset..t.offset + t.size];
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, &t.shape, None)
                .map_err(|e| anyhow!("uploading weight {}: {e}", t.name))?;
            self.stats.borrow_mut().h2d_bytes += (t.size * 4) as u64;
            map.insert(t.name.clone(), buf);
        }
        self.stats.borrow_mut().weight_uploads += 1;
        let rc = Rc::new(map);
        self.weights.borrow_mut().insert(config.to_string(), rc.clone());
        Ok(rc)
    }

    fn input_buffer(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            HostTensor::F32(d, s) => {
                self.stats.borrow_mut().h2d_bytes += (d.len() * 4) as u64;
                self.client.buffer_from_host_buffer::<f32>(d, s, None)
            }
            HostTensor::I32(d, s) => {
                self.stats.borrow_mut().h2d_bytes += (d.len() * 4) as u64;
                self.client.buffer_from_host_buffer::<i32>(d, s, None)
            }
        };
        buf.map_err(|e| anyhow!("creating input buffer: {e}"))
    }

    /// Execute an artifact: data tensors positionally for non-weight args,
    /// weight args resolved from the config's buffers. `layer` selects the
    /// `layers.{i}.` prefix for layer artifacts (None -> global weights).
    pub fn run(
        &self,
        name: &str,
        data: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.check_args(&spec, data)?;
        let exe = self.executable(name)?;
        // Resolve the config's resident weight buffers only when this
        // artifact binds any (selection artifacts bind none — executor
        // pool workers that only score selection must not each upload a
        // private copy of the full weight blob).
        let weights = if spec.args.iter().any(|a| a.weight) {
            Some(self.weight_buffers(&spec.config)?)
        } else {
            None
        };

        // Input tensors become fresh device buffers; weight args reuse the
        // resident buffers (no per-call copy — this is the point of the
        // AOT + persistent-buffer design).
        let owned: Vec<xla::PjRtBuffer> = data
            .iter()
            .map(|t| self.input_buffer(t))
            .collect::<Result<Vec<_>>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(spec.args.len());
        let mut di = 0usize;
        for a in &spec.args {
            if a.weight {
                let key = match layer {
                    Some(i) if !matches!(a.name.as_str(), "embed" | "ln_f") => {
                        format!("layers.{}.{}", i, a.name)
                    }
                    _ => a.name.clone(),
                };
                let buf = weights
                    .as_ref()
                    .expect("weights resolved when any weight arg exists")
                    .get(&key)
                    .ok_or_else(|| anyhow!("weight `{}` missing for {}", key, name))?;
                args.push(buf);
            } else {
                args.push(&owned[di]);
                di += 1;
            }
        }

        let t0 = Instant::now();
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e}", name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", name))?;
        // NB: never call size_bytes() on the tuple literal itself — XLA's
        // ShapeUtil::ByteSizeOf aborts on TUPLE shapes without a pointer
        // size. Account bytes per decomposed leaf instead.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", name))?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.exec_secs += t0.elapsed().as_secs_f64();
            st.d2h_bytes += parts.iter().map(|p| p.size_bytes() as u64).sum::<u64>();
        }
        parts
            .into_iter()
            .map(|l| literal_to_host(&l))
            .collect::<Result<Vec<_>>>()
    }

    fn check_args(&self, spec: &ArtifactSpec, data: &[HostTensor]) -> Result<()> {
        let expected: Vec<_> = spec.data_args().collect();
        if expected.len() != data.len() {
            return Err(anyhow!(
                "{} expects {} data args, got {}",
                spec.name,
                expected.len(),
                data.len()
            ));
        }
        for (a, t) in expected.iter().zip(data) {
            let dt_ok = matches!(
                (&a.dtype, t),
                (DType::F32, HostTensor::F32(..)) | (DType::I32, HostTensor::I32(..))
            );
            if !dt_ok || a.shape != t.shape() {
                return Err(anyhow!(
                    "{} arg `{}`: expected {:?} {:?}, got {:?}",
                    spec.name,
                    a.name,
                    a.dtype,
                    a.shape,
                    t.shape()
                ));
            }
        }
        Ok(())
    }
}

fn literal_to_host(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::F32(
            l.to_vec::<f32>().map_err(|e| anyhow!("literal f32: {e}"))?,
            dims,
        )),
        xla::ElementType::S32 => Ok(HostTensor::I32(
            l.to_vec::<i32>().map_err(|e| anyhow!("literal i32: {e}"))?,
            dims,
        )),
        other => Err(anyhow!("unsupported output element type {:?}", other)),
    }
}

/// Reinterpret the weight blob bytes as f32 (little-endian hosts only,
/// which is everything PJRT CPU targets).
fn bytemuck_cast_f32(bytes: &[u8]) -> Result<&[f32]> {
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("weight blob length {} not divisible by 4", bytes.len()));
    }
    if bytes.as_ptr() as usize % std::mem::align_of::<f32>() != 0 {
        return Err(anyhow!("weight blob misaligned"));
    }
    // SAFETY: length and alignment checked above; f32 has no invalid bit
    // patterns.
    Ok(unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4) })
}
