//! Artifact manifest: the contract between the python compile path and
//! the rust runtime. Parses `artifacts/manifest.json` into typed specs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, Weak};

use anyhow::{anyhow, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::{usize_array, Json};

/// Element type of a manifest tensor argument.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow!("unknown dtype `{}` in manifest", other)),
        }
    }
}

/// One argument of an artifact's entry computation.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// Argument name (matches the python export).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Tensor shape, row-major.
    pub shape: Vec<usize>,
    /// true if this argument is a model weight (bound once at load time,
    /// per layer for layer artifacts).
    pub weight: bool,
}

impl ArgSpec {
    /// Number of elements (product of the shape).
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// Model config this artifact was compiled for.
    pub config: String,
    /// Artifact kind (e.g. `decode_step`, `prefill`).
    pub kind: String,
    /// HLO-text file, relative to the manifest directory.
    pub file: String,
    /// Entry-computation arguments, in call order.
    pub args: Vec<ArgSpec>,
}

impl ArtifactSpec {
    /// Arguments supplied per call (non-weight).
    pub fn data_args(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| !a.weight)
    }
    /// Arguments bound once at load time (weights).
    pub fn weight_args(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.weight)
    }
}

/// Entry in the flat weights blob.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    /// Tensor name (matches the artifact's weight args).
    pub name: String,
    /// Tensor shape, row-major.
    pub shape: Vec<usize>,
    /// offset into the blob, in f32 elements.
    pub offset: usize,
    /// Element count.
    pub size: usize,
}

/// Weight blob for one model config: a flat f32 file plus the tensors
/// packed into it.
#[derive(Debug, Clone)]
pub struct WeightsSpec {
    /// Blob file, relative to the manifest directory.
    pub file: String,
    /// Tensors packed into the blob, in offset order.
    pub tensors: Vec<WeightTensor>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (artifact files are relative to it).
    pub dir: PathBuf,
    /// Model configs by name.
    pub configs: HashMap<String, ModelConfig>,
    /// Compiled artifacts by name.
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Weight blobs by config name.
    pub weights: HashMap<String, WeightsSpec>,
    /// Available decode batch-size buckets, ascending.
    pub decode_batch_buckets: Vec<usize>,
    /// Available prefill token-count buckets, ascending.
    pub prefill_buckets: Vec<usize>,
    /// Host-side cache of large blob files (the weights), keyed by
    /// manifest-relative path and **shared across clones**: the engine
    /// runtime and every executor-pool worker clone this manifest, so
    /// concurrent readers (warm-up, first-use uploads) share one disk
    /// read and one host copy. Entries are `Weak` — the blob is freed
    /// as soon as the last reader drops its `Arc`, so a multi-gigabyte
    /// weight blob is never pinned in host memory for the process
    /// lifetime just because it was read once.
    blob_cache: Arc<Mutex<HashMap<String, Weak<Vec<u8>>>>>,
}

impl Manifest {
    /// Parse `manifest.json` under `dir` into a typed manifest.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut configs = HashMap::new();
        if let Some(obj) = j.get("configs").as_obj() {
            for (name, cj) in obj.iter() {
                configs.insert(name.clone(), ModelConfig::from_json(cj)?);
            }
        }

        let mut artifacts = HashMap::new();
        for aj in j.get("artifacts").as_arr().unwrap_or(&[]) {
            let args = aj
                .get("args")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|arg| {
                    Ok(ArgSpec {
                        name: arg.get("name").as_str().unwrap_or("?").into(),
                        dtype: DType::parse(arg.get("dtype").as_str().unwrap_or("?"))?,
                        shape: usize_array(arg.get("shape")),
                        weight: arg.get("weight").as_bool().unwrap_or(false),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let spec = ArtifactSpec {
                name: aj.get("name").as_str().unwrap_or("?").into(),
                config: aj.get("config").as_str().unwrap_or("?").into(),
                kind: aj.get("kind").as_str().unwrap_or("?").into(),
                file: aj.get("file").as_str().unwrap_or("?").into(),
                args,
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        let mut weights = HashMap::new();
        if let Some(obj) = j.get("weights").as_obj() {
            for (cfg, wj) in obj.iter() {
                let tensors = wj
                    .get("tensors")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| WeightTensor {
                        name: t.get("name").as_str().unwrap_or("?").into(),
                        shape: usize_array(t.get("shape")),
                        offset: t.get("offset").as_usize().unwrap_or(0),
                        size: t.get("size").as_usize().unwrap_or(0),
                    })
                    .collect();
                weights.insert(
                    cfg.clone(),
                    WeightsSpec { file: wj.get("file").as_str().unwrap_or("?").into(), tensors },
                );
            }
        }

        Ok(Manifest {
            dir,
            configs,
            artifacts,
            weights,
            decode_batch_buckets: usize_array(j.get("buckets").get("decode_batch")),
            prefill_buckets: usize_array(j.get("buckets").get("prefill")),
            blob_cache: Arc::new(Mutex::new(HashMap::new())),
        })
    }

    /// Read a manifest-relative blob file through the process-wide cache
    /// shared by every clone of this manifest: readers whose lifetimes
    /// overlap (e.g. pool workers uploading weights around warm-up)
    /// share one disk read and one host copy; once every reader drops
    /// its `Arc` the memory is released and a later reader re-reads
    /// from disk (the OS page cache makes that cheap).
    pub fn read_blob(&self, file: &str) -> Result<Arc<Vec<u8>>> {
        #[allow(clippy::disallowed_methods)] // poisoning mapped to an error, not unwrapped
        let mut cache = self
            .blob_cache
            .lock()
            .map_err(|_| anyhow!("manifest blob cache poisoned"))?;
        if let Some(blob) = cache.get(file).and_then(Weak::upgrade) {
            return Ok(blob);
        }
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading blob {}", path.display()))?;
        let blob = Arc::new(bytes);
        cache.insert(file.to_string(), Arc::downgrade(&blob));
        Ok(blob)
    }

    /// Look up a model config by name.
    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs.get(name).ok_or_else(|| anyhow!("config `{}` not in manifest", name))
    }

    /// Look up an artifact spec by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| anyhow!("artifact `{}` not in manifest", name))
    }

    /// Smallest decode batch bucket >= n.
    pub fn decode_bucket(&self, n: usize) -> Option<usize> {
        self.decode_batch_buckets.iter().copied().filter(|&b| b >= n).min()
    }

    /// Smallest prefill bucket >= n.
    pub fn prefill_bucket(&self, n: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().filter(|&b| b >= n).min()
    }

    /// Golden trace path for a config.
    pub fn golden_path(&self, config: &str) -> PathBuf {
        self.dir.join(format!("golden_{}.json", config))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The built manifest, or a skip on hosts without `make artifacts`
    /// output (hard failure when FREEKV_REQUIRE_ARTIFACTS is set).
    fn built_manifest() -> Option<Manifest> {
        crate::runtime::require_or_skip(Manifest::load(artifacts_dir()))
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = built_manifest() else { return };
        assert!(m.configs.contains_key("tiny"));
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.page_size, 32);

        // Every artifact file exists and kinds are known.
        for spec in m.artifacts.values() {
            assert!(m.dir.join(&spec.file).exists(), "{} missing", spec.file);
            assert!(
                ["embed", "layer_decode", "layer_qkv", "layer_attn", "logits", "select",
                 "layer_prefill", "summarize"]
                .contains(&spec.kind.as_str()),
                "unknown kind {}",
                spec.kind
            );
        }
        // Weight blob exists with the right size.
        let w = &m.weights["tiny"];
        let floats: usize = w.tensors.iter().map(|t| t.size).sum();
        let md = std::fs::metadata(m.dir.join(&w.file)).unwrap();
        assert_eq!(md.len() as usize, floats * 4);
    }

    #[test]
    fn buckets() {
        let Some(m) = built_manifest() else { return };
        assert_eq!(m.decode_bucket(1), Some(1));
        assert_eq!(m.decode_bucket(2), Some(4));
        assert_eq!(m.decode_bucket(100), None);
        assert_eq!(m.prefill_bucket(100), Some(512));
    }

    #[test]
    fn layer_artifact_weight_args_are_marked() {
        let Some(m) = built_manifest() else { return };
        let a = m.artifact("tiny_layer_qkv_b1").unwrap();
        let wnames: Vec<_> = a.weight_args().map(|w| w.name.as_str()).collect();
        assert_eq!(wnames, vec!["ln1", "wq", "wk", "wv"]);
        let dnames: Vec<_> = a.data_args().map(|w| w.name.as_str()).collect();
        assert_eq!(dnames, vec!["h", "pos"]);
    }
}
