//! PJRT runtime (Layer-3 side of the AOT bridge): artifact manifest,
//! executable cache, resident weight buffers, typed host tensors.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArgSpec, ArtifactSpec, DType, Manifest, WeightsSpec};
pub use client::{HostTensor, Runtime, RuntimeStats};
