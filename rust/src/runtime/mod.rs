//! PJRT runtime (Layer-3 side of the AOT bridge): artifact manifest,
//! executable cache, resident weight buffers, typed host tensors, and
//! the Send-safe executor pool that multiplexes `!Send` PJRT clients
//! across worker threads.

pub mod artifacts;
pub mod client;
pub mod executor;

pub use artifacts::{ArgSpec, ArtifactSpec, DType, Manifest, WeightsSpec};
pub use client::{HostTensor, Runtime, RuntimeStats};
pub use executor::{
    ExecBackend, ExecCounters, ExecDone, ExecJob, ExecTicket, ExecutorHandle, ExecutorPool,
};

/// True when the environment demands the real artifact backend
/// (`FREEKV_REQUIRE_ARTIFACTS=1`, set by the CI real-backend job).
/// Artifact-gated tests consult this: unset they skip with a note when
/// the backend is missing; set, skipping is a hard failure, so the CI
/// matrix can prove the real paths actually ran.
pub fn artifacts_required() -> bool {
    std::env::var_os("FREEKV_REQUIRE_ARTIFACTS").is_some_and(|v| !v.is_empty() && v != "0")
}

/// The skip-or-hard-fail contract for any artifact-gated load, in one
/// place: `Ok` passes through; `Err` becomes `None` — a skip, with a
/// note on stderr — on hosts without the backend, or a panic when
/// [`artifacts_required`] demands it.
pub fn require_or_skip<T>(loaded: anyhow::Result<T>) -> Option<T> {
    match loaded {
        Ok(v) => Some(v),
        Err(e) => {
            assert!(
                !artifacts_required(),
                "FREEKV_REQUIRE_ARTIFACTS set but backend unavailable: {e:#}"
            );
            eprintln!("artifacts/PJRT unavailable — skipping: {e:#}");
            None
        }
    }
}

/// [`require_or_skip`] over the common case: loading the runtime.
pub fn load_or_skip(dir: impl AsRef<std::path::Path>) -> Option<Runtime> {
    require_or_skip(Runtime::load(dir))
}
