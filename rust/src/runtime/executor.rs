//! Send-safe executor pool for artifact execution.
//!
//! The PJRT CPU client is deliberately `!Send` (`runtime::Runtime` caches
//! executables and weight buffers behind `Rc`/`RefCell`), which until now
//! serialized every artifact execution — QKV, attention, selection
//! scoring, logits — on whichever thread built the runtime. This module
//! provides concurrency *around* that constraint instead of fighting it:
//!
//! * [`ExecutorPool::spawn`] starts N worker threads, each of which
//!   constructs its own backend **on-thread** (the same trick
//!   `EngineLoop::spawn` uses for the engine). A worker's PJRT client,
//!   executable cache, and resident weight buffers never cross a thread
//!   boundary, so nothing `Send` is ever required of them.
//! * Jobs are typed [`ExecJob`]s carrying owned [`HostTensor`] inputs —
//!   plain `Send` data. Submitting returns an [`ExecTicket`], a one-shot
//!   future the caller joins wherever the result is actually needed;
//!   completions may be joined in any order.
//! * [`ExecDone`] hands the input tensors back alongside the outputs, so
//!   callers that maintain reusable scratch buffers (the engine's
//!   selection planes are the big ones) get them back without
//!   reallocating.
//! * [`ExecutorHandle`] is cloneable and `Send`: any thread may submit.
//!
//! ## Weight-affinity routing
//!
//! Each worker has its own job queue, and submission routes by job
//! class: **weight-bearing** jobs (embed / QKV / attention / logits /
//! prefill chunks — anything that binds model weights) go only to the
//! first `weight_workers` workers, so only those ever upload a private
//! copy of the weight blob; **weight-free** jobs (selection scoring,
//! warm-up) go to whichever worker has the least outstanding work,
//! preferring non-weight workers on ties so the weight lane stays
//! clear. This is the designated-weight-worker design: pool weight
//! memory is `weight_workers` copies instead of one per worker, at the
//! cost of weight jobs queueing behind each other when
//! `weight_workers < workers`. Chunk-sized jobs keep that head-of-line
//! wait bounded. Warm-up is route-aware too: non-weight workers compile
//! only the weight-free artifacts they can ever be asked to run.
//!
//! Workers fold their backend's compile / weight-upload counters into
//! pool-wide totals after every job ([`ExecutorPool::counters`]), which
//! is how `EngineStats` proves weight memory stopped scaling with the
//! pool.
//!
//! ## Failure semantics & degradation ladder
//!
//! A panic inside a job is caught on the worker, reported as an error
//! on that job's ticket, and the worker keeps serving (one poisoned
//! input must not take down the pool). Failed job attempts (error or
//! panic) get **one deterministic retry** on the same worker before the
//! failure surfaces — transient faults cost a retry, persistent ones
//! still fail fast. A worker that *dies* resolves its queued tickets as
//! errors (never leaves them blocking), is marked dead so routing steers
//! around it, and is **respawned with a bounded budget** and a small
//! deterministic backoff the next time a job needs it; respawned workers
//! keep their index, so weight affinity is preserved. When every
//! eligible worker is dead and the budget is exhausted, submission
//! returns an already-failed ticket (the engine then falls back to
//! inline execution). Dropping the pool drains: already-queued jobs
//! still execute and their tickets still resolve — including on dead
//! workers, whose queues resolve as disconnects — then the workers exit
//! and are joined. Faults can be injected deterministically via
//! [`ExecutorPool::set_faults`] (`ExecJobError`, `ExecWorkerDeath`).
//!
//! The pool is generic over [`ExecBackend`] so its scheduling/lifecycle
//! machinery is testable on hosts without a native XLA backend (see
//! `tests/executor_pool.rs`); [`ExecutorPool::for_manifest`] is the
//! production constructor where every worker is a full PJRT [`Runtime`].
//!
//! What this buys the engine: selection scoring leaves the decode
//! critical path (scored on a worker while the engine drains the recall
//! pipeline), N decode microbatch lanes keep several workers busy at
//! once (`Engine::decode_step_lanes`), and chunked prefill jobs
//! interleave with in-flight decode. Outputs are bit-identical to
//! serial in-thread dispatch — same artifacts, same inputs, same XLA
//! CPU kernels — so pooling is a pure scheduling change.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::artifacts::Manifest;
use super::client::{HostTensor, Runtime};
use crate::util::fault::{panic_message, FaultPlan, FaultSite};
use crate::util::sync::lock_unpoisoned;

/// How many times a dead worker may be respawned before the pool gives
/// up on that slot and submission degrades to already-failed tickets.
const RESPAWN_BUDGET: u64 = 2;

/// Base backoff before a respawn attempt; scales linearly with the
/// attempt number so repeated deaths pay increasing, deterministic cost.
const RESPAWN_BACKOFF: Duration = Duration::from_millis(10);

/// One artifact execution, typed by pipeline stage. The variants carry
/// the fully-resolved artifact name (the engine owns config/bucket
/// naming); the type distinguishes stages for labeling, stats, and
/// weight-affinity routing.
pub enum ExecJob {
    /// Token embedding (`*_embed_*`).
    Embed { name: String, args: Vec<HostTensor> },
    /// Per-layer QKV projection (`*_layer_qkv_*`).
    Qkv { name: String, layer: usize, args: Vec<HostTensor> },
    /// Per-layer attention + FFN (`*_layer_attn_*`).
    Attention { name: String, layer: usize, args: Vec<HostTensor> },
    /// Per-layer full-prompt prefill chunk (`*_layer_prefill_*`).
    Prefill { name: String, layer: usize, args: Vec<HostTensor> },
    /// Page-selection scoring (`*_select_*`); no layer weights.
    Selection { name: String, args: Vec<HostTensor> },
    /// Final-norm + LM head (`*_logits_*`).
    Logits { name: String, args: Vec<HostTensor> },
    /// Escape hatch for anything else (benches, tests). Routed as
    /// weight-bearing (the pool cannot know it binds none).
    Raw { name: String, layer: Option<usize>, args: Vec<HostTensor> },
    /// Eager-compile `config`'s artifacts on the executing worker (see
    /// [`ExecBackend::warmup`]); completes with empty outputs. Handled
    /// on the worker before `into_parts`. `weight_free_only` restricts
    /// the warm set to artifacts binding no weights — what non-weight
    /// workers compile.
    Warmup { config: String, weight_free_only: bool },
}

impl ExecJob {
    /// Artifact name (config name for `Warmup`).
    pub fn name(&self) -> &str {
        match self {
            ExecJob::Embed { name, .. }
            | ExecJob::Qkv { name, .. }
            | ExecJob::Attention { name, .. }
            | ExecJob::Prefill { name, .. }
            | ExecJob::Selection { name, .. }
            | ExecJob::Logits { name, .. }
            | ExecJob::Raw { name, .. } => name,
            ExecJob::Warmup { config, .. } => config,
        }
    }

    /// Job kind as a static label (metrics / logs).
    pub fn kind(&self) -> &'static str {
        match self {
            ExecJob::Embed { .. } => "embed",
            ExecJob::Qkv { .. } => "qkv",
            ExecJob::Attention { .. } => "attention",
            ExecJob::Prefill { .. } => "prefill",
            ExecJob::Selection { .. } => "selection",
            ExecJob::Logits { .. } => "logits",
            ExecJob::Raw { .. } => "raw",
            ExecJob::Warmup { .. } => "warmup",
        }
    }

    /// Does executing this job bind model weights on the worker? Drives
    /// routing: weight-bearing jobs are confined to the designated
    /// weight workers so the pool holds `weight_workers` copies of the
    /// blob, not one per worker.
    pub fn needs_weights(&self) -> bool {
        match self {
            ExecJob::Embed { .. }
            | ExecJob::Qkv { .. }
            | ExecJob::Attention { .. }
            | ExecJob::Prefill { .. }
            | ExecJob::Logits { .. }
            | ExecJob::Raw { .. } => true,
            ExecJob::Selection { .. } | ExecJob::Warmup { .. } => false,
        }
    }

    /// (artifact name, layer for weight resolution, input tensors).
    /// Public so serial (in-thread) dispatch can execute the same jobs.
    /// `Warmup` never reaches this (the worker intercepts it).
    pub fn into_parts(self) -> (String, Option<usize>, Vec<HostTensor>) {
        match self {
            ExecJob::Embed { name, args }
            | ExecJob::Selection { name, args }
            | ExecJob::Logits { name, args } => (name, None, args),
            ExecJob::Qkv { name, layer, args }
            | ExecJob::Attention { name, layer, args }
            | ExecJob::Prefill { name, layer, args } => (name, Some(layer), args),
            ExecJob::Raw { name, layer, args } => (name, layer, args),
            ExecJob::Warmup { config, .. } => (config, None, Vec::new()),
        }
    }
}

/// A completed execution: outputs plus the job's own input tensors
/// (returned so callers can recycle scratch buffers), and the worker
/// wall time — hidden latency unless the caller blocked in
/// [`ExecTicket::wait`] for it.
pub struct ExecDone {
    /// Artifact outputs, in entry-computation order.
    pub outputs: Vec<HostTensor>,
    /// The job's input tensors, returned for buffer reuse.
    pub inputs: Vec<HostTensor>,
    /// Wall-clock seconds the worker spent on the job.
    pub busy_secs: f64,
    /// Index of the worker that executed the job.
    pub worker: usize,
}

struct JobMsg {
    job: ExecJob,
    reply: Sender<Result<ExecDone, String>>,
}

/// One-shot handle to an in-flight job. Join with [`ExecTicket::wait`].
pub struct ExecTicket {
    rx: Receiver<Result<ExecDone, String>>,
    name: String,
}

impl ExecTicket {
    /// Block until the job completes. Worker panics and execution errors
    /// surface here; a dead pool surfaces as a disconnect error.
    pub fn wait(self) -> Result<ExecDone> {
        match self.rx.recv() {
            Ok(Ok(done)) => Ok(done),
            Ok(Err(e)) => Err(anyhow!("executor job `{}` failed: {}", self.name, e)),
            Err(_) => Err(anyhow!(
                "executor pool shut down with job `{}` outstanding",
                self.name
            )),
        }
    }

    /// Non-blocking probe; `None` while the job is still running. NB:
    /// a `Some` return consumes the completion — the caller must use it.
    pub fn try_wait(&self) -> Option<Result<ExecDone>> {
        match self.rx.try_recv() {
            Ok(Ok(done)) => Some(Ok(done)),
            Ok(Err(e)) => Some(Err(anyhow!("executor job `{}` failed: {}", self.name, e))),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(anyhow!(
                "executor pool shut down with job `{}` outstanding",
                self.name
            ))),
        }
    }
}

/// Cumulative backend-side counters a worker samples after every job so
/// the pool can aggregate compile / weight-upload totals.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ExecCounters {
    /// Executables compiled by this backend so far.
    pub compiled: u64,
    /// Weight-blob device uploads performed by this backend so far
    /// (one per config whose weights became resident).
    pub weight_uploads: u64,
}

/// What a worker thread executes jobs against. The production backend is
/// a per-worker PJRT [`Runtime`]; tests substitute host-side backends so
/// pool mechanics are covered without a native XLA client.
pub trait ExecBackend {
    fn run(
        &mut self,
        name: &str,
        args: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>>;

    /// Eager-compile `config`'s artifacts (first-request latency
    /// control); with `weight_free_only` set, only artifacts that bind
    /// no weights. Returns how many were prepared. No-op by default.
    fn warmup(&mut self, _config: &str, _weight_free_only: bool) -> Result<usize> {
        Ok(0)
    }

    /// Cumulative compile / weight-upload counters (deltas are folded
    /// into the pool totals after each job). Zero by default.
    fn counters(&self) -> ExecCounters {
        ExecCounters::default()
    }
}

impl ExecBackend for Runtime {
    fn run(
        &mut self,
        name: &str,
        args: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>> {
        Runtime::run(self, name, args, layer)
    }

    fn warmup(&mut self, config: &str, weight_free_only: bool) -> Result<usize> {
        if weight_free_only {
            Runtime::warmup_weight_free(self, config)
        } else {
            Runtime::warmup(self, config)
        }
    }

    fn counters(&self) -> ExecCounters {
        let st = self.stats.borrow();
        ExecCounters { compiled: st.compiled, weight_uploads: st.weight_uploads }
    }
}

/// Pool-wide counter totals, folded in by workers after every job.
#[derive(Default)]
struct PoolCounters {
    compiled: AtomicU64,
    weight_uploads: AtomicU64,
    retries: AtomicU64,
    respawns: AtomicU64,
}

/// Liveness + health gauges of the pool, surfaced in `EngineStats` and
/// on `/metrics`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolHealth {
    /// Configured worker count.
    pub workers: usize,
    /// Workers currently accepting jobs.
    pub alive: usize,
    /// Dead workers brought back over the pool's lifetime.
    pub respawns: u64,
    /// Job attempts that failed once and were retried.
    pub retries: u64,
}

/// State shared with the worker thread itself. Deliberately does NOT
/// hold the job `Sender`: a worker must never keep its own queue open,
/// or pool drop would deadlock waiting for the queue to close.
struct WorkerState {
    /// Jobs submitted-but-not-finished (the routing load signal).
    outstanding: AtomicU64,
    /// Cleared when the worker exits (injected death, queue close) or a
    /// send to it fails; routing skips dead workers.
    alive: AtomicBool,
}

/// One worker's submission side. The sender is replaced wholesale when
/// the worker is respawned, hence the mutex (held only to clone/swap).
struct WorkerLink {
    tx: Mutex<Sender<JobMsg>>,
    state: Arc<WorkerState>,
    /// Remaining respawn budget for this slot.
    respawns_left: AtomicU64,
}

/// Cloneable, `Send` submission handle. Holding one keeps the pool's
/// job queues open — workers exit only after every handle (and the
/// pool's own copy) is gone and their queues have drained.
#[derive(Clone)]
pub struct ExecutorHandle {
    links: Arc<Vec<WorkerLink>>,
    weight_workers: usize,
    jobs: Arc<AtomicU64>,
    counters: Arc<PoolCounters>,
    faults: Arc<OnceLock<Arc<FaultPlan>>>,
    /// Respawn worker `i` in place (bounded budget, deterministic
    /// backoff). Type-erased: constructed inside `spawn_routed`, where
    /// the backend type and factory are still known.
    respawn: Arc<dyn Fn(usize) -> Result<(), String> + Send + Sync>,
}

impl ExecutorHandle {
    /// Enqueue a job on the least-loaded *live* eligible worker
    /// (weight-bearing jobs: the weight workers only), respawning a dead
    /// worker if none is live. Never blocks on a queue. When every
    /// eligible worker is dead and the respawn budget is exhausted, the
    /// returned ticket is already resolved to an error — the caller's
    /// cue to fall back to inline execution.
    pub fn submit(&self, job: ExecJob) -> ExecTicket {
        match self.route(&job) {
            Some(worker) => self.submit_to(worker, job),
            None => {
                let name = job.name().to_string();
                let (reply, rx) = channel();
                let _ = reply.send(Err(format!(
                    "no live executor worker for `{}` (respawn budget exhausted)",
                    name
                )));
                ExecTicket { rx, name }
            }
        }
    }

    /// Enqueue a job on a specific worker (warm-up broadcast, tests).
    pub fn submit_to(&self, worker: usize, job: ExecJob) -> ExecTicket {
        let name = job.name().to_string();
        let (reply, rx) = channel();
        self.jobs.fetch_add(1, Ordering::Relaxed);
        let link = &self.links[worker];
        link.state.outstanding.fetch_add(1, Ordering::SeqCst);
        let tx = lock_unpoisoned(&link.tx).clone();
        // On a dead worker the message (with its reply sender) is
        // dropped, which the ticket observes as a disconnect error.
        if tx.send(JobMsg { job, reply }).is_err() {
            link.state.outstanding.fetch_sub(1, Ordering::SeqCst);
            link.state.alive.store(false, Ordering::SeqCst);
        }
        ExecTicket { rx, name }
    }

    /// Least-outstanding live worker among those eligible for this job;
    /// ties prefer non-weight workers so the weight lane stays clear for
    /// the jobs that must run there. With every eligible worker dead,
    /// attempts a respawn (index order, so routing stays deterministic).
    fn route(&self, job: &ExecJob) -> Option<usize> {
        let eligible =
            if job.needs_weights() { self.weight_workers } else { self.links.len() };
        let mut best: Option<usize> = None;
        let mut best_load = u64::MAX;
        for (i, link) in self.links[..eligible].iter().enumerate() {
            if !link.state.alive.load(Ordering::SeqCst) {
                continue;
            }
            let load = link.state.outstanding.load(Ordering::SeqCst);
            if load < best_load || (load == best_load && i >= self.weight_workers) {
                best = Some(i);
                best_load = load;
            }
        }
        if best.is_none() {
            for i in 0..eligible {
                if (self.respawn)(i).is_ok() {
                    return Some(i);
                }
            }
        }
        best
    }

    /// Would this job find (or revive) a worker right now? The engine
    /// checks before dispatching a pooled stage and runs inline when the
    /// answer is no.
    pub fn ready_for(&self, job: &ExecJob) -> bool {
        self.route(job).is_some()
    }

    /// Is a weight-eligible worker live (reviving one if needed)? Gates
    /// chunked pooled prefill; `false` means prefill synchronously.
    pub fn ready_weight(&self) -> bool {
        if self.links[..self.weight_workers]
            .iter()
            .any(|l| l.state.alive.load(Ordering::SeqCst))
        {
            return true;
        }
        (0..self.weight_workers).any(|i| (self.respawn)(i).is_ok())
    }

    /// Install a fault plan (first caller wins; later calls are ignored).
    /// Workers observe it from their next job on.
    pub fn set_faults(&self, plan: Arc<FaultPlan>) {
        let _ = self.faults.set(plan);
    }

    /// Number of workers in the pool.
    pub fn workers(&self) -> usize {
        self.links.len()
    }

    /// Workers eligible to hold model weights.
    pub fn weight_workers(&self) -> usize {
        self.weight_workers
    }

    /// Total jobs submitted over the pool's lifetime.
    pub fn jobs_submitted(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Aggregated backend counters across every worker (updated after
    /// each completed job).
    pub fn counters(&self) -> ExecCounters {
        ExecCounters {
            compiled: self.counters.compiled.load(Ordering::Relaxed),
            weight_uploads: self.counters.weight_uploads.load(Ordering::Relaxed),
        }
    }

    /// Live health gauges (worker liveness, respawns, retries).
    pub fn health(&self) -> PoolHealth {
        PoolHealth {
            workers: self.links.len(),
            alive: self
                .links
                .iter()
                .filter(|l| l.state.alive.load(Ordering::SeqCst))
                .count(),
            respawns: self.counters.respawns.load(Ordering::Relaxed),
            retries: self.counters.retries.load(Ordering::Relaxed),
        }
    }
}

/// The pool: owns the worker threads. Dropping it drains the queues
/// (queued jobs still run, tickets still resolve) and joins the workers.
pub struct ExecutorPool {
    /// Dropped first on shutdown so workers see their queues close.
    handle: Option<ExecutorHandle>,
    worker_count: usize,
    weight_workers: usize,
    /// Shared with the respawner so replacement threads are joined too.
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ExecutorPool {
    /// Spawn `workers` threads with every worker eligible to hold
    /// weights (the pre-routing behaviour). See [`ExecutorPool::spawn_routed`].
    pub fn spawn<B, F>(workers: usize, factory: F) -> Result<ExecutorPool>
    where
        B: ExecBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        Self::spawn_routed(workers, workers, factory)
    }

    /// Spawn `workers` threads (min 1), confining weight-bearing jobs to
    /// the first `weight_workers` of them (clamped to `1..=workers`).
    /// `factory(i)` runs *on* worker `i`'s thread to build its backend —
    /// this is what makes a pool of `!Send` PJRT clients possible. Fails
    /// if any worker's backend fails to construct (the others are shut
    /// down cleanly).
    pub fn spawn_routed<B, F>(
        workers: usize,
        weight_workers: usize,
        factory: F,
    ) -> Result<ExecutorPool>
    where
        B: ExecBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let weight_workers = weight_workers.clamp(1, workers);
        let factory = Arc::new(factory);
        let counters = Arc::new(PoolCounters::default());
        let faults_cell: Arc<OnceLock<Arc<FaultPlan>>> = Arc::new(OnceLock::new());
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let mut links = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        let mut failures = Vec::new();
        for i in 0..workers {
            let (tx, rx) = channel::<JobMsg>();
            let state = Arc::new(WorkerState {
                outstanding: AtomicU64::new(0),
                alive: AtomicBool::new(true),
            });
            links.push(WorkerLink {
                tx: Mutex::new(tx),
                state: state.clone(),
                respawns_left: AtomicU64::new(RESPAWN_BUDGET),
            });
            let factory = factory.clone();
            let ready = ready_tx.clone();
            let totals = counters.clone();
            let faults = faults_cell.clone();
            let spawned = thread::Builder::new()
                .name(format!("freekv-exec-{}", i))
                .spawn(move || {
                    // Backend built on-thread; never crosses threads.
                    let backend = match factory(i) {
                        Ok(b) => {
                            let _ = ready.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    worker_loop(backend, rx, i, &state, &totals, &faults);
                });
            match spawned {
                Ok(j) => joins.push(j),
                Err(e) => {
                    // OS refused the thread (EAGAIN under pressure):
                    // abort below exactly like a backend failure.
                    failures.push(format!("spawning executor worker {}: {}", i, e));
                    links.pop();
                    break;
                }
            }
        }
        drop(ready_tx);

        // One readiness report per thread that actually spawned.
        for _ in 0..joins.len() {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(e),
                Err(_) => failures.push("worker thread died before reporting ready".into()),
            }
        }
        if !failures.is_empty() {
            // Abort: close every queue so healthy workers exit, then join.
            drop(links);
            for j in joins {
                let _ = j.join();
            }
            return Err(anyhow!(
                "executor pool startup failed ({} of {} workers): {}",
                failures.len(),
                workers,
                failures.join("; ")
            ));
        }

        let links = Arc::new(links);
        let joins = Arc::new(Mutex::new(joins));

        // The respawner: replaces a dead worker's thread and queue in
        // place (same index, so weight affinity is preserved). Built
        // here, where `B` and the factory are still nameable, and then
        // type-erased into the handle.
        let respawn: Arc<dyn Fn(usize) -> Result<(), String> + Send + Sync> = {
            let links = links.clone();
            let joins = joins.clone();
            let factory = factory.clone();
            let totals = counters.clone();
            let faults = faults_cell.clone();
            Arc::new(move |i: usize| {
                let link = &links[i];
                if link.state.alive.load(Ordering::SeqCst) {
                    return Ok(()); // a concurrent respawn beat us to it
                }
                // Claim one unit of budget (CAS so racers cannot overspend).
                let left = loop {
                    let left = link.respawns_left.load(Ordering::SeqCst);
                    if left == 0 {
                        return Err(format!(
                            "executor worker {} respawn budget exhausted",
                            i
                        ));
                    }
                    if link
                        .respawns_left
                        .compare_exchange(left, left - 1, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        break left;
                    }
                };
                // Deterministic linear backoff: later attempts wait longer.
                thread::sleep(RESPAWN_BACKOFF * (RESPAWN_BUDGET - left + 1) as u32);
                let (tx, rx) = channel::<JobMsg>();
                let (ready_tx, ready_rx) = channel::<Result<(), String>>();
                let spawned = thread::Builder::new()
                    .name(format!("freekv-exec-{}", i))
                    .spawn({
                        let factory = factory.clone();
                        let state = link.state.clone();
                        let totals = totals.clone();
                        let faults = faults.clone();
                        move || {
                            let backend = match factory(i) {
                                Ok(b) => {
                                    let _ = ready_tx.send(Ok(()));
                                    b
                                }
                                Err(e) => {
                                    let _ = ready_tx.send(Err(format!("{e:#}")));
                                    return;
                                }
                            };
                            worker_loop(backend, rx, i, &state, &totals, &faults);
                        }
                    })
                    .map_err(|e| format!("respawning executor worker {}: {}", i, e))?;
                match ready_rx.recv() {
                    Ok(Ok(())) => {
                        // Jobs stranded in the dead worker's old queue have
                        // resolved (or will) as disconnects; the load gauge
                        // restarts clean with the fresh queue.
                        link.state.outstanding.store(0, Ordering::SeqCst);
                        *lock_unpoisoned(&link.tx) = tx;
                        link.state.alive.store(true, Ordering::SeqCst);
                        totals.respawns.fetch_add(1, Ordering::Relaxed);
                        lock_unpoisoned(&joins).push(spawned);
                        Ok(())
                    }
                    Ok(Err(e)) => {
                        let _ = spawned.join();
                        Err(format!("respawned executor worker {} failed: {}", i, e))
                    }
                    Err(_) => {
                        let _ = spawned.join();
                        Err(format!("respawned executor worker {} died before ready", i))
                    }
                }
            })
        };

        Ok(ExecutorPool {
            handle: Some(ExecutorHandle {
                links,
                weight_workers,
                jobs: Arc::new(AtomicU64::new(0)),
                counters,
                faults: faults_cell,
                respawn,
            }),
            worker_count: workers,
            weight_workers,
            workers: joins,
        })
    }

    /// Production pool: every worker constructs its own PJRT [`Runtime`]
    /// over a clone of `manifest` (shared artifact dir + host blob
    /// cache, private client, private executable/weight caches). All
    /// workers weight-eligible; see [`ExecutorPool::for_manifest_routed`].
    pub fn for_manifest(manifest: &Manifest, workers: usize) -> Result<ExecutorPool> {
        Self::for_manifest_routed(manifest, workers, workers)
    }

    /// Production pool with weight-affinity routing: only the first
    /// `weight_workers` runtimes ever upload the weight blob.
    pub fn for_manifest_routed(
        manifest: &Manifest,
        workers: usize,
        weight_workers: usize,
    ) -> Result<ExecutorPool> {
        let manifest = manifest.clone();
        ExecutorPool::spawn_routed(workers, weight_workers, move |_| Runtime::new(manifest.clone()))
    }

    /// Submit directly on the pool (same as `handle().submit`).
    pub fn submit(&self, job: ExecJob) -> ExecTicket {
        self.inner().submit(job)
    }

    /// Route-aware pool warm-up: one [`ExecJob::Warmup`] per worker,
    /// awaited together — weight workers compile everything, the rest
    /// only the weight-free artifacts they can be routed. Returns the
    /// number of warm jobs completed.
    pub fn warmup(&self, config: &str) -> Result<usize> {
        let h = self.inner();
        let tickets: Vec<ExecTicket> = (0..self.worker_count)
            .map(|i| {
                h.submit_to(
                    i,
                    ExecJob::Warmup {
                        config: config.to_string(),
                        weight_free_only: i >= self.weight_workers,
                    },
                )
            })
            .collect();
        let mut done = 0;
        for t in tickets {
            t.wait()?;
            done += 1;
        }
        Ok(done)
    }

    fn inner(&self) -> &ExecutorHandle {
        self.handle.as_ref().expect("pool not yet shut down")
    }

    /// A cloneable, `Send` submission handle for other threads. NB: an
    /// outstanding handle keeps the job queues open, so dropping the
    /// pool blocks until every handle is gone.
    pub fn handle(&self) -> ExecutorHandle {
        self.inner().clone()
    }

    /// Number of workers in the pool.
    pub fn workers(&self) -> usize {
        self.worker_count
    }

    /// Workers eligible to hold model weights.
    pub fn weight_workers(&self) -> usize {
        self.weight_workers
    }

    /// Total jobs submitted over the pool's lifetime.
    pub fn jobs_submitted(&self) -> u64 {
        self.inner().jobs_submitted()
    }

    /// Aggregated compile / weight-upload counters across the workers.
    pub fn counters(&self) -> ExecCounters {
        self.inner().counters()
    }

    /// Install a fault plan on the workers (first caller wins).
    pub fn set_faults(&self, plan: Arc<FaultPlan>) {
        self.inner().set_faults(plan);
    }

    /// Live health gauges (worker liveness, respawns, retries).
    pub fn health(&self) -> PoolHealth {
        self.inner().health()
    }

    /// See [`ExecutorHandle::ready_for`].
    pub fn ready_for(&self, job: &ExecJob) -> bool {
        self.inner().ready_for(job)
    }

    /// See [`ExecutorHandle::ready_weight`].
    pub fn ready_weight(&self) -> bool {
        self.inner().ready_weight()
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // Close the queues (the handle holds every sender and the
        // respawner), let live workers drain what's already enqueued,
        // then join them. Dead workers' threads are already gone — their
        // JoinHandles resolve immediately, so a dead worker can never
        // hang shutdown.
        self.handle.take();
        let joins: Vec<JoinHandle<()>> = lock_unpoisoned(&self.workers).drain(..).collect();
        for j in joins {
            let _ = j.join();
        }
    }
}

/// One worker's serve loop: pull jobs until the queue closes or an
/// injected death fires. On death, the current job and everything
/// already queued resolve as errors (tickets must never block on a dead
/// worker) before the thread exits.
fn worker_loop<B: ExecBackend>(
    mut backend: B,
    rx: Receiver<JobMsg>,
    i: usize,
    state: &WorkerState,
    totals: &PoolCounters,
    faults: &OnceLock<Arc<FaultPlan>>,
) {
    let mut last = ExecCounters::default();
    while let Ok(JobMsg { job, reply }) = rx.recv() {
        if let Some(f) = faults.get() {
            if f.check(FaultSite::ExecWorkerDeath) {
                state.alive.store(false, Ordering::SeqCst);
                let fail = |job: ExecJob, reply: Sender<Result<ExecDone, String>>| {
                    state.outstanding.fetch_sub(1, Ordering::SeqCst);
                    let _ = reply.send(Err(format!(
                        "executor worker {} died (injected fault) with `{}` queued",
                        i,
                        job.name()
                    )));
                };
                fail(job, reply);
                while let Ok(JobMsg { job, reply }) = rx.try_recv() {
                    fail(job, reply);
                }
                return;
            }
        }
        let result = run_job(&mut backend, job, i, faults.get().map(|a| a.as_ref()), totals);
        state.outstanding.fetch_sub(1, Ordering::SeqCst);
        let now = backend.counters();
        totals
            .compiled
            .fetch_add(now.compiled.saturating_sub(last.compiled), Ordering::Relaxed);
        totals.weight_uploads.fetch_add(
            now.weight_uploads.saturating_sub(last.weight_uploads),
            Ordering::Relaxed,
        );
        last = now;
        // A caller that dropped its ticket just loses the result; the
        // worker moves on.
        let _ = reply.send(result);
    }
    state.alive.store(false, Ordering::SeqCst);
}

/// Execute one job on a worker's backend, panics contained. A failed
/// attempt (error or panic) gets exactly one retry on the same worker —
/// deterministic, so fault-free runs are unaffected — before the
/// failure surfaces on the ticket.
fn run_job<B: ExecBackend>(
    backend: &mut B,
    job: ExecJob,
    worker: usize,
    faults: Option<&FaultPlan>,
    totals: &PoolCounters,
) -> Result<ExecDone, String> {
    let t0 = Instant::now();
    match job {
        ExecJob::Warmup { config, weight_free_only } => {
            match catch_unwind(AssertUnwindSafe(|| backend.warmup(&config, weight_free_only))) {
                Ok(Ok(_n)) => Ok(ExecDone {
                    outputs: Vec::new(),
                    inputs: Vec::new(),
                    busy_secs: t0.elapsed().as_secs_f64(),
                    worker,
                }),
                Ok(Err(e)) => Err(format!("{e:#}")),
                Err(payload) => Err(format!(
                    "worker {} panicked warming `{}`: {}",
                    worker,
                    config,
                    panic_message(&payload)
                )),
            }
        }
        job => {
            let (name, layer, args) = job.into_parts();
            let mut attempt = |backend: &mut B| -> Result<Vec<HostTensor>, String> {
                if let Some(f) = faults {
                    if f.check(FaultSite::ExecJobError) {
                        return Err(format!(
                            "injected transient failure on worker {}",
                            worker
                        ));
                    }
                }
                match catch_unwind(AssertUnwindSafe(|| backend.run(&name, &args, layer))) {
                    Ok(Ok(outputs)) => Ok(outputs),
                    Ok(Err(e)) => Err(format!("{e:#}")),
                    Err(payload) => Err(format!(
                        "worker {} panicked executing `{}`: {}",
                        worker,
                        name,
                        panic_message(&payload)
                    )),
                }
            };
            let outcome = match attempt(backend) {
                Ok(outputs) => Ok(outputs),
                Err(first) => {
                    totals.retries.fetch_add(1, Ordering::Relaxed);
                    attempt(backend).map_err(|second| {
                        format!("{} (after one retry; first failure: {})", second, first)
                    })
                }
            };
            drop(attempt);
            match outcome {
                Ok(outputs) => Ok(ExecDone {
                    outputs,
                    inputs: args,
                    busy_secs: t0.elapsed().as_secs_f64(),
                    worker,
                }),
                Err(e) => Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Host-side backend: multiplies every f32 input by (layer + 2).
    struct Scaler;

    impl ExecBackend for Scaler {
        fn run(
            &mut self,
            name: &str,
            args: &[HostTensor],
            layer: Option<usize>,
        ) -> Result<Vec<HostTensor>> {
            if name == "explode" {
                panic!("requested panic");
            }
            let k = (layer.unwrap_or(0) + 2) as f32;
            Ok(args
                .iter()
                .map(|t| match t {
                    HostTensor::F32(d, s) => {
                        HostTensor::F32(d.iter().map(|x| x * k).collect(), s.clone())
                    }
                    HostTensor::I32(d, s) => HostTensor::I32(d.clone(), s.clone()),
                })
                .collect())
        }
    }

    fn f32s(v: &[f32]) -> HostTensor {
        HostTensor::F32(v.to_vec(), vec![v.len()])
    }

    #[test]
    fn jobs_round_trip_and_return_inputs() {
        let pool = ExecutorPool::spawn(2, |_| Ok(Scaler)).unwrap();
        let t = pool.submit(ExecJob::Qkv {
            name: "anything".into(),
            layer: 1,
            args: vec![f32s(&[1.0, 2.0])],
        });
        let done = t.wait().unwrap();
        assert_eq!(done.outputs, vec![f32s(&[3.0, 6.0])]);
        assert_eq!(done.inputs, vec![f32s(&[1.0, 2.0])], "inputs handed back for reuse");
        assert!(done.busy_secs >= 0.0);
        assert_eq!(pool.jobs_submitted(), 1);
    }

    #[test]
    fn out_of_order_joins() {
        let pool = ExecutorPool::spawn(3, |_| Ok(Scaler)).unwrap();
        let tickets: Vec<ExecTicket> = (0..16)
            .map(|i| {
                pool.submit(ExecJob::Raw {
                    name: format!("job{}", i),
                    layer: Some(i),
                    args: vec![f32s(&[i as f32])],
                })
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate().rev() {
            let done = t.wait().unwrap();
            assert_eq!(done.outputs, vec![f32s(&[i as f32 * (i + 2) as f32])]);
        }
    }

    #[test]
    fn startup_failure_aborts_the_pool() {
        let err = ExecutorPool::spawn(3, |i| {
            if i == 1 {
                Err(anyhow!("no backend on worker 1"))
            } else {
                Ok(Scaler)
            }
        })
        .map(|_| ())
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no backend on worker 1"), "{}", msg);
    }

    #[test]
    fn weight_jobs_stay_on_weight_workers() {
        let pool = ExecutorPool::spawn_routed(4, 1, |_| Ok(Scaler)).unwrap();
        assert_eq!(pool.weight_workers(), 1);
        let weight_tickets: Vec<ExecTicket> = (0..8)
            .map(|i| {
                pool.submit(ExecJob::Qkv {
                    name: format!("w{}", i),
                    layer: 0,
                    args: vec![f32s(&[1.0])],
                })
            })
            .collect();
        let free_tickets: Vec<ExecTicket> = (0..8)
            .map(|i| {
                pool.submit(ExecJob::Selection { name: format!("s{}", i), args: vec![f32s(&[1.0])] })
            })
            .collect();
        for t in weight_tickets {
            assert_eq!(t.wait().unwrap().worker, 0, "weight job left the weight worker");
        }
        for t in free_tickets {
            assert!(t.wait().unwrap().worker < 4);
        }
    }
}
