//! Send-safe executor pool for artifact execution.
//!
//! The PJRT CPU client is deliberately `!Send` (`runtime::Runtime` caches
//! executables and weight buffers behind `Rc`/`RefCell`), which until now
//! serialized every artifact execution — QKV, attention, selection
//! scoring, logits — on whichever thread built the runtime. This module
//! provides concurrency *around* that constraint instead of fighting it:
//!
//! * [`ExecutorPool::spawn`] starts N worker threads, each of which
//!   constructs its own backend **on-thread** (the same trick
//!   `EngineLoop::spawn` uses for the engine). A worker's PJRT client,
//!   executable cache, and resident weight buffers never cross a thread
//!   boundary, so nothing `Send` is ever required of them.
//! * Jobs are typed [`ExecJob`]s carrying owned [`HostTensor`] inputs —
//!   plain `Send` data. Submitting returns an [`ExecTicket`], a one-shot
//!   future the caller joins wherever the result is actually needed;
//!   completions may be joined in any order.
//! * [`ExecDone`] hands the input tensors back alongside the outputs, so
//!   callers that maintain reusable scratch buffers (the engine's
//!   selection planes are the big ones) get them back without
//!   reallocating.
//! * [`ExecutorHandle`] is cloneable and `Send`: any thread may submit.
//!
//! Failure semantics: a panic inside a job is caught on the worker,
//! reported as an error on that job's ticket, and the worker keeps
//! serving (one poisoned input must not take down the pool). A worker
//! that dies entirely surfaces as a disconnected ticket. Dropping the
//! pool drains: already-queued jobs still execute and their tickets
//! still resolve, then the workers exit and are joined.
//!
//! The pool is generic over [`ExecBackend`] so its scheduling/lifecycle
//! machinery is testable on hosts without a native XLA backend (see
//! `tests/executor_pool.rs`); [`ExecutorPool::for_manifest`] is the
//! production constructor where every worker is a full PJRT [`Runtime`].
//!
//! What this buys the engine: selection scoring leaves the decode
//! critical path (scored on a worker while the engine drains the recall
//! pipeline), and two decode microbatches can keep several workers busy
//! at once (`Engine::decode_step_pair`). Outputs are bit-identical to
//! serial in-thread dispatch — same artifacts, same inputs, same XLA CPU
//! kernels — so pooling is a pure scheduling change.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::artifacts::Manifest;
use super::client::{HostTensor, Runtime};

/// One artifact execution, typed by pipeline stage. The variants carry
/// the fully-resolved artifact name (the engine owns config/bucket
/// naming); the type distinguishes stages for labeling and stats.
pub enum ExecJob {
    /// Token embedding (`*_embed_*`).
    Embed { name: String, args: Vec<HostTensor> },
    /// Per-layer QKV projection (`*_layer_qkv_*`).
    Qkv { name: String, layer: usize, args: Vec<HostTensor> },
    /// Per-layer attention + FFN (`*_layer_attn_*`).
    Attention { name: String, layer: usize, args: Vec<HostTensor> },
    /// Page-selection scoring (`*_select_*`); no layer weights.
    Selection { name: String, args: Vec<HostTensor> },
    /// Final-norm + LM head (`*_logits_*`).
    Logits { name: String, args: Vec<HostTensor> },
    /// Escape hatch for anything else (benches, tests).
    Raw { name: String, layer: Option<usize>, args: Vec<HostTensor> },
    /// Eager-compile every artifact of `config` on the executing worker
    /// (see [`ExecBackend::warmup`]); completes with empty outputs.
    /// Handled on the worker before `into_parts`.
    Warmup { config: String },
}

impl ExecJob {
    pub fn name(&self) -> &str {
        match self {
            ExecJob::Embed { name, .. }
            | ExecJob::Qkv { name, .. }
            | ExecJob::Attention { name, .. }
            | ExecJob::Selection { name, .. }
            | ExecJob::Logits { name, .. }
            | ExecJob::Raw { name, .. } => name,
            ExecJob::Warmup { config } => config,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ExecJob::Embed { .. } => "embed",
            ExecJob::Qkv { .. } => "qkv",
            ExecJob::Attention { .. } => "attention",
            ExecJob::Selection { .. } => "selection",
            ExecJob::Logits { .. } => "logits",
            ExecJob::Raw { .. } => "raw",
            ExecJob::Warmup { .. } => "warmup",
        }
    }

    /// (artifact name, layer for weight resolution, input tensors).
    /// Public so serial (in-thread) dispatch can execute the same jobs.
    /// `Warmup` never reaches this (the worker intercepts it).
    pub fn into_parts(self) -> (String, Option<usize>, Vec<HostTensor>) {
        match self {
            ExecJob::Embed { name, args }
            | ExecJob::Selection { name, args }
            | ExecJob::Logits { name, args } => (name, None, args),
            ExecJob::Qkv { name, layer, args } | ExecJob::Attention { name, layer, args } => {
                (name, Some(layer), args)
            }
            ExecJob::Raw { name, layer, args } => (name, layer, args),
            ExecJob::Warmup { config } => (config, None, Vec::new()),
        }
    }
}

/// A completed execution: outputs plus the job's own input tensors
/// (returned so callers can recycle scratch buffers), and the worker
/// wall time — hidden latency unless the caller blocked in
/// [`ExecTicket::wait`] for it.
pub struct ExecDone {
    pub outputs: Vec<HostTensor>,
    pub inputs: Vec<HostTensor>,
    pub busy_secs: f64,
    /// Index of the worker that executed the job.
    pub worker: usize,
}

struct JobMsg {
    job: ExecJob,
    reply: Sender<Result<ExecDone, String>>,
}

/// One-shot handle to an in-flight job. Join with [`ExecTicket::wait`].
pub struct ExecTicket {
    rx: Receiver<Result<ExecDone, String>>,
    name: String,
}

impl ExecTicket {
    /// Block until the job completes. Worker panics and execution errors
    /// surface here; a dead pool surfaces as a disconnect error.
    pub fn wait(self) -> Result<ExecDone> {
        match self.rx.recv() {
            Ok(Ok(done)) => Ok(done),
            Ok(Err(e)) => Err(anyhow!("executor job `{}` failed: {}", self.name, e)),
            Err(_) => Err(anyhow!(
                "executor pool shut down with job `{}` outstanding",
                self.name
            )),
        }
    }

    /// Non-blocking probe; `None` while the job is still running.
    pub fn try_wait(&self) -> Option<Result<ExecDone>> {
        match self.rx.try_recv() {
            Ok(Ok(done)) => Some(Ok(done)),
            Ok(Err(e)) => Some(Err(anyhow!("executor job `{}` failed: {}", self.name, e))),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(anyhow!(
                "executor pool shut down with job `{}` outstanding",
                self.name
            ))),
        }
    }
}

/// What a worker thread executes jobs against. The production backend is
/// a per-worker PJRT [`Runtime`]; tests substitute host-side backends so
/// pool mechanics are covered without a native XLA client.
pub trait ExecBackend {
    fn run(
        &mut self,
        name: &str,
        args: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>>;

    /// Eager-compile every artifact of `config` (first-request latency
    /// control); returns how many were prepared. No-op by default.
    fn warmup(&mut self, _config: &str) -> Result<usize> {
        Ok(0)
    }
}

impl ExecBackend for Runtime {
    fn run(
        &mut self,
        name: &str,
        args: &[HostTensor],
        layer: Option<usize>,
    ) -> Result<Vec<HostTensor>> {
        Runtime::run(self, name, args, layer)
    }

    fn warmup(&mut self, config: &str) -> Result<usize> {
        Runtime::warmup(self, config)
    }
}

/// Cloneable, `Send` submission handle. Holding one keeps the pool's
/// job queue open — workers exit only after every handle (and the pool's
/// own sender) is gone and the queue has drained.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: Sender<JobMsg>,
    jobs: Arc<AtomicU64>,
    workers: usize,
}

impl ExecutorHandle {
    /// Enqueue a job; any idle worker picks it up FIFO. Never blocks.
    /// If the pool is gone the error surfaces at [`ExecTicket::wait`].
    pub fn submit(&self, job: ExecJob) -> ExecTicket {
        let name = job.name().to_string();
        let (reply, rx) = channel();
        self.jobs.fetch_add(1, Ordering::Relaxed);
        // On a dead pool the message (with its reply sender) is dropped,
        // which the ticket observes as a disconnect.
        let _ = self.tx.send(JobMsg { job, reply });
        ExecTicket { rx, name }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total jobs submitted over the pool's lifetime.
    pub fn jobs_submitted(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }
}

/// The pool: owns the worker threads. Dropping it drains the queue
/// (queued jobs still run, tickets still resolve) and joins the workers.
pub struct ExecutorPool {
    /// Dropped first on shutdown so workers see the queue close.
    tx: Option<Sender<JobMsg>>,
    jobs: Arc<AtomicU64>,
    worker_count: usize,
    workers: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `workers` threads (min 1). `factory(i)` runs *on* worker
    /// `i`'s thread to build its backend — this is what makes a pool of
    /// `!Send` PJRT clients possible. Fails if any worker's backend
    /// fails to construct (the others are shut down cleanly).
    pub fn spawn<B, F>(workers: usize, factory: F) -> Result<ExecutorPool>
    where
        B: ExecBackend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (tx, rx) = channel::<JobMsg>();
        let queue = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let mut joins = Vec::with_capacity(workers);
        let mut failures = Vec::new();
        for i in 0..workers {
            let queue = queue.clone();
            let factory = factory.clone();
            let ready = ready_tx.clone();
            let spawned = thread::Builder::new()
                .name(format!("freekv-exec-{}", i))
                .spawn(move || {
                    // Backend built on-thread; never crosses threads.
                    let mut backend = match factory(i) {
                        Ok(b) => {
                            let _ = ready.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    loop {
                        // Hold the queue lock only for the dequeue; idle
                        // workers queue up on the mutex, which is exactly
                        // the work-stealing order we want from std mpsc.
                        let msg = match queue.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // queue mutex poisoned: shut down
                        };
                        let Ok(JobMsg { job, reply }) = msg else {
                            break; // every sender gone and queue drained
                        };
                        let result = run_job(&mut backend, job, i);
                        // A caller that dropped its ticket just loses the
                        // result; the worker moves on.
                        let _ = reply.send(result);
                    }
                });
            match spawned {
                Ok(j) => joins.push(j),
                Err(e) => {
                    // OS refused the thread (EAGAIN under pressure):
                    // abort below exactly like a backend failure.
                    failures.push(format!("spawning executor worker {}: {}", i, e));
                    break;
                }
            }
        }
        drop(ready_tx);

        // One readiness report per thread that actually spawned.
        for _ in 0..joins.len() {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failures.push(e),
                Err(_) => failures.push("worker thread died before reporting ready".into()),
            }
        }
        if !failures.is_empty() {
            // Abort: close the queue so healthy workers exit, then join.
            drop(tx);
            for j in joins {
                let _ = j.join();
            }
            return Err(anyhow!(
                "executor pool startup failed ({} of {} workers): {}",
                failures.len(),
                workers,
                failures.join("; ")
            ));
        }

        Ok(ExecutorPool {
            tx: Some(tx),
            jobs: Arc::new(AtomicU64::new(0)),
            worker_count: workers,
            workers: joins,
        })
    }

    /// Production pool: every worker constructs its own PJRT [`Runtime`]
    /// over a clone of `manifest` (shared artifact dir, private client,
    /// private executable/weight caches).
    pub fn for_manifest(manifest: &Manifest, workers: usize) -> Result<ExecutorPool> {
        let manifest = manifest.clone();
        ExecutorPool::spawn(workers, move |_| Runtime::new(manifest.clone()))
    }

    /// Submit directly on the pool (same as `handle().submit`).
    pub fn submit(&self, job: ExecJob) -> ExecTicket {
        self.handle().submit(job)
    }

    /// Best-effort pool warm-up: one [`ExecJob::Warmup`] per worker,
    /// awaited together. Warming takes long enough that idle workers
    /// each pick one job up; a worker that grabs two just re-warms
    /// idempotently. Returns the number of warm jobs completed.
    pub fn warmup(&self, config: &str) -> Result<usize> {
        let tickets: Vec<ExecTicket> = (0..self.worker_count)
            .map(|_| self.submit(ExecJob::Warmup { config: config.to_string() }))
            .collect();
        let mut done = 0;
        for t in tickets {
            t.wait()?;
            done += 1;
        }
        Ok(done)
    }

    /// A cloneable, `Send` submission handle for other threads. NB: an
    /// outstanding handle keeps the job queue open, so dropping the pool
    /// blocks until every handle is gone.
    pub fn handle(&self) -> ExecutorHandle {
        ExecutorHandle {
            tx: self.tx.as_ref().expect("pool not yet shut down").clone(),
            jobs: self.jobs.clone(),
            workers: self.worker_count,
        }
    }

    pub fn workers(&self) -> usize {
        self.worker_count
    }

    pub fn jobs_submitted(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // Close the queue, let the workers drain what's already
        // enqueued, then join them.
        self.tx.take();
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

/// Execute one job on a worker's backend, panics contained.
fn run_job<B: ExecBackend>(
    backend: &mut B,
    job: ExecJob,
    worker: usize,
) -> Result<ExecDone, String> {
    let t0 = Instant::now();
    match job {
        ExecJob::Warmup { config } => {
            match catch_unwind(AssertUnwindSafe(|| backend.warmup(&config))) {
                Ok(Ok(_n)) => Ok(ExecDone {
                    outputs: Vec::new(),
                    inputs: Vec::new(),
                    busy_secs: t0.elapsed().as_secs_f64(),
                    worker,
                }),
                Ok(Err(e)) => Err(format!("{e:#}")),
                Err(payload) => Err(format!(
                    "worker {} panicked warming `{}`: {}",
                    worker,
                    config,
                    panic_message(&payload)
                )),
            }
        }
        job => {
            let (name, layer, args) = job.into_parts();
            let outcome = catch_unwind(AssertUnwindSafe(|| backend.run(&name, &args, layer)));
            match outcome {
                Ok(Ok(outputs)) => Ok(ExecDone {
                    outputs,
                    inputs: args,
                    busy_secs: t0.elapsed().as_secs_f64(),
                    worker,
                }),
                Ok(Err(e)) => Err(format!("{e:#}")),
                Err(payload) => Err(format!(
                    "worker {} panicked executing `{}`: {}",
                    worker,
                    name,
                    panic_message(&payload)
                )),
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Host-side backend: multiplies every f32 input by (layer + 2).
    struct Scaler;

    impl ExecBackend for Scaler {
        fn run(
            &mut self,
            name: &str,
            args: &[HostTensor],
            layer: Option<usize>,
        ) -> Result<Vec<HostTensor>> {
            if name == "explode" {
                panic!("requested panic");
            }
            let k = (layer.unwrap_or(0) + 2) as f32;
            Ok(args
                .iter()
                .map(|t| match t {
                    HostTensor::F32(d, s) => {
                        HostTensor::F32(d.iter().map(|x| x * k).collect(), s.clone())
                    }
                    HostTensor::I32(d, s) => HostTensor::I32(d.clone(), s.clone()),
                })
                .collect())
        }
    }

    fn f32s(v: &[f32]) -> HostTensor {
        HostTensor::F32(v.to_vec(), vec![v.len()])
    }

    #[test]
    fn jobs_round_trip_and_return_inputs() {
        let pool = ExecutorPool::spawn(2, |_| Ok(Scaler)).unwrap();
        let t = pool.submit(ExecJob::Qkv {
            name: "anything".into(),
            layer: 1,
            args: vec![f32s(&[1.0, 2.0])],
        });
        let done = t.wait().unwrap();
        assert_eq!(done.outputs, vec![f32s(&[3.0, 6.0])]);
        assert_eq!(done.inputs, vec![f32s(&[1.0, 2.0])], "inputs handed back for reuse");
        assert!(done.busy_secs >= 0.0);
        assert_eq!(pool.jobs_submitted(), 1);
    }

    #[test]
    fn out_of_order_joins() {
        let pool = ExecutorPool::spawn(3, |_| Ok(Scaler)).unwrap();
        let tickets: Vec<ExecTicket> = (0..16)
            .map(|i| {
                pool.submit(ExecJob::Raw {
                    name: format!("job{}", i),
                    layer: Some(i),
                    args: vec![f32s(&[i as f32])],
                })
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate().rev() {
            let done = t.wait().unwrap();
            assert_eq!(done.outputs, vec![f32s(&[i as f32 * (i + 2) as f32])]);
        }
    }

    #[test]
    fn startup_failure_aborts_the_pool() {
        let err = ExecutorPool::spawn(3, |i| {
            if i == 1 {
                Err(anyhow!("no backend on worker 1"))
            } else {
                Ok(Scaler)
            }
        })
        .map(|_| ())
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("no backend on worker 1"), "{}", msg);
    }
}
