//! Background speculative-recall pipeline.
//!
//! The paper's headline system claim (§4.2) is that streamed recall
//! *overlaps with computation*: the speculative selection made at layer
//! *l* of step *t* is recalled while the GPU computes layers *l+1..L*
//! (and the step's logits), so that by the time step *t+1* reaches layer
//! *l* the pages are already resident and only mispredicted heads pay a
//! blocking correction recall. This module makes that overlap real in
//! the rust engine with a dedicated worker thread, mirroring the
//! acceptor/engine thread split already used by `server`.
//!
//! # Queue protocol
//!
//! * The engine thread enqueues one [`RecallJob`] per (sequence, layer)
//!   right after that layer's attention + append, carrying the selection
//!   to install and the checked-out [`LayerXfer`] (select slots + CPU
//!   pool) — see *Ownership split* below.
//! * The worker performs the page-cache diff (`plan_selection`) and the
//!   double-buffered chunked recall (`TransferEngine::recall_page`) for
//!   every kv head, then sends a [`RecallDone`] back with the transfer
//!   half, per-job counters, and its busy time.
//! * Jobs are processed strictly FIFO; completions may be awaited out of
//!   order — [`RecallPipeline::wait`] parks early completions in a
//!   `(seq, layer)`-keyed ready map.
//!
//! # Ownership split
//!
//! Rust makes the concurrency discipline explicit: the *compute half* of
//! a layer's KV state (`GpuLayerCache`: sink/window slabs, summaries,
//! sequence length) never leaves the engine thread, while the *transfer
//! half* (`LayerXfer`: select slab + page table + CPU pool view) is
//! **moved** into the job and moved back in the completion. While a
//! layer's transfer half is in flight, `LayerState::xfer` is `None`, so
//! any accidental engine-side use is a loud panic instead of a data
//! race. The pool view itself is just a page→slot table plus an `Arc`
//! of the shared page allocator (`kvcache::alloc`) — moving it here
//! moves no page data, and the worker's recall reads go through the
//! allocator's refcounted handles (short critical sections), so pages
//! aliased across requests by the prefix cache are safe to read while
//! the engine offloads other pages into the same slab.
//!
//! # Drain points
//!
//! The engine re-attaches a layer's transfer half ("drains") at:
//! 1. step *t+1*, layer *l*, right after selection and before the
//!    correction check — the first point that needs the select table;
//! 2. end of any decode step for sequences that just finished, so a
//!    retired sequence never strands state on the worker;
//! 3. `Engine::drain_sequence`, for callers that stop decoding early.
//!
//! Time the worker spends recalling is recorded as *hidden*
//! (`busy_secs`); time the engine blocks in `wait` is the *exposed*
//! remainder and is accounted separately by the engine
//! (`EngineStats::recall_exposed_secs`).
//!
//! This worker owns host-side page movement only. Artifact execution is
//! handled separately: each PJRT client is `!Send` by design, so
//! `runtime::executor` runs a pool of clients (one per worker thread)
//! and the engine dispatches selection scoring — and, for paired
//! microbatches, QKV/attention — to it. The two workers compose: while
//! this thread recalls pages for step *t+1*, an executor worker can be
//! scoring step *t*'s selection.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Instant;

use crate::kvcache::{apply_selection_parts, LayerXfer};
use crate::transfer::engine::{TransferCounters, TransferEngine};
use crate::util::fault::{FaultPlan, FaultSite};

/// A speculative-recall work item for one (sequence, layer).
pub struct RecallJob {
    /// Unique id of the sequence (not the user-facing request id, which
    /// callers may reuse across sequences).
    pub seq_uid: u64,
    /// Layer the recall targets.
    pub layer: usize,
    /// Selected pages per kv head (already mask-filtered).
    pub selections: Vec<Vec<usize>>,
    /// The checked-out transfer half the recall operates on.
    pub xfer: LayerXfer,
}

/// Completion of a [`RecallJob`]: the transfer half plus accounting.
pub struct RecallDone {
    /// Sequence uid the job belonged to.
    pub seq_uid: u64,
    /// Layer the recall targeted.
    pub layer: usize,
    /// The transfer half, handed back unconditionally.
    pub xfer: LayerXfer,
    /// Pages actually moved (page-cache misses).
    pub recalled_pages: usize,
    /// The worker engine's counters for exactly this job.
    pub counters: TransferCounters,
    /// Wall time the worker spent on this job (hidden recall time).
    pub busy_secs: f64,
    /// `Some(selections)` when the worker did NOT complete the job — an
    /// injected worker death or a contained per-job panic. The transfer
    /// half always comes back (possibly with a partial selection
    /// installed); the engine must re-run the echoed selection inline.
    /// The invariant behind the whole ladder: a `LayerXfer` handed to
    /// the worker is ALWAYS handed back, whatever happened.
    pub aborted: Option<Vec<Vec<usize>>>,
}

/// Handle to the background recall worker. Dropping it closes the job
/// channel and joins the thread; any unclaimed completions are dropped
/// with it.
pub struct RecallPipeline {
    job_tx: Option<Sender<RecallJob>>,
    done_rx: Receiver<RecallDone>,
    worker: Option<JoinHandle<()>>,
    /// completions received but not yet claimed by `wait`.
    ready: HashMap<(u64, usize), RecallDone>,
    in_flight: usize,
    /// total jobs enqueued over the pipeline's lifetime.
    pub enqueued_jobs: u64,
}

impl RecallPipeline {
    /// Spawn the worker. `page_size`/`d_head` size its staging buffers
    /// (the same double-buffered pair a serial `TransferEngine` uses).
    pub fn new(page_size: usize, d_head: usize) -> RecallPipeline {
        RecallPipeline::with_faults(page_size, d_head, None)
    }

    /// [`RecallPipeline::new`] with a fault plan on the worker
    /// (`RecallWorkerDeath` aborts jobs, `SlowTransfer` stalls recalls).
    ///
    /// Failure containment: a per-job panic is caught on the worker and
    /// the job's transfer half is sent back with `aborted` set — the
    /// worker keeps serving. An injected worker death flips the worker
    /// into *dead mode*: it stops doing recall work and bounces every
    /// job back untouched (also `aborted`). Dead mode deliberately keeps
    /// the thread on its receive loop rather than exiting, so a
    /// `LayerXfer` can never be stranded in a closed channel; the engine
    /// degrades to serial recall after the first abort it sees.
    pub fn with_faults(
        page_size: usize,
        d_head: usize,
        faults: Option<Arc<FaultPlan>>,
    ) -> RecallPipeline {
        let (job_tx, job_rx) = channel::<RecallJob>();
        let (done_tx, done_rx) = channel::<RecallDone>();
        let worker = thread::Builder::new()
            .name("freekv-recall".into())
            .spawn(move || {
                let mut eng = TransferEngine::new(page_size, d_head, true);
                eng.faults = faults.clone();
                let mut dying = false;
                for job in job_rx {
                    if !dying {
                        if let Some(f) = &faults {
                            dying = f.check(FaultSite::RecallWorkerDeath);
                        }
                    }
                    let RecallJob { seq_uid, layer, selections, xfer } = job;
                    if dying {
                        let done = RecallDone {
                            seq_uid,
                            layer,
                            xfer,
                            recalled_pages: 0,
                            counters: TransferCounters::default(),
                            busy_secs: 0.0,
                            aborted: Some(selections),
                        };
                        if done_tx.send(done).is_err() {
                            break;
                        }
                        continue;
                    }
                    let t0 = Instant::now();
                    // The transfer half lives OUTSIDE the unwind boundary
                    // so it survives a panicking recall and always goes
                    // back to the engine.
                    let mut xfer_cell = Some(xfer);
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        let xf = xfer_cell.as_mut().expect("transfer half present");
                        let mut recalled = 0usize;
                        for (head, pages) in selections.iter().enumerate() {
                            recalled += apply_selection_parts(
                                &mut xf.select,
                                &xf.pool,
                                head,
                                pages,
                                &mut eng,
                            );
                        }
                        recalled
                    }));
                    let xfer = xfer_cell.take().expect("transfer half survives the job");
                    let counters = std::mem::take(&mut eng.counters);
                    let busy_secs = t0.elapsed().as_secs_f64();
                    let done = match outcome {
                        Ok(recalled) => RecallDone {
                            seq_uid,
                            layer,
                            xfer,
                            recalled_pages: recalled,
                            counters,
                            busy_secs,
                            aborted: None,
                        },
                        // Contained panic: partial work is fine — the
                        // inline redo of the echoed selection converges
                        // (apply_selection diffs against current slots).
                        Err(_) => RecallDone {
                            seq_uid,
                            layer,
                            xfer,
                            recalled_pages: 0,
                            counters,
                            busy_secs,
                            aborted: Some(selections),
                        },
                    };
                    if done_tx.send(done).is_err() {
                        break; // receiver gone: engine is shutting down
                    }
                }
            })
            .expect("spawning recall worker");
        RecallPipeline {
            job_tx: Some(job_tx),
            done_rx,
            worker: Some(worker),
            ready: HashMap::new(),
            in_flight: 0,
            enqueued_jobs: 0,
        }
    }

    /// Enqueue a job. Returns immediately; the worker picks it up FIFO.
    /// `Err` hands the job back when the worker is unreachable (channel
    /// closed) — the caller must then run the recall inline.
    pub fn submit(&mut self, job: RecallJob) -> Result<(), RecallJob> {
        let Some(tx) = self.job_tx.as_ref() else { return Err(job) };
        match tx.send(job) {
            Ok(()) => {
                self.in_flight += 1;
                self.enqueued_jobs += 1;
                Ok(())
            }
            Err(std::sync::mpsc::SendError(job)) => Err(job),
        }
    }

    /// Jobs submitted but not yet absorbed into the ready map.
    pub fn pending(&self) -> usize {
        self.in_flight
    }

    fn absorb(&mut self, done: RecallDone) {
        self.in_flight -= 1;
        let key = (done.seq_uid, done.layer);
        let prev = self.ready.insert(key, done);
        debug_assert!(prev.is_none(), "duplicate in-flight job for {:?}", key);
    }

    /// Non-blocking sweep of finished jobs into the ready map.
    pub fn poll(&mut self) {
        while let Ok(done) = self.done_rx.try_recv() {
            self.absorb(done);
        }
    }

    /// Block until the job for (seq_uid, layer) completes and return it.
    /// Earlier completions for other keys are parked in the ready map.
    /// `None` means the worker vanished without returning the transfer
    /// half — unreachable under the dead-mode protocol (a dying worker
    /// bounces jobs back instead of exiting), so callers treat it as the
    /// sequence's state being unrecoverable.
    pub fn wait(&mut self, seq_uid: u64, layer: usize) -> Option<RecallDone> {
        self.poll();
        loop {
            if let Some(done) = self.ready.remove(&(seq_uid, layer)) {
                return Some(done);
            }
            match self.done_rx.recv() {
                Ok(done) => self.absorb(done),
                Err(_) => {
                    self.in_flight = 0;
                    return None;
                }
            }
        }
    }
}

impl Drop for RecallPipeline {
    fn drop(&mut self) {
        // Closing the job channel ends the worker's loop; join so no
        // detached thread outlives the engine.
        self.job_tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{LayerPool, LayerXfer, Layout, SelectSlots};
    use crate::util::rng::Rng;

    fn xfer(pages: usize, m: usize, p: usize, d: usize, seed: u64) -> LayerXfer {
        let mut pool = LayerPool::new(Layout::Hnd, pages, m, p, d);
        let mut rng = Rng::new(seed);
        for pg in 0..pages {
            let k: Vec<f32> = (0..p * m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..p * m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            pool.write_page(pg, &k, &v);
        }
        LayerXfer { select: SelectSlots::new(m, d, p, 2), pool }
    }

    #[test]
    fn worker_matches_inline_recall() {
        let (pages, m, p, d) = (8, 2, 4, 8);
        // inline reference
        let mut a = xfer(pages, m, p, d, 42);
        let mut eng = TransferEngine::new(p, d, true);
        let sel_pages = vec![vec![1usize, 3], vec![2usize, 5]];
        let mut inline_recalled = 0;
        for (head, pg) in sel_pages.iter().enumerate() {
            inline_recalled += apply_selection_parts(&mut a.select, &a.pool, head, pg, &mut eng);
        }
        // worker path on an identical transfer half
        let b = xfer(pages, m, p, d, 42);
        let mut pipe = RecallPipeline::new(p, d);
        assert!(pipe
            .submit(RecallJob { seq_uid: 7, layer: 0, selections: sel_pages.clone(), xfer: b })
            .is_ok());
        let done = pipe.wait(7, 0).expect("worker returns the job");
        assert!(done.aborted.is_none());
        assert_eq!(done.recalled_pages, inline_recalled);
        assert_eq!(done.counters.recalled_pages, eng.counters.recalled_pages);
        assert_eq!(done.counters.h2d_chunks, eng.counters.h2d_chunks);
        assert_eq!(done.counters.h2d_bytes, eng.counters.h2d_bytes);
        for head in 0..m {
            assert_eq!(done.xfer.select.selected(head), a.select.selected(head));
        }
        assert_eq!(pipe.pending(), 0);
    }

    #[test]
    fn completions_awaitable_out_of_order() {
        let (pages, m, p, d) = (8, 2, 4, 8);
        let mut pipe = RecallPipeline::new(p, d);
        for layer in 0..4usize {
            assert!(pipe
                .submit(RecallJob {
                    seq_uid: 1,
                    layer,
                    selections: vec![vec![1 + layer % 3], vec![2]],
                    xfer: xfer(pages, m, p, d, layer as u64),
                })
                .is_ok());
        }
        assert_eq!(pipe.pending(), 4);
        // await in reverse order: FIFO completions get parked and matched
        for layer in (0..4usize).rev() {
            let done = pipe.wait(1, layer).expect("worker alive");
            assert_eq!(done.layer, layer);
            assert!(done.xfer.select.selected(0).iter().flatten().count() > 0);
        }
        assert_eq!(pipe.pending(), 0);
        assert_eq!(pipe.enqueued_jobs, 4);
    }

    #[test]
    fn injected_worker_death_bounces_jobs_back() {
        let (pages, m, p, d) = (8, 2, 4, 8);
        let plan = Arc::new(FaultPlan::events(&[(FaultSite::RecallWorkerDeath, 1)]));
        let mut pipe = RecallPipeline::with_faults(p, d, Some(plan.clone()));
        assert!(pipe
            .submit(RecallJob {
                seq_uid: 1,
                layer: 0,
                selections: vec![vec![1], vec![2]],
                xfer: xfer(pages, m, p, d, 1),
            })
            .is_ok());
        let first = pipe.wait(1, 0).expect("first job completes normally");
        assert!(first.aborted.is_none());
        assert!(first.recalled_pages > 0);
        // the second job hits the injected death: bounced back untouched
        assert!(pipe
            .submit(RecallJob {
                seq_uid: 1,
                layer: 1,
                selections: vec![vec![3], vec![4]],
                xfer: xfer(pages, m, p, d, 2),
            })
            .is_ok());
        let second = pipe.wait(1, 1).expect("aborted jobs still return the transfer half");
        assert_eq!(second.aborted.as_deref(), Some(&[vec![3usize], vec![4usize]][..]));
        assert_eq!(second.recalled_pages, 0);
        // dead mode is sticky: later jobs bounce too, nothing is stranded
        assert!(pipe
            .submit(RecallJob {
                seq_uid: 1,
                layer: 2,
                selections: vec![vec![1], vec![1]],
                xfer: xfer(pages, m, p, d, 3),
            })
            .is_ok());
        assert!(pipe.wait(1, 2).expect("still answering").aborted.is_some());
        assert_eq!(pipe.pending(), 0);
        assert_eq!(plan.fired(FaultSite::RecallWorkerDeath), 1);
    }

    #[test]
    fn job_panic_is_contained_and_returns_the_transfer_half() {
        let (pages, m, p, d) = (4, 2, 4, 8);
        let mut pipe = RecallPipeline::new(p, d);
        // page 99 is out of range for a 4-page pool: the recall panics
        // on the worker; the transfer half must still come back
        assert!(pipe
            .submit(RecallJob {
                seq_uid: 5,
                layer: 0,
                selections: vec![vec![99], vec![0]],
                xfer: xfer(pages, m, p, d, 9),
            })
            .is_ok());
        let done = pipe.wait(5, 0).expect("transfer half survives the panic");
        assert!(done.aborted.is_some(), "panicked job reports aborted");
        // the worker survives one poisoned job and keeps serving
        assert!(pipe
            .submit(RecallJob {
                seq_uid: 5,
                layer: 1,
                selections: vec![vec![1], vec![2]],
                xfer: xfer(pages, m, p, d, 10),
            })
            .is_ok());
        assert!(pipe.wait(5, 1).expect("still serving").aborted.is_none());
    }
}
