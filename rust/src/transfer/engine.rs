//! Transfer engine: the CPU<->GPU data mover.
//!
//! On this testbed "device" and "host" are both host memory, but the
//! engine moves data with exactly the chunk granularity the layouts
//! dictate (one 2*p*d chunk per head under HND, 2*p chunks of d under
//! NHD) through a double-buffered staging pipeline, and records counters
//! (chunks / bytes / calls) that the cost model turns into modeled PCIe
//! time. Real wall time per phase is also measured for the perf pass.
//!
//! One `TransferEngine` lives on the engine thread (offload + blocking
//! correction recalls) and one inside the background recall worker
//! (`transfer::pipeline`); the worker's counters are snapshotted per job
//! and merged back at the drain point.

use std::sync::Arc;
use std::time::Instant;

use crate::kvcache::gpu::{CompletedPage, SelectSlots};
use crate::kvcache::pool::{LayerPool, Layout};
use crate::util::fault::{FaultPlan, FaultSite};

/// Cumulative transfer counters: chunk/byte/page counts per direction
/// plus measured wall time, mirroring the paper's Fig. 5 accounting.
#[derive(Debug, Default, Clone)]
pub struct TransferCounters {
    /// DMA transactions issued host-to-device.
    pub h2d_chunks: u64,
    /// Logical (decoded f32) bytes recalled — layout/selection driven,
    /// codec independent, comparable across dtypes.
    pub h2d_bytes: u64,
    /// Recall invocations (one per page-head recalled).
    pub h2d_calls: u64,
    /// DMA transactions issued device-to-host.
    pub d2h_chunks: u64,
    /// Logical (decoded f32) bytes offloaded.
    pub d2h_bytes: u64,
    /// Encoded wire bytes recalled (quantized payload + scale sidecar);
    /// equals `h2d_bytes` on an f32 pool.
    pub h2d_encoded_bytes: u64,
    /// Encoded wire bytes offloaded into the pool; equals `d2h_bytes`
    /// on an f32 pool (prefix hits move nothing).
    pub d2h_encoded_bytes: u64,
    /// Bytes run through HND→NHD layout conversion on device.
    pub convert_bytes: u64,
    /// (page, head) pairs recalled from the CPU pool.
    pub recalled_pages: u64,
    /// (page, head) pairs offloaded into the CPU pool.
    pub offloaded_pages: u64,
    /// Offloads satisfied by aliasing a resident prefix-matched page:
    /// no bytes moved, no pool page written.
    pub prefix_hits: u64,
    /// Measured wall time inside recall copies, seconds.
    pub real_h2d_secs: f64,
    /// Measured wall time inside layout conversion, seconds.
    pub real_convert_secs: f64,
    /// Measured wall time inside offload copies, seconds.
    pub real_d2h_secs: f64,
}

impl TransferCounters {
    /// Element-wise sum of two counter sets (aggregating workers).
    pub fn merged(&self, o: &TransferCounters) -> TransferCounters {
        TransferCounters {
            h2d_chunks: self.h2d_chunks + o.h2d_chunks,
            h2d_bytes: self.h2d_bytes + o.h2d_bytes,
            h2d_calls: self.h2d_calls + o.h2d_calls,
            d2h_chunks: self.d2h_chunks + o.d2h_chunks,
            d2h_bytes: self.d2h_bytes + o.d2h_bytes,
            h2d_encoded_bytes: self.h2d_encoded_bytes + o.h2d_encoded_bytes,
            d2h_encoded_bytes: self.d2h_encoded_bytes + o.d2h_encoded_bytes,
            convert_bytes: self.convert_bytes + o.convert_bytes,
            recalled_pages: self.recalled_pages + o.recalled_pages,
            offloaded_pages: self.offloaded_pages + o.offloaded_pages,
            prefix_hits: self.prefix_hits + o.prefix_hits,
            real_h2d_secs: self.real_h2d_secs + o.real_h2d_secs,
            real_convert_secs: self.real_convert_secs + o.real_convert_secs,
            real_d2h_secs: self.real_d2h_secs + o.real_d2h_secs,
        }
    }
}

/// Staging buffers for streamed recall. Two buffers so the layout
/// conversion of page i can proceed while page i+1 streams in (§4.2,
/// Fig. 6 right); the `double_buffer` flag is the DB ablation switch.
pub struct TransferEngine {
    staging: [Vec<f32>; 2],
    cur: usize,
    /// Alternate staging buffers between recalls (the DB ablation).
    pub double_buffer: bool,
    /// Cumulative transfer counters for this engine.
    pub counters: TransferCounters,
    /// Fault injection (`SlowTransfer` stalls a recall). Set by the
    /// recall pipeline on its worker's engine; `None` in production.
    pub faults: Option<Arc<FaultPlan>>,
}

impl TransferEngine {
    /// Engine with staging sized for `p`-slot pages of `d`-dim heads.
    pub fn new(p: usize, d: usize, double_buffer: bool) -> TransferEngine {
        TransferEngine {
            staging: [vec![0.0; 2 * p * d], vec![0.0; 2 * p * d]],
            cur: 0,
            double_buffer,
            counters: TransferCounters::default(),
            faults: None,
        }
    }

    /// Recall one (page, head) pair from the CPU pool into a GPU select
    /// slot. Phase 1 streams the pool chunks into a staging buffer
    /// ("PCIe"); phase 2 converts/installs into the NHD select slab
    /// ("GPU").
    pub fn recall_page(
        &mut self,
        pool: &LayerPool,
        page: usize,
        head: usize,
        sel: &mut SelectSlots,
        slot_j: usize,
    ) {
        if let Some(f) = &self.faults {
            if f.check(FaultSite::SlowTransfer) {
                // A degraded link: the recall still completes, it just
                // pays a stall (shows up as hidden/exposed recall time).
                std::thread::sleep(f.slow_transfer_delay());
            }
        }
        let (p, d) = (pool.p, pool.d);
        let chunks = pool.recall_chunks(page, head);
        let buf_idx = self.cur;
        if self.double_buffer {
            self.cur = 1 - self.cur;
        }

        // Phase 1: chunked "DMA" into staging, normalized to
        // [K tokens | V tokens] token-major order. The pool view
        // snapshots its (possibly shared) slot under that layer's
        // shard lock and decodes outside it (generation-checked).
        let t0 = Instant::now();
        {
            let staging = &mut self.staging[buf_idx];
            let off = pool.copy_chunks(page, &chunks, staging);
            self.counters.h2d_chunks += chunks.len() as u64;
            self.counters.h2d_bytes += (off * 4) as u64;
            self.counters.h2d_encoded_bytes +=
                (pool.encoded_bytes(off) + pool.head_scale_bytes()) as u64;
            self.counters.h2d_calls += 1;
        }
        self.counters.real_h2d_secs += t0.elapsed().as_secs_f64();

        // Phase 2: layout conversion + install. Under HND the staging
        // buffer is already [K|V] token-major (conversion = the NHD
        // scatter, charged to the Convert stream); under NHD the chunk
        // order happens to be token-major per plane too, so the same
        // install applies but *every chunk* paid the fragmented PCIe cost
        // in phase 1.
        let t1 = Instant::now();
        {
            let staging = &self.staging[buf_idx];
            let (k_head, v_head) = staging.split_at(p * d);
            sel.install(head, slot_j, page, k_head, &v_head[..p * d]);
            self.counters.convert_bytes += (2 * p * d * 4) as u64;
        }
        self.counters.real_convert_secs += t1.elapsed().as_secs_f64();
        self.counters.recalled_pages += 1;
    }

    /// Offload a completed page to the CPU pool. Under HND the transpose
    /// happens here, once per page (amortized off the decode path, §4.2);
    /// chunk accounting reflects the wire format: n_kv contiguous
    /// per-head chunks for HND, 2 plane chunks for NHD.
    pub fn offload_page(&mut self, cp: &CompletedPage, pool: &mut LayerPool) {
        self.offload_page_keyed(cp, pool, None);
    }

    /// `offload_page` with an optional prefix key. When the key matches
    /// a page a resident request already committed, the pool aliases
    /// that page instead of writing a duplicate: no D2H traffic, no new
    /// pool page — counted as a `prefix_hits` (the page still counts as
    /// offloaded: it is resident and recallable).
    pub fn offload_page_keyed(
        &mut self,
        cp: &CompletedPage,
        pool: &mut LayerPool,
        key: Option<u128>,
    ) {
        if let Some(h) = key {
            if pool.try_adopt(cp.page, h) {
                self.counters.prefix_hits += 1;
                self.counters.offloaded_pages += 1;
                return;
            }
        }
        let t0 = Instant::now();
        pool.write_page_keyed(cp.page, &cp.k_nhd, &cp.v_nhd, key);
        let bytes = ((cp.k_nhd.len() + cp.v_nhd.len()) * 4) as u64;
        self.counters.d2h_bytes += bytes;
        self.counters.d2h_encoded_bytes += pool.page_encoded_bytes() as u64;
        self.counters.d2h_chunks += match pool.layout {
            Layout::Hnd => pool.n_kv as u64,
            Layout::Nhd => 2,
        };
        self.counters.offloaded_pages += 1;
        self.counters.real_d2h_secs += t0.elapsed().as_secs_f64();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::gpu::GpuLayerCache;
    use crate::util::rng::Rng;

    fn setup(layout: Layout) -> (LayerPool, GpuLayerCache, SelectSlots, TransferEngine) {
        let (m, d, p) = (2, 8, 4);
        let pool = LayerPool::new(layout, 16, m, p, d);
        let gpu = GpuLayerCache::new(m, d, p, 1, 2, 2, 16);
        let sel = gpu.new_select_slots();
        let eng = TransferEngine::new(p, d, true);
        (pool, gpu, sel, eng)
    }

    fn run_roundtrip(layout: Layout) {
        let (mut pool, mut gpu, mut sel, mut eng) = setup(layout);
        let mut rng = Rng::new(11);
        // Fill 5 pages through the GPU cache, offloading as they complete.
        let mut kept: Vec<CompletedPage> = Vec::new();
        for _ in 0..20 {
            let k: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            if let Some(cp) = gpu.append(&k, &v) {
                eng.offload_page(&cp, &mut pool);
                kept.push(cp);
            }
        }
        assert_eq!(eng.counters.offloaded_pages, 5);
        // Recall page 1 for head 1 into select slot 0 and check content.
        eng.recall_page(&pool, 1, 1, &mut sel, 0);
        assert_eq!(sel.selected(1)[0], Some(1));
        let cp = &kept[1];
        let s = gpu.budget_slots();
        let (mut gk, mut gv, mut valid) =
            (vec![0.0; 2 * s * 8], vec![0.0; 2 * s * 8], vec![0.0; 2 * s]);
        gpu.gather_full(&mut sel, &mut gk, &mut gv, &mut valid);
        let select_slot = (1 + 2) * 4; // sink 1 page + window 2 pages
        for tok in 0..4 {
            for dim in 0..8 {
                let got = gk[(1 * s + select_slot + tok) * 8 + dim];
                let want = cp.k_nhd[(tok * 2 + 1) * 8 + dim];
                assert_eq!(got, want, "layout {:?} tok {} dim {}", layout, tok, dim);
                let gotv = gv[(1 * s + select_slot + tok) * 8 + dim];
                assert_eq!(gotv, cp.v_nhd[(tok * 2 + 1) * 8 + dim]);
            }
            assert_eq!(valid[1 * s + select_slot + tok], 1.0);
        }
    }

    #[test]
    fn roundtrip_hnd() {
        run_roundtrip(Layout::Hnd);
    }

    #[test]
    fn roundtrip_nhd() {
        run_roundtrip(Layout::Nhd);
    }

    #[test]
    fn chunk_counters_reflect_layout() {
        for (layout, per_page_head) in [(Layout::Hnd, 1u64), (Layout::Nhd, 8u64)] {
            let (mut pool, mut gpu, mut sel, mut eng) = setup(layout);
            let mut rng = Rng::new(3);
            for _ in 0..8 {
                let k: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                if let Some(cp) = gpu.append(&k.clone(), &k) {
                    eng.offload_page(&cp, &mut pool);
                }
            }
            eng.recall_page(&pool, 0, 0, &mut sel, 0);
            eng.recall_page(&pool, 1, 1, &mut sel, 0);
            assert_eq!(eng.counters.h2d_chunks, 2 * per_page_head, "{:?}", layout);
            assert_eq!(eng.counters.h2d_bytes, 2 * (2 * 4 * 8 * 4) as u64);
            assert_eq!(eng.counters.recalled_pages, 2);
            // on the default f32 pool the wire bytes ARE the logical bytes
            assert_eq!(eng.counters.h2d_encoded_bytes, eng.counters.h2d_bytes);
        }
    }

    #[test]
    fn encoded_byte_gauges_track_the_codec() {
        use crate::kvcache::quant::KvDtype;
        let (m, d, p) = (2usize, 8usize, 4usize);
        let mut wire = Vec::new();
        for dtype in KvDtype::all() {
            let mut pool = LayerPool::new_dtype(Layout::Hnd, 16, m, p, d, dtype);
            let mut gpu = GpuLayerCache::new(m, d, p, 1, 2, 2, 16);
            let mut sel = gpu.new_select_slots();
            let mut eng = TransferEngine::new(p, d, true);
            let mut rng = Rng::new(7);
            for _ in 0..8 {
                let k: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                if let Some(cp) = gpu.append(&k, &v) {
                    eng.offload_page(&cp, &mut pool);
                }
            }
            eng.recall_page(&pool, 0, 0, &mut sel, 0);
            let c = &eng.counters;
            // logical gauges are codec-independent
            assert_eq!(c.h2d_bytes, (2 * p * d * 4) as u64, "{:?}", dtype);
            assert_eq!(c.d2h_bytes, (2 * 2 * m * p * d * 4) as u64, "{:?}", dtype);
            if dtype == KvDtype::F32 {
                assert_eq!(c.h2d_encoded_bytes, c.h2d_bytes);
                assert_eq!(c.d2h_encoded_bytes, c.d2h_bytes);
            } else {
                assert!(c.h2d_encoded_bytes < c.h2d_bytes / 3, "{:?}", dtype);
                assert!(c.d2h_encoded_bytes < c.d2h_bytes / 3, "{:?}", dtype);
            }
            wire.push(c.d2h_encoded_bytes);
        }
        assert!(wire[2] < wire[1] && wire[1] < wire[0], "int4 < int8 < f32: {:?}", wire);
    }
}
