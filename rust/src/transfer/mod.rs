//! CPU<->GPU transfer path: double-buffered streamed recall, offload with
//! amortized layout transpose, chunk-accurate counters, and the
//! background speculative-recall pipeline that overlaps page movement
//! with the engine's compute.

pub mod engine;
pub mod pipeline;

pub use engine::{TransferCounters, TransferEngine};
pub use pipeline::{RecallDone, RecallJob, RecallPipeline};
