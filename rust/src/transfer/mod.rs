//! CPU<->GPU transfer path: double-buffered streamed recall, offload with
//! amortized layout transpose, and chunk-accurate counters.

pub mod engine;

pub use engine::{TransferCounters, TransferEngine};
