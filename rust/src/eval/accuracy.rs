//! Accuracy eval drivers: Fig. 1 (left), Fig. 2b, Tables 2-9.
//!
//! Every driver prints the paper-shaped table and saves a CSV under
//! `results/`. Scores are oracle analogs (see DESIGN.md): the reproduced
//! claim is each exhibit's *ordering and gaps*, not absolute benchmark
//! points.

use crate::config::{FreeKvParams, SelectVariant};
use crate::oracle::{generate, OracleParams, TaskKind, TaskSpec, Trace};
use crate::policies::accuracy::{run_episode, AccBudget, AccKnobs, EpisodeResult};
use crate::policies::latency::Method;
use crate::util::table::{fnum, Table};

/// Paper model analogs: (display name, n_qo, n_kv).
pub const MODELS: [(&str, usize, usize); 3] =
    [("llama-3.1-8b", 32, 8), ("qwen-2.5-7b", 28, 4), ("qwen-2.5-14b", 40, 8)];

/// Directory CSV exhibits are saved under (`None` disables saving).
pub fn out_dir() -> Option<&'static str> {
    Some("results")
}

fn traces_for(kind: TaskKind, n_qo: usize, n_kv: usize, seeds: u64) -> Vec<Trace> {
    let spec = TaskSpec::default_for(kind);
    (0..seeds)
        .map(|s| generate(&spec, n_qo, n_kv, &OracleParams::default(), s * 7919 + kind as u64))
        .collect()
}

fn mean_ep(
    method: Method,
    variant: SelectVariant,
    traces: &[Trace],
    knobs: &AccKnobs,
) -> EpisodeResult {
    let mut agg = EpisodeResult::default();
    for (i, tr) in traces.iter().enumerate() {
        let r = run_episode(method, variant, tr, &AccBudget::default(), knobs, i as u64);
        agg.mass_recall += r.mass_recall;
        agg.task_score += r.task_score;
        agg.completion_rate += r.completion_rate;
        agg.correction_rate += r.correction_rate;
        agg.mean_query_sim += r.mean_query_sim;
        if r.solved {
            agg.solved = true; // pass@k
        }
    }
    let n = traces.len() as f64;
    agg.mass_recall /= n;
    agg.task_score /= n;
    agg.completion_rate /= n;
    agg.correction_rate /= n;
    agg.mean_query_sim /= n;
    agg
}

fn knobs_for(method: Method, kind: TaskKind) -> AccKnobs {
    let tau = match kind {
        TaskKind::Niah | TaskKind::Summarization => 0.8, // long-input (App. A)
        _ => 0.9,                                        // long-generation
    };
    AccKnobs { freekv: FreeKvParams { tau, ..Default::default() }, ..Default::default() }
        .tap(|k| {
            let _ = method;
            let _ = k;
        })
}

trait Tap: Sized {
    fn tap<F: FnOnce(&Self)>(self, f: F) -> Self {
        f(&self);
        self
    }
}
impl<T> Tap for T {}

/// Fig. 1 (left): dropping vs retrieval accuracy by task category.
pub fn fig1_accuracy(seeds: u64) -> Table {
    let mut t = Table::new(
        "Fig. 1 (left) — accuracy analog by task (oracle; x100)",
        &["method", "niah", "summarization", "reasoning"],
    );
    let methods = [Method::Razor, Method::RaaS, Method::Quest, Method::FreeKv, Method::Full];
    for m in methods {
        let mut row = vec![m.name().to_string()];
        for kind in [TaskKind::Niah, TaskKind::Summarization, TaskKind::Reasoning] {
            let traces = traces_for(kind, 32, 8, seeds);
            let r = mean_ep(m, SelectVariant::MeanS, &traces, &knobs_for(m, kind));
            row.push(fnum(r.task_score * 100.0));
        }
        t.row(row);
    }
    t
}

/// Table 2: long-input (LongBench-v2 analog) + long-generation
/// (LongGenBench analog) accuracy per model and method.
pub fn table2(seeds: u64) -> Vec<Table> {
    let methods = [
        Method::Full,
        Method::Razor,
        Method::RaaS,
        Method::Quest,
        Method::ArkVale,
        Method::ShadowKv,
        Method::InfiniGen,
        Method::FreeKv,
    ];
    let mut out = Vec::new();
    for (model, n_qo, n_kv) in MODELS {
        let mut t = Table::new(
            &format!("Table 2 analog — {} (oracle scores x100)", model),
            &["method", "longinput-acc", "longgen-CR", "longgen-CRxAcc"],
        );
        let li: Vec<Trace> = traces_for(TaskKind::Summarization, n_qo, n_kv, seeds);
        let lg: Vec<Trace> = traces_for(TaskKind::LongGen, n_qo, n_kv, seeds);
        for m in methods {
            let rli = mean_ep(m, SelectVariant::MeanS, &li, &knobs_for(m, TaskKind::Summarization));
            let rlg = mean_ep(m, SelectVariant::MeanS, &lg, &knobs_for(m, TaskKind::LongGen));
            t.row(vec![
                m.name().into(),
                fnum(rli.task_score * 100.0),
                fnum(rlg.completion_rate * 100.0),
                fnum(rlg.completion_rate * rlg.mass_recall * 100.0),
            ]);
        }
        out.push(t);
    }
    out
}

/// Table 3: reasoning tasks, pass@k / avg@k per model.
pub fn table3(k: u64) -> Vec<Table> {
    let methods = [
        Method::Full,
        Method::Razor,
        Method::RaaS,
        Method::Quest,
        Method::ArkVale,
        Method::ShadowKv,
        Method::InfiniGen,
        Method::FreeKv,
    ];
    // Three reasoning "datasets" of increasing difficulty: revisit density
    // and outlier frequency grow (MATH500 -> GPQA -> AIME-like).
    let datasets: [(&str, f32); 3] = [("math500", 0.015), ("gpqa", 0.03), ("aime24", 0.05)];
    let mut out = Vec::new();
    for (model, n_qo, n_kv) in MODELS {
        let mut t = Table::new(
            &format!("Table 3 analog — {} reasoning (x100)", model),
            &["method", "math500 pass@k", "math500 avg@k", "gpqa pass@k", "gpqa avg@k",
              "aime24 pass@k", "aime24 avg@k"],
        );
        let mut rows: Vec<Vec<String>> =
            methods.iter().map(|m| vec![m.name().to_string()]).collect();
        for (_ds, outlier) in datasets {
            let spec = TaskSpec::default_for(TaskKind::Reasoning);
            let params = OracleParams { outlier_prob: outlier, ..Default::default() };
            let traces: Vec<Trace> = (0..k)
                .map(|s| generate(&spec, n_qo, n_kv, &params, s * 31 + (outlier * 1e4) as u64))
                .collect();
            for (mi, m) in methods.iter().enumerate() {
                let knobs = knobs_for(*m, TaskKind::Reasoning);
                let mut solved = 0usize;
                let mut avg = 0.0;
                for (i, tr) in traces.iter().enumerate() {
                    let r = run_episode(*m, SelectVariant::MeanS, tr, &AccBudget::default(), &knobs, i as u64);
                    if r.solved {
                        solved += 1;
                    }
                    avg += r.task_score;
                }
                rows[mi].push(fnum(if solved > 0 { 100.0 } else { 0.0 }));
                rows[mi].push(fnum(avg / k as f64 * 100.0));
            }
        }
        for r in rows {
            t.row(r);
        }
        out.push(t);
    }
    out
}

/// Table 4: recall with last-layer query vs last-step query (App. B.1).
pub fn table4(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 4 analog — last-layer vs last-step query (x100)",
        &["query source", "longinput", "longgen", "reasoning"],
    );
    for (label, last_layer) in [("last layer", true), ("last step (speculative)", false)] {
        let mut row = vec![label.to_string()];
        for kind in [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning] {
            let traces = traces_for(kind, 28, 4, seeds);
            let knobs = AccKnobs {
                freekv: FreeKvParams { tau: 0.0, ..Default::default() }, // pure speculation
                freekv_last_layer_proxy: last_layer,
                ..Default::default()
            };
            let r = mean_ep(Method::FreeKv, SelectVariant::MeanS, &traces, &knobs);
            row.push(fnum(r.task_score * 100.0));
        }
        t.row(row);
    }
    t
}

/// Table 5: group-consistent selection variants (App. B.2).
pub fn table5(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 5 analog — selection variants (x100)",
        &["variant", "longinput", "longgen", "reasoning", "mass-recall"],
    );
    for variant in SelectVariant::all() {
        let mut row = vec![variant.as_str().to_string()];
        let mut mass = 0.0;
        for kind in [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning] {
            let traces = traces_for(kind, 28, 4, seeds);
            let r = mean_ep(Method::FreeKv, variant, &traces, &knobs_for(Method::FreeKv, kind));
            row.push(fnum(r.task_score * 100.0));
            mass += r.mass_recall / 3.0;
        }
        row.push(fnum(mass * 100.0));
        t.row(row);
    }
    t
}

/// Table 6: correction pooling mean vs max (App. B.3).
pub fn table6(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 6 analog — correction pooling (x100)",
        &["pooling", "longgen", "reasoning", "correction-rate"],
    );
    for (label, maxp) in [("mean", false), ("max", true)] {
        let mut row = vec![label.to_string()];
        let mut cr = 0.0;
        for kind in [TaskKind::LongGen, TaskKind::Reasoning] {
            let traces = traces_for(kind, 28, 4, seeds);
            let knobs = AccKnobs {
                freekv: FreeKvParams { tau: 0.9, correction_pool_max: maxp, ..Default::default() },
                ..Default::default()
            };
            let r = mean_ep(Method::FreeKv, SelectVariant::MeanS, &traces, &knobs);
            row.push(fnum(r.task_score * 100.0));
            cr += r.correction_rate / 2.0;
        }
        row.push(fnum(cr));
        t.row(row);
    }
    t
}

/// Table 7: correction threshold sweep (App. B.3).
pub fn table7(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 7 analog — correction threshold tau (x100)",
        &["tau", "longinput", "longgen", "reasoning", "correction-rate"],
    );
    for tau in [0.0f32, 0.7, 0.8, 0.9, 1.0] {
        let label = if tau == 0.0 {
            "0 (no correction)".to_string()
        } else if tau >= 1.0 {
            "1 (no speculation)".to_string()
        } else {
            format!("{}", tau)
        };
        let mut row = vec![label];
        let mut cr = 0.0;
        for kind in [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning] {
            let traces = traces_for(kind, 28, 4, seeds);
            let knobs = AccKnobs {
                freekv: FreeKvParams {
                    tau,
                    no_speculation: tau >= 1.0,
                    ..Default::default()
                },
                ..Default::default()
            };
            let r = mean_ep(Method::FreeKv, SelectVariant::MeanS, &traces, &knobs);
            row.push(fnum(r.task_score * 100.0));
            cr += r.correction_rate / 3.0;
        }
        row.push(fnum(cr));
        t.row(row);
    }
    t
}

/// Table 8: query similarity across models/tasks (oracle calibration).
pub fn table8(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 8 analog — mean adjacent-step query similarity",
        &["model", "summarization", "longgen", "reasoning", "niah"],
    );
    // Architecture analogs: alpha controls the AR(1) persistence.
    let archs: [(&str, usize, usize, f32); 4] = [
        ("qwen-2.5-7b", 28, 4, 0.995),
        ("llama-3.1-8b", 32, 8, 0.993),
        ("qwen-2.5-14b", 40, 8, 0.994),
        ("qwen-3-8b", 32, 8, 0.988),
    ];
    for (name, n_qo, n_kv, alpha) in archs {
        let mut row = vec![name.to_string()];
        for kind in
            [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning, TaskKind::Niah]
        {
            let spec = TaskSpec::default_for(kind);
            let params = OracleParams { alpha, ..Default::default() };
            let mut s = 0.0;
            for seed in 0..seeds {
                let tr = generate(&spec, n_qo, n_kv, &params, seed * 13 + 5);
                let r = run_episode(
                    Method::FreeKv,
                    SelectVariant::MeanS,
                    &tr,
                    &AccBudget::default(),
                    &AccKnobs::default(),
                    seed,
                );
                s += r.mean_query_sim;
            }
            row.push(fnum(s / seeds as f64));
        }
        t.row(row);
    }
    t
}

/// Table 9: correction rates by task and threshold.
pub fn table9(seeds: u64) -> Table {
    let mut t = Table::new(
        "Table 9 analog — correction rates",
        &["setting", "longinput", "longgen", "reasoning"],
    );
    for (model, n_qo, n_kv) in [("llama-8b", 32usize, 8usize), ("qwen-7b", 28, 4)] {
        for tau in [0.8f32, 0.9] {
            let mut row = vec![format!("{}, tau={}", model, tau)];
            for kind in [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning] {
                let traces = traces_for(kind, n_qo, n_kv, seeds);
                let knobs = AccKnobs {
                    freekv: FreeKvParams { tau, ..Default::default() },
                    ..Default::default()
                };
                let r = mean_ep(Method::FreeKv, SelectVariant::MeanS, &traces, &knobs);
                row.push(fnum(r.correction_rate));
            }
            t.row(row);
        }
    }
    t
}

/// KV-dtype ablation: FreeKV accuracy when offloaded pages are stored
/// through the page codec (kvcache::quant) instead of f32.
///
/// The oracle carries score surfaces rather than raw K/V tensors, so
/// quantization enters through what a retrieval policy *reads back from
/// CPU pages*: every score row (summary / MeanQ / MaxQ) and the realized
/// attention-weight rows pass through the codec roundtrip with one scale
/// per row — the same per-(page, head) scale granularity the slab codec
/// uses. Weight rows are renormalized to their original mass so the
/// ablation perturbs *which* pages look hot, not how much attention mass
/// exists. F32 is the bit-exact baseline row.
pub fn dtype_ablation(seeds: u64) -> Table {
    use crate::kvcache::quant::KvDtype;
    let mut t = Table::new(
        "Dtype ablation — FreeKV under quantized KV pages (x100)",
        &["kv dtype", "longinput", "longgen", "reasoning", "mass-recall"],
    );
    for dtype in KvDtype::all() {
        let mut row = vec![dtype.as_str().to_string()];
        let mut mass = 0.0;
        for kind in [TaskKind::Summarization, TaskKind::LongGen, TaskKind::Reasoning] {
            let traces: Vec<Trace> = traces_for(kind, 28, 4, seeds)
                .into_iter()
                .map(|tr| quantize_trace(tr, dtype))
                .collect();
            let knobs = knobs_for(Method::FreeKv, kind);
            let r = mean_ep(Method::FreeKv, SelectVariant::MeanS, &traces, &knobs);
            row.push(fnum(r.task_score * 100.0));
            mass += r.mass_recall / 3.0;
        }
        row.push(fnum(mass * 100.0));
        t.row(row);
    }
    t
}

/// Pass every score surface a retrieval policy reads through the page
/// codec's quantize/dequantize roundtrip (one scale per row).
fn quantize_trace(tr: Trace, dtype: crate::kvcache::quant::KvDtype) -> Trace {
    use crate::kvcache::quant::{roundtrip_f32s, KvDtype};
    if dtype == KvDtype::F32 {
        return tr;
    }
    let Trace { spec, n_qo, n_kv, steps } = tr;
    let steps = steps
        .into_iter()
        .map(|mut st| {
            for rows in
                [&mut st.summary_scores, &mut st.scores_meanq, &mut st.scores_maxq]
            {
                for row in rows.iter_mut() {
                    *row = roundtrip_f32s(dtype, row);
                }
            }
            for row in st.weights.iter_mut() {
                let total: f32 = row.iter().sum();
                *row = roundtrip_f32s(dtype, row);
                let qt: f32 = row.iter().sum();
                if qt > 0.0 {
                    let k = total / qt;
                    row.iter_mut().for_each(|x| *x *= k);
                }
            }
            st
        })
        .collect();
    Trace { spec, n_qo, n_kv, steps }
}

/// Fig. 2b: accuracy-efficiency Pareto points (accuracy from the oracle,
/// latency from the simulator).
pub fn fig2_pareto(seeds: u64) -> Table {
    use crate::config::ModelConfig;
    use crate::policies::latency::{simulate_request, SimKnobs};
    use crate::sim::{CostModel, DeviceProfile};
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
    let mut t = Table::new(
        "Fig. 2b analog — accuracy vs per-token latency",
        &["method", "accuracy (x100)", "per-token latency (ms)"],
    );
    for m in [
        Method::Full,
        Method::Razor,
        Method::RaaS,
        Method::Quest,
        Method::ArkVale,
        Method::ShadowKv,
        Method::InfiniGen,
        Method::FreeKv,
    ] {
        let mut acc = 0.0;
        for kind in [TaskKind::Niah, TaskKind::Summarization, TaskKind::Reasoning] {
            let traces = traces_for(kind, 32, 8, seeds);
            acc += mean_ep(m, SelectVariant::MeanS, &traces, &knobs_for(m, kind)).task_score / 3.0;
        }
        let lat = simulate_request(m, &cm, 1, 8192, 64, &SimKnobs::default()).per_token();
        t.row(vec![m.name().into(), fnum(acc * 100.0), fnum(lat * 1e3)]);
    }
    t
}
