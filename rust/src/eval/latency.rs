//! Latency eval drivers: Table 1, Fig. 1 (right), Fig. 7, Fig. 8,
//! Fig. 9, Fig. 10 — built on the discrete-event simulator with the
//! paper's model geometries and device profiles.

use crate::config::ModelConfig;
use crate::policies::latency::{
    gpu_kv_bytes, shared_prefix_pool_pages, simulate_request, weight_bytes, Method, SimKnobs,
};
use crate::sim::{CostModel, DeviceProfile};
use crate::util::table::{fnum, ftime, Table};

fn paper_models() -> Vec<ModelConfig> {
    vec![ModelConfig::qwen25_7b(), ModelConfig::llama31_8b()]
}

fn retrieval_methods() -> Vec<Method> {
    vec![
        Method::Razor,
        Method::RaaS,
        Method::ArkVale,
        Method::ShadowKv,
        Method::InfiniGen,
        Method::FreeKv,
    ]
}

/// Table 1 analog: measured complexity/feature comparison.
pub fn table1() -> Table {
    let m = ModelConfig::llama31_8b();
    let knobs = SimKnobs::default();
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), m.clone());
    let mut t = Table::new(
        "Table 1 analog — per-method properties (Llama-3.1-8B, 32K ctx, b=1)",
        &["method", "category", "gpu KV mem", "recall/step", "recall exposed", "group-consistent"],
    );
    for method in Method::all() {
        let rec = simulate_request(method, &cm, 1, 32768, 32, &knobs);
        let cat = match method {
            Method::Full => "full cache",
            Method::Razor | Method::Streaming => "static drop",
            Method::RaaS => "dynamic drop",
            _ => "retrieval",
        };
        let gc = match method {
            Method::Quest | Method::InfiniGen => "adapted",
            Method::Full | Method::Streaming => "n/a",
            _ => "yes",
        };
        t.row(vec![
            method.name().into(),
            cat.into(),
            format!("{:.2} GB", gpu_kv_bytes(method, &m, 1, 32768, &knobs) / 1e9),
            ftime(rec.recall_busy / rec.steps.max(1) as f64),
            ftime(rec.recall_exposed / rec.steps.max(1) as f64),
            gc.into(),
        ]);
    }
    t
}

/// Fig. 1 (right): latency breakdown of offloading retrieval methods
/// (Llama-3.1-8B, batch 1, 32K context).
pub fn fig1_breakdown() -> Table {
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
    let knobs = SimKnobs::default();
    let mut t = Table::new(
        "Fig. 1 (right) analog — per-token latency breakdown (ms)",
        &["method", "compute", "selection", "recall (exposed)", "total", "recall+sel %"],
    );
    for m in [Method::ArkVale, Method::ShadowKv, Method::InfiniGen, Method::FreeKv, Method::Full] {
        let r = simulate_request(m, &cm, 1, 32768, 64, &knobs);
        let per = r.steps.max(1) as f64;
        let comp = (r.compute_busy - r.selection_busy) / per * 1e3;
        let sel = r.selection_busy / per * 1e3;
        let rec = r.recall_exposed / per * 1e3;
        let tot = r.per_token() * 1e3;
        t.row(vec![
            m.name().into(),
            fnum(comp),
            fnum(sel),
            fnum(rec),
            fnum(tot),
            fnum((sel + rec) / tot * 100.0),
        ]);
    }
    t
}

/// Fig. 7: end-to-end latency, 2 models x 2 scenarios x batch sizes.
pub fn fig7() -> Vec<Table> {
    let mut out = Vec::new();
    for model in paper_models() {
        for (scenario, input, output, knobs) in [
            ("long-input 32K->512", 32768usize, 512usize, SimKnobs::default()),
            ("long-gen 600->16K", 600, 16384, SimKnobs::long_generation()),
        ] {
            let cm = CostModel::new(DeviceProfile::a100_pcie4(), model.clone());
            let mut t = Table::new(
                &format!("Fig. 7 analog — {} {}", model.name, scenario),
                &["method", "b=1 (s)", "b=2 (s)", "b=4 (s)", "b=8 (s)", "speedup vs freekv (b=4)"],
            );
            let mut fk_b4 = 1.0;
            let mut rows: Vec<(Method, Vec<f64>)> = Vec::new();
            for method in retrieval_methods() {
                let mut totals = Vec::new();
                for b in [1usize, 2, 4, 8] {
                    // scale decode steps down for sim speed; report scaled total
                    let steps = output.min(2048);
                    let r = simulate_request(method, &cm, b, input, steps, &knobs);
                    let total = r.prefill_secs + r.per_token() * output as f64;
                    totals.push(total);
                }
                if method == Method::FreeKv {
                    fk_b4 = totals[2];
                }
                rows.push((method, totals));
            }
            for (method, totals) in rows {
                t.row(vec![
                    method.name().into(),
                    fnum(totals[0]),
                    fnum(totals[1]),
                    fnum(totals[2]),
                    fnum(totals[3]),
                    format!("{:.1}x", totals[2] / fk_b4),
                ]);
            }
            out.push(t);
        }
    }
    out
}

/// Fig. 8: FreeKV vs ArkVale across input and output lengths.
pub fn fig8() -> Vec<Table> {
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
    let mut out = Vec::new();

    let mut t = Table::new(
        "Fig. 8a analog — long-input: latency vs input length (512 out, s)",
        &["input", "arkvale", "freekv", "speedup"],
    );
    for input in [8192usize, 16384, 32768, 65536] {
        let k = SimKnobs::default();
        let a = simulate_request(Method::ArkVale, &cm, 1, input, 512, &k);
        let f = simulate_request(Method::FreeKv, &cm, 1, input, 512, &k);
        t.row(vec![
            format!("{}K", input / 1024),
            fnum(a.total()),
            fnum(f.total()),
            format!("{:.1}x", a.total() / f.total()),
        ]);
    }
    out.push(t);

    let mut t = Table::new(
        "Fig. 8b analog — long-gen: latency vs output length (600 in, s)",
        &["output", "arkvale", "freekv", "speedup"],
    );
    for output in [2048usize, 4096, 8192, 16384] {
        let k = SimKnobs::long_generation();
        let steps = output.min(2048);
        let a = simulate_request(Method::ArkVale, &cm, 1, 600, steps, &k);
        let f = simulate_request(Method::FreeKv, &cm, 1, 600, steps, &k);
        let at = a.prefill_secs + a.per_token() * output as f64;
        let ft = f.prefill_secs + f.per_token() * output as f64;
        t.row(vec![
            format!("{}K", output / 1024),
            fnum(at),
            fnum(ft),
            format!("{:.1}x", at / ft),
        ]);
    }
    out.push(t);
    out
}

/// Fig. 9: ablation of HL / DB / SR (Llama-3.1-8B).
pub fn fig9() -> Vec<Table> {
    let cm = CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b());
    let mut out = Vec::new();
    for (scenario, input, output, base) in [
        ("long-input 32K->512", 32768usize, 512usize, SimKnobs::default()),
        ("long-gen 600->16K", 600, 2048, SimKnobs::long_generation()),
    ] {
        for b in [1usize, 4] {
            let mut t = Table::new(
                &format!("Fig. 9 analog — {} (b={})", scenario, b),
                &["config", "per-token (ms)", "speedup vs none"],
            );
            let configs: [(&str, bool, bool, bool); 4] = [
                ("none (blocking, NHD)", false, false, false),
                ("+HL", true, false, false),
                ("+HL+DB", true, true, false),
                ("+HL+DB+SR (FreeKV)", true, true, true),
            ];
            let mut none = 0.0;
            for (label, hl, db, sr) in configs {
                let knobs = SimKnobs {
                    hybrid_layout: hl,
                    double_buffer: db,
                    speculative: sr,
                    ..base.clone()
                };
                let r = simulate_request(Method::FreeKv, &cm, b, input, output, &knobs);
                let pt = r.per_token() * 1e3;
                if !hl {
                    none = pt;
                }
                t.row(vec![label.into(), fnum(pt), format!("{:.1}x", none / pt)]);
            }
            out.push(t);
        }
    }
    out
}

/// Fig. 10: Ascend-910B profile, FreeKV vs ArkVale, 32K long-input.
pub fn fig10() -> Table {
    let mut t = Table::new(
        "Fig. 10 analog — Ascend 910B vs A100 (32K long-input, b=1)",
        &["device", "arkvale (s)", "freekv (s)", "speedup"],
    );
    for dev in [DeviceProfile::a100_pcie4(), DeviceProfile::ascend_910b()] {
        let cm = CostModel::new(dev.clone(), ModelConfig::llama31_8b());
        let k = SimKnobs::default();
        let a = simulate_request(Method::ArkVale, &cm, 1, 32768, 512, &k);
        let f = simulate_request(Method::FreeKv, &cm, 1, 32768, 512, &k);
        t.row(vec![
            dev.name.clone(),
            fnum(a.total()),
            fnum(f.total()),
            format!("{:.1}x", a.total() / f.total()),
        ]);
    }
    t
}

/// Shared-prefix pool memory: modeled CPU pages (and GB) for N
/// requests with a common prompt prefix, with and without the
/// copy-on-write prefix cache — the modeled twin of the rust engine's
/// `--prefix-cache` page sharing.
pub fn prefix_mem_table() -> Table {
    let m = ModelConfig::llama31_8b();
    let (prefix, unique) = (32768usize, 512usize);
    // page counts are aggregated across layers, so GB = pages x one
    // page's bytes (all kv heads, K+V)
    let page_gb = m.page_bytes() as f64 / 1e9;
    let mut t = Table::new(
        "Shared-prefix CPU pool memory (Llama-3.1-8B, 32K shared prompt + 512 unique)",
        &["requests", "private pages", "shared pages", "private GB", "shared GB", "savings"],
    );
    for n in [1usize, 4, 8, 16] {
        let private = shared_prefix_pool_pages(&m, n, prefix, unique, false);
        let shared = shared_prefix_pool_pages(&m, n, prefix, unique, true);
        t.row(vec![
            n.to_string(),
            private.to_string(),
            shared.to_string(),
            fnum(private as f64 * page_gb),
            fnum(shared as f64 * page_gb),
            format!("{:.2}x", private as f64 / shared as f64),
        ]);
    }
    t
}

/// Memory safety check backing the Fig. 7 Quest exclusion.
pub fn oom_table() -> Table {
    let m = ModelConfig::llama31_8b();
    let knobs = SimKnobs::default();
    let mut t = Table::new(
        "Quest OOM check (A100-40G, Llama-3.1-8B, 32K ctx)",
        &["method", "batch", "kv+weights+reserve (GB)", "fits 40GB"],
    );
    for method in [Method::Quest, Method::FreeKv] {
        for b in [1usize, 4] {
            let total = gpu_kv_bytes(method, &m, b, 32768, &knobs)
                + weight_bytes(&m, 2)
                + knobs.runtime_reserve;
            t.row(vec![
                method.name().into(),
                b.to_string(),
                fnum(total / 1e9),
                (total <= knobs.gpu_mem_bytes).to_string(),
            ]);
        }
    }
    t
}
