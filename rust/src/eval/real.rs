//! Real-pipeline evals on the tiny model: query-similarity measurement
//! (Fig. 3 on real artifacts rather than the oracle), the wall-clock
//! phase breakdown of the rust engine, and modeled-vs-real cross-checks.

use anyhow::Result;

use crate::config::FreeKvParams;
use crate::coordinator::engine::{Engine, SampleParams};
use crate::runtime::Runtime;
use crate::util::stats::Summary;
use crate::util::table::{fnum, ftime, Table};

/// Load the manifest under `artifacts` and build a single-stream engine
/// for `model` (pool dispatch disabled so phase timings are full costs).
pub fn load_engine(artifacts: &str, model: &str, params: FreeKvParams) -> Result<Engine> {
    let rt = Runtime::load(artifacts)?;
    // Exhibits reproduce the paper's single-stream engine: artifact
    // dispatch stays on this thread so the phase breakdown reports full
    // selection execution time, not the post-pool exposed remainder
    // (mirrors `SimKnobs::pooled_selection` defaulting to false).
    Engine::new(rt, model, FreeKvParams { exec_workers: 0, ..params })
}

/// Fig. 3 analog on the real model: per-layer mean adjacent-step query
/// cosine similarity during generation.
pub fn fig3_similarity(artifacts: &str, model: &str, steps: usize) -> Result<Table> {
    let mut eng = load_engine(artifacts, model, FreeKvParams::default())?;
    eng.record_sims = true;
    let prompt: Vec<i32> = (0..256).map(|i| (i * 11 % 250) as i32).collect();
    let mut seq = eng.new_sequence(
        1,
        prompt,
        steps,
        SampleParams { temperature: 0.9, top_p: 0.95, seed: 11 },
    );
    eng.generate(&mut seq)?;

    let n_layers = eng.cfg.n_layers;
    let n_qo = eng.cfg.n_qo;
    let mut t = Table::new(
        &format!("Fig. 3 analog — real {} model query similarity", model),
        &["layer", "mean", "min", "p10", "per-head means"],
    );
    for l in 0..n_layers {
        let mut per_head: Vec<Vec<f64>> = vec![Vec::new(); n_qo];
        let mut all = Vec::new();
        for (layer, sims) in &eng.sim_trace {
            if *layer == l {
                for (h, &s) in sims.iter().enumerate() {
                    per_head[h].push(s as f64);
                    all.push(s as f64);
                }
            }
        }
        let s = Summary::of(&all);
        let heads: Vec<String> = per_head
            .iter()
            .map(|xs| format!("{:.2}", xs.iter().sum::<f64>() / xs.len().max(1) as f64))
            .collect();
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p10 = crate::util::stats::percentile_sorted(&sorted, 10.0);
        t.row(vec![l.to_string(), fnum(s.mean), fnum(s.min), fnum(p10), heads.join(" ")]);
    }
    Ok(t)
}

/// Real-engine phase breakdown + counters for a long generation.
pub fn real_breakdown(artifacts: &str, model: &str, prompt_len: usize, steps: usize, tau: f32) -> Result<(Table, Table)> {
    let mut eng = load_engine(artifacts, model, FreeKvParams { tau, ..Default::default() })?;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| (i * 13 % 250) as i32).collect();
    let mut seq = eng.new_sequence(
        2,
        prompt,
        steps,
        SampleParams { temperature: 0.8, top_p: 0.95, seed: 5 },
    );
    eng.generate(&mut seq)?;

    let st = &eng.stats;
    let per = st.steps.max(1) as f64;
    let mut t = Table::new(
        &format!("Real pipeline breakdown — {} ({} prompt, {} steps)", model, prompt_len, steps),
        &["phase", "total", "per step"],
    );
    for (name, secs) in [
        ("prefill", st.prefill_secs),
        ("decode total", st.decode_secs),
        ("  qkv exec", st.qkv_secs),
        ("  attention exec", st.attn_secs),
        ("  selection exec", st.select_secs),
        ("  gather (host)", st.gather_secs),
        ("  recall transfers", st.recall_secs),
        ("    hidden (worker)", st.recall_hidden_secs),
        ("    exposed (blocking)", st.recall_exposed_secs),
        ("  logits exec", st.logits_secs),
    ] {
        t.row(vec![name.into(), ftime(secs), ftime(secs / per)]);
    }

    let c = &seq.xfer.counters;
    let mut t2 = Table::new(
        "Engine counters",
        &["counter", "value"],
    );
    for (k, v) in [
        ("decode steps", st.steps as f64),
        ("corrections", st.corrections as f64),
        ("correction checks", st.correction_checks as f64),
        ("correction rate", st.correction_rate()),
        ("speculative hits", st.speculative_hits as f64),
        ("recalled pages", st.recalled_pages as f64),
        ("recall jobs (worker)", st.recall_jobs as f64),
        ("max queue depth", st.max_queue_depth as f64),
        ("recall hidden fraction", st.recall_hidden_fraction()),
        ("offloaded pages", c.offloaded_pages as f64),
        ("h2d chunks", c.h2d_chunks as f64),
        ("h2d bytes", c.h2d_bytes as f64),
        ("tokens/s (real decode)", per / st.decode_secs.max(1e-9)),
    ] {
        t2.row(vec![k.into(), fnum(v)]);
    }
    Ok((t, t2))
}

/// Per-layer correction-rate distribution on the real model — the analog
/// of the paper's per-layer histograms (Figs. 16-20).
pub fn per_layer_corrections(artifacts: &str, model: &str, steps: usize, tau: f32) -> Result<Table> {
    let mut eng = load_engine(artifacts, model, FreeKvParams { tau, ..Default::default() })?;
    eng.record_sims = true;
    let prompt: Vec<i32> = (0..600).map(|i| (i * 19 % 250) as i32).collect();
    let mut seq = eng.new_sequence(
        5,
        prompt,
        steps,
        SampleParams { temperature: 0.85, top_p: 0.95, seed: 23 },
    );
    eng.generate(&mut seq)?;
    let g = eng.cfg.group_size();
    let n_kv = eng.cfg.n_kv;
    let mut t = Table::new(
        &format!("Per-layer correction rates — {} model, tau={} (Figs. 16-20 analog)", model, tau),
        &["layer", "corr. rate", "mean sim", "min pooled sim"],
    );
    for l in 1..eng.cfg.n_layers {
        let mut checks = 0usize;
        let mut corr = 0usize;
        let mut sims = Vec::new();
        let mut min_pooled = f64::MAX;
        for (layer, hs) in &eng.sim_trace {
            if *layer != l {
                continue;
            }
            for m in 0..n_kv {
                let pooled: f32 = hs[m * g..(m + 1) * g].iter().sum::<f32>() / g as f32;
                checks += 1;
                if pooled < tau {
                    corr += 1;
                }
                min_pooled = min_pooled.min(pooled as f64);
            }
            sims.extend(hs.iter().map(|&x| x as f64));
        }
        let mean = sims.iter().sum::<f64>() / sims.len().max(1) as f64;
        t.row(vec![
            l.to_string(),
            fnum(corr as f64 / checks.max(1) as f64),
            fnum(mean),
            fnum(min_pooled),
        ]);
    }
    Ok(t)
}

/// Table 9 analog measured on the *real* model: correction rate vs tau.
pub fn real_correction_rates(artifacts: &str, model: &str, steps: usize) -> Result<Table> {
    let mut t = Table::new(
        &format!("Real correction rates — {} model", model),
        &["tau", "correction rate", "spec hit rate", "recalled pages/step"],
    );
    for tau in [0.7f32, 0.8, 0.9, 0.95] {
        let mut eng = load_engine(artifacts, model, FreeKvParams { tau, ..Default::default() })?;
        let prompt: Vec<i32> = (0..600).map(|i| (i * 13 % 250) as i32).collect();
        let mut seq = eng.new_sequence(
            3,
            prompt,
            steps,
            SampleParams { temperature: 0.8, top_p: 0.95, seed: 7 },
        );
        eng.generate(&mut seq)?;
        let st = &eng.stats;
        let checks = st.correction_checks.max(1) as f64;
        t.row(vec![
            format!("{}", tau),
            fnum(st.corrections as f64 / checks),
            fnum(st.speculative_hits as f64 / checks),
            fnum(st.recalled_pages as f64 / st.steps.max(1) as f64),
        ]);
    }
    Ok(t)
}
