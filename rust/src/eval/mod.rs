//! Eval harness: one driver per paper table/figure (see DESIGN.md's
//! experiment index). Each driver prints the paper-shaped rows and saves
//! CSV to `results/`.

pub mod accuracy;
pub mod latency;
pub mod real;
