//! `freekv` CLI: serve, generate, eval (paper exhibits), info.
//!
//! Examples:
//!   freekv generate --prompt "The paper shows" --max-tokens 32
//!   freekv serve --addr 127.0.0.1:8080
//!   freekv eval fig7
//!   freekv eval all --seeds 4
//!   freekv info

use anyhow::{anyhow, Result};

use freekv::config::FreeKvParams;
use freekv::coordinator::engine::{Backend, Engine, SampleParams};
use freekv::coordinator::engine_loop::LoopConfig;
use freekv::coordinator::router::{DispatchPolicy, ReplicaSet, RouterKind};
use freekv::coordinator::scheduler::{Scheduler, SchedulerConfig};
use freekv::coordinator::sim_backend::SimBackend;
use freekv::coordinator::tokenizer;
use freekv::eval::{accuracy, latency, real};
use freekv::kvcache::quant::KvDtype;
use freekv::kvcache::{KvLockMode, PrefixCacheMode};
use freekv::runtime::Runtime;
use freekv::server::ServeOptions;
use freekv::util::cli::Args;
use freekv::util::table::Table;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn emit(t: Table, name: &str) {
    t.emit(Some("results"), name);
    println!();
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "tiny");
    let tau = args.f64_or("tau", 0.8) as f32;
    // --serial-recall keeps speculative recall on the decode thread (the
    // overlap ablation baseline); default dispatches it to the worker.
    // --exec-workers N sizes the PJRT executor pool (0 = serial
    // in-thread artifact dispatch, the ablation baseline).
    // --max-lanes caps concurrent decode microbatch lanes;
    // --weight-workers bounds how many pool workers hold weight copies.
    // --kv-pool-pages caps the shared CPU KV page pool (0 = unbounded);
    // admission queues requests the pool cannot cover.
    // --prefix-cache[=resident|retained|off] enables copy-on-write
    // prefix sharing of pool pages; `retained` also keeps committed
    // prefix pages cached after their last request retires (bare
    // --prefix-cache means `resident`). --kv-retain-pages N caps the
    // retained tier (0 = bounded only by pool pressure).
    // --chaos-seed N seeds deterministic fault injection (worker deaths,
    // engine panics, slow transfers) to exercise the degradation ladder.
    // --kv-dtype f32|int8|int4 selects the CPU pool page codec
    // (quantize-on-offload, dequantize-on-gather; sink/window stay f32).
    // --kv-lock global|sharded selects the allocator lock layout
    // (sharded per-layer slab locks by default; global is the
    // contention-ablation baseline, bit-identical output).
    let defaults = FreeKvParams::default();
    let kv_dtype = match args.get("kv-dtype") {
        Some(s) => KvDtype::parse(&s)
            .ok_or_else(|| anyhow!("unknown --kv-dtype {s:?} (expected f32|int8|int4)"))?,
        None => defaults.kv_dtype,
    };
    let kv_lock = match args.get("kv-lock") {
        Some(s) => KvLockMode::parse(&s)
            .ok_or_else(|| anyhow!("unknown --kv-lock {s:?} (expected global|sharded)"))?,
        None => defaults.kv_lock,
    };
    let prefix_cache = match args.get("prefix-cache") {
        Some(s) => PrefixCacheMode::parse(&s).ok_or_else(|| {
            anyhow!("unknown --prefix-cache {s:?} (expected off|resident|retained)")
        })?,
        // bare `--prefix-cache` keeps its historical meaning: resident
        // CoW sharing without the persistent tier.
        None if args.flag("prefix-cache") => PrefixCacheMode::Resident,
        None => defaults.prefix_cache,
    };
    let params = FreeKvParams {
        tau,
        overlap: !args.flag("serial-recall"),
        exec_workers: args.usize_or("exec-workers", defaults.exec_workers),
        max_lanes: args.usize_or("max-lanes", defaults.max_lanes),
        weight_workers: args.usize_or("weight-workers", defaults.weight_workers),
        kv_pool_pages: args.usize_or("kv-pool-pages", defaults.kv_pool_pages),
        prefix_cache,
        kv_retain_pages: args.usize_or("kv-retain-pages", defaults.kv_retain_pages),
        chaos_seed: args.get("chaos-seed").and_then(|v| v.parse().ok()),
        kv_dtype,
        kv_lock,
        ..Default::default()
    };

    match args.command() {
        Some("info") => {
            let rt = Runtime::load(&artifacts)?;
            println!("configs: {:?}", rt.manifest.configs.keys().collect::<Vec<_>>());
            println!("artifacts: {}", rt.manifest.artifacts.len());
            for (name, cfg) in &rt.manifest.configs {
                println!(
                    "  {}: {}L d{} q{} kv{} page{} budget {} slots",
                    name, cfg.n_layers, cfg.d_model, cfg.n_qo, cfg.n_kv, cfg.page_size,
                    cfg.budget_slots()
                );
            }
            Ok(())
        }
        Some("generate") => {
            let prompt = args.str_or("prompt", "FreeKV boosts KV cache retrieval. ");
            let max_tokens = args.usize_or("max-tokens", 32);
            let temp = args.f64_or("temperature", 0.0) as f32;
            let rt = Runtime::load(&artifacts)?;
            let mut eng = freekv::coordinator::engine::Engine::new(rt, &model, params)?;
            let mut seq = eng.new_sequence(
                1,
                tokenizer::encode(&prompt),
                max_tokens,
                SampleParams { temperature: temp, top_p: 0.95, seed: args.u64_or("seed", 0) },
            );
            seq.eos = Some(tokenizer::EOS);
            eng.generate(&mut seq)?;
            println!("prompt: {prompt}");
            println!("output: {}", tokenizer::decode(seq.generated()));
            println!(
                "[{} steps, {:.1} tok/s, corrections {} ({:.1}%), recalled {} pages]",
                eng.stats.steps,
                eng.stats.steps as f64 / eng.stats.decode_secs.max(1e-9),
                eng.stats.corrections,
                eng.stats.correction_rate() * 100.0,
                eng.stats.recalled_pages,
            );
            Ok(())
        }
        Some("serve") => {
            let addr = args.str_or("addr", "127.0.0.1:8080");
            // Block SIGINT/SIGTERM before any thread spawns so the
            // watcher thread below is their only consumer: Ctrl-C then
            // triggers the graceful-drain path instead of killing
            // in-flight sessions.
            #[cfg(unix)]
            let signals_blocked = freekv::util::signal::block_shutdown_signals();
            let scfg = SchedulerConfig {
                max_batch: args.usize_or("max-batch", 4),
                admit_below: args.usize_or("admit-below", 4),
                // split decode into pipelined microbatch lanes once this
                // many sequences are running (0 = never split)
                microbatch_min: args.usize_or("microbatch-min", 0),
                max_lanes: params.max_lanes,
                ..Default::default()
            };
            let loop_cfg =
                LoopConfig { queue_cap: args.usize_or("queue-cap", 64), ..Default::default() };
            let warm = args.flag("warmup");
            // --replicas N runs N independent engine loops behind one
            // router; --router picks the dispatch policy (kv-aware
            // pressure + prefix affinity, or the round-robin ablation).
            // N=1 is a bit-identical passthrough to the single loop.
            let replicas = args.usize_or("replicas", 1).max(1);
            let router_kind = RouterKind::parse(&args.str_or("router", "kv"))
                .ok_or_else(|| anyhow!("unknown --router (expected kv|round-robin)"))?;
            // Each replica's engine is constructed on its own loop
            // thread (the PJRT client is !Send); --sim swaps in the
            // artifact-free backend. Per-replica schedulers, backends,
            // and KV allocators are fully independent.
            let set = if args.flag("sim") {
                let (pool_pages, prefix) = (params.kv_pool_pages as u64, params.prefix_cache);
                let retain = params.kv_retain_pages as u64;
                let dtype = params.kv_dtype;
                let lock = params.kv_lock;
                // One fault plan per replica: a supervised engine
                // restart keeps advancing the same schedule instead of
                // replaying it from call index 0, and replicas fault
                // independently (seed offset by replica index).
                let chaos_seed = params.chaos_seed;
                ReplicaSet::spawn(replicas, loop_cfg, move |i| {
                    let plan = chaos_seed.map(|s| {
                        std::sync::Arc::new(freekv::util::fault::FaultPlan::chaos(s + i as u64))
                    });
                    let scfg = scfg.clone();
                    move || {
                        let mut b = SimBackend::tiny_with_pool_opts(
                            pool_pages, prefix, retain, dtype, lock,
                        );
                        if let Some(p) = &plan {
                            b.set_faults(p.clone());
                        }
                        Ok(Scheduler::new(b, scfg.clone()))
                    }
                })?
            } else {
                ReplicaSet::spawn(replicas, loop_cfg, move |_i| {
                    let artifacts = artifacts.clone();
                    let model = model.clone();
                    let params = params.clone();
                    let scfg = scfg.clone();
                    move || {
                        let rt = Runtime::load(&artifacts)?;
                        let eng = Engine::new(rt, &model, params.clone())?;
                        if warm {
                            // warms the engine runtime and every pool worker
                            let n = eng.warmup()?;
                            println!("[freekv] warmed {} artifacts", n);
                        }
                        Ok(Scheduler::new(eng, scfg.clone()))
                    }
                })?
            };
            let router = set.build_router(router_kind)?;
            let max_requests = args.get("max-requests").and_then(|v| v.parse().ok());
            // --drain-secs: on shutdown (Ctrl-C / SIGTERM included), let
            // running sessions finish for this long before cancelling
            // them (0 = cancel immediately). Default 5s so a signal
            // drains gracefully out of the box.
            let drain = std::time::Duration::from_secs_f64(args.f64_or("drain-secs", 5.0).max(0.0));
            // Bind here so the signal watcher can wake a blocked accept
            // by poking the listener address.
            let listener = std::net::TcpListener::bind(&addr)?;
            let local = listener.local_addr()?;
            let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
            #[cfg(unix)]
            if signals_blocked {
                let flag = stop.clone();
                // handle dropped: the watcher lives for the process
                let _ = freekv::util::signal::watch_shutdown(flag, move || {
                    let _ = std::net::TcpStream::connect(local);
                });
            }
            let opts = ServeOptions {
                max_requests,
                // 0 derives the connection-thread cap from the queue cap
                max_connections: args.usize_or("max-conns", 0),
                drain,
                shutdown: Some(stop.clone()),
                ..Default::default()
            };
            let result = freekv::server::serve_listener(listener, router, opts);
            // Set-wide teardown: the graceful path fans one shared
            // drain deadline out to every replica before joining them.
            if drain.is_zero() {
                set.shutdown();
            } else {
                set.shutdown_graceful(drain);
            }
            result
        }
        Some("loadtest") => {
            let scfg = SchedulerConfig {
                max_batch: args.usize_or("max-batch", 4),
                admit_below: args.usize_or("admit-below", 4),
                microbatch_min: args.usize_or("microbatch-min", 0),
                max_lanes: params.max_lanes,
                ..Default::default()
            };
            // --replicas N replays the workload across N independent
            // schedulers through the same dispatch policy the serving
            // tier runs (--router kv|round-robin); N=1 keeps the
            // original single-scheduler replay bit-identical.
            let replicas = args.usize_or("replicas", 1).max(1);
            if args.flag("sim") {
                let make = |i: usize| {
                    let mut backend = SimBackend::tiny_with_pool_opts(
                        params.kv_pool_pages as u64,
                        params.prefix_cache,
                        params.kv_retain_pages as u64,
                        params.kv_dtype,
                        params.kv_lock,
                    );
                    // per-replica fault schedules, offset by index
                    if let Some(seed) = params.chaos_seed {
                        backend.set_faults(std::sync::Arc::new(
                            freekv::util::fault::FaultPlan::chaos(seed + i as u64),
                        ));
                    }
                    Scheduler::new(backend, scfg.clone())
                };
                if replicas == 1 {
                    loadtest(make(0), &args)
                } else {
                    router_loadtest((0..replicas).map(make).collect(), &args)
                }
            } else if replicas == 1 {
                let rt = Runtime::load(&artifacts)?;
                let eng = Engine::new(rt, &model, params)?;
                loadtest(Scheduler::new(eng, scfg), &args)
            } else {
                // N engines on this one thread (Runtime is !Send): fine
                // for a replay, which ticks them in lockstep anyway.
                let mut scheds = Vec::with_capacity(replicas);
                for _ in 0..replicas {
                    let rt = Runtime::load(&artifacts)?;
                    let eng = Engine::new(rt, &model, params.clone())?;
                    scheds.push(Scheduler::new(eng, scfg.clone()));
                }
                router_loadtest(scheds, &args)
            }
        }
        Some("eval") => {
            let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let seeds = args.u64_or("seeds", 3);
            eval(what, seeds, &artifacts, &model)
        }
        _ => Err(anyhow!(
            "usage: freekv <info|generate|serve|loadtest|eval> [--model tiny] [--artifacts dir] \
             [--serial-recall] [--exec-workers 2] [--max-lanes 2] [--weight-workers 1] \
             [--kv-pool-pages 0] [--kv-dtype f32|int8|int4] [--kv-lock global|sharded] \
             [--prefix-cache[=off|resident|retained]] [--kv-retain-pages 0] [--sim] \
             [--chaos-seed N] \
             [--queue-cap 64] [--max-batch 4] [--admit-below 4] [--microbatch-min 0] \
             [--max-conns 0] [--drain-secs 5] [--replicas 1] [--router kv|round-robin]\n\
             eval exhibits: fig1-accuracy fig1-breakdown fig2-pareto fig3-similarity table1 \
             table2 table3 table4 table5 table6 table7 table8 table9 fig7 fig8 fig9 fig10 \
             dtype oom prefix-mem real-breakdown real-correction fig16-20 all"
        )),
    }
}

fn workload_spec(args: &Args) -> Result<freekv::workload::WorkloadSpec> {
    Ok(freekv::workload::WorkloadSpec {
        scenario: freekv::workload::Scenario::parse(&args.str_or("scenario", "mixed"))
            .ok_or_else(|| anyhow!("unknown scenario"))?,
        rate: args.f64_or("rate", 4.0),
        n_requests: args.usize_or("requests", 16),
        max_prompt: args.usize_or("max-prompt", 1000),
        max_output: args.usize_or("max-output", 48),
        seed: args.u64_or("seed", 0xF00D),
    })
}

fn loadtest<B: Backend>(mut sched: Scheduler<B>, args: &Args) -> Result<()> {
    let spec = workload_spec(args)?;
    let workload = freekv::workload::generate(&spec);
    let report =
        freekv::workload::run_loadtest(&mut sched, workload, args.f64_or("ticks-per-sec", 8.0))?;
    println!("{}", sched.metrics.report());
    println!(
        "loadtest: {} completed ({} failed, {} engine faults) in {:.2}s over {} ticks, \
         max inflight {}, {} tokens out",
        report.completed,
        report.failed,
        report.tick_faults,
        report.wall_secs,
        report.ticks,
        report.max_inflight,
        report.tokens_out
    );
    if report.tick_faults > 0 {
        println!(
            "loadtest: degraded run — {} tick(s) hit an injected or real engine fault; \
             every request still reached a terminal outcome",
            report.tick_faults
        );
    }
    Ok(())
}

/// Multi-replica replay: the same workload through [`DispatchPolicy`]
/// over N schedulers, with per-replica and routing breakdowns printed.
fn router_loadtest<B: Backend>(mut scheds: Vec<Scheduler<B>>, args: &Args) -> Result<()> {
    let spec = workload_spec(args)?;
    let page_size = scheds[0].engine.model().page_size;
    let mut policy = DispatchPolicy::parse(&args.str_or("router", "kv"), page_size)
        .ok_or_else(|| anyhow!("unknown --router (expected kv|round-robin)"))?;
    let tps = args.f64_or("ticks-per-sec", 8.0);
    let workload = freekv::workload::generate(&spec);
    let report = freekv::workload::run_router_loadtest(&mut scheds, &mut policy, workload, tps)?;
    println!(
        "loadtest: router={} replicas={} — {} completed ({} failed, {} engine faults) \
         in {:.2}s over {} ticks, max inflight {}, {} tokens out",
        policy.name(),
        scheds.len(),
        report.completed,
        report.failed,
        report.tick_faults,
        report.wall_secs,
        report.ticks,
        report.max_inflight,
        report.tokens_out
    );
    let c = report.counters;
    println!(
        "router: modeled {:.1} tok/s, ttft p95 {:.3}s, retained hits {} \
         (concentration {:.2}), prefill tokens saved {}, \
         affinity hits/misses/reroutes/evictions {}/{}/{}/{}",
        report.modeled_throughput(tps),
        report.ttft_p95_secs,
        report.retained_hits(),
        report.retained_hit_concentration(),
        report.prefill_tokens_saved(),
        c.affinity_hits,
        c.affinity_misses,
        c.affinity_reroutes,
        c.affinity_evictions
    );
    for (i, p) in report.per_replica.iter().enumerate() {
        println!(
            "replica{}: completed={} failed={} tokens_out={} retained_hits={} \
             prefill_tokens_saved={} pages_retained={}",
            i,
            p.completed,
            p.failed,
            p.tokens_out,
            p.retained_hits,
            p.prefill_tokens_saved,
            p.kv_pages_retained
        );
    }
    Ok(())
}

fn eval(what: &str, seeds: u64, artifacts: &str, model: &str) -> Result<()> {
    let all = what == "all";
    let is = |x: &str| all || what == x;

    if is("fig1-accuracy") {
        emit(accuracy::fig1_accuracy(seeds), "fig1_accuracy");
    }
    if is("fig1-breakdown") {
        emit(latency::fig1_breakdown(), "fig1_breakdown");
    }
    if is("fig2-pareto") {
        emit(accuracy::fig2_pareto(seeds), "fig2_pareto");
    }
    if is("table1") {
        emit(latency::table1(), "table1");
    }
    if is("table2") {
        for (i, t) in accuracy::table2(seeds).into_iter().enumerate() {
            emit(t, &format!("table2_{}", i));
        }
    }
    if is("table3") {
        let k = if all { seeds.max(4) } else { seeds.max(4) };
        for (i, t) in accuracy::table3(k).into_iter().enumerate() {
            emit(t, &format!("table3_{}", i));
        }
    }
    if is("table4") {
        emit(accuracy::table4(seeds), "table4");
    }
    if is("table5") {
        emit(accuracy::table5(seeds), "table5");
    }
    if is("table6") {
        emit(accuracy::table6(seeds), "table6");
    }
    if is("table7") {
        emit(accuracy::table7(seeds), "table7");
    }
    if is("table8") {
        emit(accuracy::table8(seeds), "table8");
    }
    if is("table9") {
        emit(accuracy::table9(seeds), "table9");
    }
    if is("fig7") {
        for (i, t) in latency::fig7().into_iter().enumerate() {
            emit(t, &format!("fig7_{}", i));
        }
    }
    if is("fig8") {
        for (i, t) in latency::fig8().into_iter().enumerate() {
            emit(t, &format!("fig8_{}", i));
        }
    }
    if is("fig9") {
        for (i, t) in latency::fig9().into_iter().enumerate() {
            emit(t, &format!("fig9_{}", i));
        }
    }
    if is("fig10") {
        emit(latency::fig10(), "fig10");
    }
    if is("dtype") {
        emit(accuracy::dtype_ablation(seeds), "dtype_ablation");
    }
    if is("oom") {
        emit(latency::oom_table(), "oom");
    }
    if is("prefix-mem") {
        emit(latency::prefix_mem_table(), "prefix_mem");
    }
    if is("fig3-similarity") {
        emit(real::fig3_similarity(artifacts, model, 96)?, "fig3_similarity");
    }
    if is("real-breakdown") {
        let (a, b) = real::real_breakdown(artifacts, model, 600, 128, 0.9)?;
        emit(a, "real_breakdown");
        emit(b, "real_counters");
    }
    if is("real-correction") {
        emit(real::real_correction_rates(artifacts, model, 96)?, "real_correction");
    }
    if is("fig16-20") {
        emit(real::per_layer_corrections(artifacts, model, 96, 0.9)?, "fig16_20");
    }
    Ok(())
}
