//! f32 linear-algebra substrate for coordinator-side math: cosine
//! similarity (fine-grained correction), selection scoring for the
//! simulators, softmax/top-k, and a one-sided Jacobi SVD used by the
//! ShadowKV baseline's low-rank key reconstruction.

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than a naive fold on
    // the per-step correction path (called n_layers * n_qo times/token).
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Euclidean (L2) norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0 when either vector is ~zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// Indices of the k largest values (descending by value, stable on ties).
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    if k == 0 {
        // select_nth_unstable_by(0, ..) on an empty index vec would be
        // out-of-bounds; an empty query or empty input selects nothing.
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut top: Vec<usize> = idx[..k].to_vec();
    top.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    top
}

/// Index of the largest value (first on ties; 0 for empty input).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major backing storage, `rows * cols` elements.
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from row vectors; every row must have the same length.
    pub fn from_rows(rows: Vec<Vec<f32>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Element at row `r`, column `c`.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable reference to the element at row `r`, column `c`.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row `r` as a contiguous slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// self [m,k] x other [k,n] -> [m,n]; ikj loop order for locality.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.at(i, p);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[p * n..(p + 1) * n];
                let dst = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    dst[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Frobenius norm (L2 norm of all entries).
    pub fn frob_norm(&self) -> f32 {
        norm(&self.data)
    }
}

/// Thin SVD A = U S V^T via one-sided Jacobi on A^T A (columns of A are
/// rotated until mutually orthogonal). Suited to the tall-skinny key
/// matrices ShadowKV factorizes (T x d with T >> d).
///
/// Returns (u [m,k], s [k], vt [k,n]) with k = min(rank, n), singular
/// values descending.
pub fn svd_jacobi(a: &Mat, rank: usize, max_sweeps: usize) -> (Mat, Vec<f32>, Mat) {
    let (m, n) = (a.rows, a.cols);
    let mut u = a.clone(); // columns become U * S
    let mut v = Mat::zeros(n, n);
    for i in 0..n {
        *v.at_mut(i, i) = 1.0;
    }
    let eps = 1e-9f32;
    for _ in 0..max_sweeps {
        let mut off = 0.0f32;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f32, 0.0f32, 0.0f32);
                for r in 0..m {
                    let x = u.at(r, p);
                    let y = u.at(r, q);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() < eps * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..m {
                    let x = u.at(r, p);
                    let y = u.at(r, q);
                    *u.at_mut(r, p) = c * x - s * y;
                    *u.at_mut(r, q) = s * x + c * y;
                }
                for r in 0..n {
                    let x = v.at(r, p);
                    let y = v.at(r, q);
                    *v.at_mut(r, p) = c * x - s * y;
                    *v.at_mut(r, q) = s * x + c * y;
                }
            }
        }
        if off.sqrt() < 1e-7 * a.frob_norm().max(1e-30) {
            break;
        }
    }
    // Column norms are singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sig = vec![0.0f32; n];
    for j in 0..n {
        let mut s = 0.0f32;
        for r in 0..m {
            s += u.at(r, j) * u.at(r, j);
        }
        sig[j] = s.sqrt();
    }
    order.sort_by(|&a_, &b_| sig[b_].partial_cmp(&sig[a_]).unwrap());
    let k = rank.min(n);
    let mut uk = Mat::zeros(m, k);
    let mut vtk = Mat::zeros(k, n);
    let mut sk = vec![0.0f32; k];
    for (jj, &j) in order.iter().take(k).enumerate() {
        sk[jj] = sig[j];
        let inv = if sig[j] > 1e-20 { 1.0 / sig[j] } else { 0.0 };
        for r in 0..m {
            *uk.at_mut(r, jj) = u.at(r, j) * inv;
        }
        for r in 0..n {
            *vtk.at_mut(jj, r) = v.at(r, j);
        }
    }
    (uk, sk, vtk)
}

/// Reconstruct the rank-k approximation U diag(S) V^T.
pub fn svd_reconstruct(u: &Mat, s: &[f32], vt: &Mat) -> Mat {
    let mut us = u.clone();
    for r in 0..us.rows {
        for c in 0..us.cols {
            *us.at_mut(r, c) *= s[c];
        }
    }
    us.matmul(vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dot_and_cosine() {
        assert_eq!(dot(&[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0, 1.0, 1.0, 1.0, 1.0]), 15.0);
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-2.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -1e30];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert_eq!(xs[3], 0.0);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn top_k_orders_desc() {
        let xs = [0.1f32, 5.0, 3.0, 5.0, -2.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 0), Vec::<usize>::new());
        assert_eq!(top_k(&xs, 10).len(), 5);
    }

    #[test]
    fn top_k_empty_input_is_empty() {
        // regression: used to call select_nth_unstable_by(0, ..) on an
        // empty index vec and panic out-of-bounds.
        assert_eq!(top_k(&[], 3), Vec::<usize>::new());
        assert_eq!(top_k(&[], 0), Vec::<usize>::new());
        assert_eq!(top_k(&[1.0], 0), Vec::<usize>::new());
        assert_eq!(top_k(&[1.0], 1), vec![0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let id = Mat::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(a.matmul(&id), a);
        let b = Mat::from_rows(vec![vec![5.0], vec![6.0]]);
        let ab = a.matmul(&b);
        assert_eq!(ab.data, vec![17.0, 39.0]);
    }

    #[test]
    fn svd_reconstructs_low_rank() {
        // Build an exactly rank-3 matrix and verify the rank-3 SVD recovers it.
        let mut rng = Rng::new(9);
        let (m, n, r) = (64, 16, 3);
        let b = Mat { rows: m, cols: r, data: (0..m * r).map(|_| rng.normal_f32(0.0, 1.0)).collect() };
        let c = Mat { rows: r, cols: n, data: (0..r * n).map(|_| rng.normal_f32(0.0, 1.0)).collect() };
        let a = b.matmul(&c);
        let (u, s, vt) = svd_jacobi(&a, r, 30);
        let rec = svd_reconstruct(&u, &s, &vt);
        let mut err = 0.0f32;
        for i in 0..a.data.len() {
            err += (a.data[i] - rec.data[i]).powi(2);
        }
        assert!(err.sqrt() / a.frob_norm() < 1e-3, "rel err {}", err.sqrt() / a.frob_norm());
        assert!(s[0] >= s[1] && s[1] >= s[2]);
    }

    #[test]
    fn svd_truncation_error_decreases_with_rank() {
        let mut rng = Rng::new(10);
        let a = Mat { rows: 48, cols: 12, data: (0..48 * 12).map(|_| rng.normal_f32(0.0, 1.0)).collect() };
        let mut prev = f32::MAX;
        for rank in [2, 4, 8, 12] {
            let (u, s, vt) = svd_jacobi(&a, rank, 30);
            let rec = svd_reconstruct(&u, &s, &vt);
            let err: f32 = a
                .data
                .iter()
                .zip(&rec.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt();
            assert!(err <= prev + 1e-4, "rank {} err {} prev {}", rank, err, prev);
            prev = err;
        }
        assert!(prev < 1e-2); // full rank reconstructs exactly
    }

    #[test]
    fn svd_orthogonal_u() {
        let mut rng = Rng::new(11);
        let a = Mat { rows: 32, cols: 8, data: (0..32 * 8).map(|_| rng.normal_f32(0.0, 1.0)).collect() };
        let (u, _s, _vt) = svd_jacobi(&a, 8, 30);
        for i in 0..8 {
            for j in 0..8 {
                let mut d = 0.0f32;
                for r in 0..32 {
                    d += u.at(r, i) * u.at(r, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-3, "u'u[{},{}] = {}", i, j, d);
            }
        }
    }
}
