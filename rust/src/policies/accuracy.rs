//! Accuracy simulator: run each KV-compression policy's *selection /
//! eviction logic* over attention-oracle traces and measure attention-
//! mass recall plus task scores. Regenerates the accuracy exhibits
//! (Fig. 1 left, Fig. 2b, Tables 2-7) as analogs — the claim reproduced
//! is the ordering/gaps between methods, not LLM benchmark points.

use std::collections::HashMap;

use crate::config::{FreeKvParams, SelectVariant};
use crate::linalg;
use crate::oracle::{StepTruth, TaskKind, Trace};
use crate::policies::latency::Method;
use crate::util::rng::Rng;

/// Page budget shared by all methods (paper: B=2048 => sink/window/select
/// in pages; defaults mirror the tiny config's proportions).
#[derive(Debug, Clone, Copy)]
pub struct AccBudget {
    /// Always-resident sink pages at the sequence start.
    pub sink: usize,
    /// Always-resident sliding-window pages at the sequence tail.
    pub window: usize,
    /// Dynamically selected middle pages.
    pub select: usize,
}

impl Default for AccBudget {
    fn default() -> Self {
        AccBudget { sink: 2, window: 2, select: 12 }
    }
}

/// Per-episode outcome.
#[derive(Debug, Clone, Default)]
pub struct EpisodeResult {
    /// mean attention-mass recall over steps and heads.
    pub mass_recall: f64,
    /// task score in [0,1] (needle coverage / CR / revisit coverage).
    pub task_score: f64,
    /// completion rate for LongGen-style subtask windows.
    pub completion_rate: f64,
    /// solved flag for reasoning episodes (coverage >= 0.8).
    pub solved: bool,
    /// fraction of (step, kv-head) pairs corrected (FreeKV only).
    pub correction_rate: f64,
    /// mean adjacent-step query similarity observed.
    pub mean_query_sim: f64,
}

/// Extra method knobs for the accuracy sim.
#[derive(Debug, Clone)]
pub struct AccKnobs {
    /// FreeKV parameters (tau, pooling, selection variant).
    pub freekv: FreeKvParams,
    /// Razor retrieval-head fraction.
    pub razor_rho: f64,
    /// ShadowKV summary-refresh interval (steps) and staleness noise.
    pub shadowkv_refresh: usize,
    /// Noise added to ShadowKV's stale summaries between refreshes.
    pub shadowkv_stale_noise: f32,
    /// InfiniGen last-layer proxy quality (1.0 = perfect query).
    pub infinigen_mix: f32,
    /// Use the previous step's *last layer* query instead of the previous
    /// step (Appendix B.1 comparison).
    pub freekv_last_layer_proxy: bool,
}

impl Default for AccKnobs {
    fn default() -> Self {
        AccKnobs {
            freekv: FreeKvParams::default(),
            razor_rho: 0.25,
            shadowkv_refresh: 128,
            shadowkv_stale_noise: 0.5,
            infinigen_mix: 0.5,
            freekv_last_layer_proxy: false,
        }
    }
}

/// Group-pool per-q-head score rows into per-kv-head scores.
fn pool_scores(
    st: &StepTruth,
    n_kv: usize,
    g: usize,
    variant: SelectVariant,
    mask: impl Fn(usize) -> bool,
) -> Vec<Vec<f32>> {
    let neg = -1e30f32;
    let n_pages = st.n_pages;
    let mut out = Vec::with_capacity(n_kv);
    for m in 0..n_kv {
        let rows: Vec<&Vec<f32>> =
            (0..g).map(|j| &st.summary_scores[m * g + j]).collect();
        let scores: Vec<f32> = match variant {
            SelectVariant::MeanQ => st.scores_meanq[m]
                .iter()
                .enumerate()
                .map(|(pg, &s)| if mask(pg) { s } else { neg })
                .collect(),
            SelectVariant::MaxQ => st.scores_maxq[m]
                .iter()
                .enumerate()
                .map(|(pg, &s)| if mask(pg) { s } else { neg })
                .collect(),
            SelectVariant::MeanQK | SelectVariant::MaxQK => (0..n_pages)
                .map(|pg| {
                    if !mask(pg) {
                        return neg;
                    }
                    let vals = rows.iter().map(|r| r[pg]);
                    if variant == SelectVariant::MeanQK {
                        vals.sum::<f32>() / g as f32
                    } else {
                        vals.fold(f32::NEG_INFINITY, f32::max)
                    }
                })
                .collect(),
            SelectVariant::MeanS | SelectVariant::MaxS => {
                let mut pooled = vec![0.0f32; n_pages];
                for r in &rows {
                    let mut row: Vec<f32> = (0..n_pages)
                        .map(|pg| if mask(pg) { r[pg] } else { neg })
                        .collect();
                    linalg::softmax_inplace(&mut row);
                    for pg in 0..n_pages {
                        if variant == SelectVariant::MeanS {
                            pooled[pg] += row[pg] / g as f32;
                        } else {
                            pooled[pg] = pooled[pg].max(row[pg]);
                        }
                    }
                }
                (0..n_pages).map(|pg| if mask(pg) { pooled[pg] } else { neg }).collect()
            }
        };
        out.push(scores);
    }
    out
}

/// Resident (non-selected) pages at a step: sink + window.
fn resident(st: &StepTruth, b: &AccBudget) -> Vec<usize> {
    let mut r: Vec<usize> = (0..b.sink.min(st.n_pages)).collect();
    let lo = st.n_pages.saturating_sub(b.window);
    for pg in lo..st.n_pages {
        if pg >= b.sink {
            r.push(pg);
        }
    }
    r
}

fn selectable(st: &StepTruth, b: &AccBudget) -> impl Fn(usize) -> bool {
    let lo = b.sink;
    let hi = st.n_pages.saturating_sub(b.window);
    move |pg| pg >= lo && pg < hi
}

/// Run one method over one trace.
pub fn run_episode(
    method: Method,
    variant: SelectVariant,
    trace: &Trace,
    budget: &AccBudget,
    knobs: &AccKnobs,
    seed: u64,
) -> EpisodeResult {
    let mut rng = Rng::new(seed ^ 0xACC);
    let n_kv = trace.n_kv;
    let g = trace.group();
    let k_sel = budget.select;

    // --- per-method persistent state ---
    // retrieval: previous step's selection (FreeKV speculation).
    let mut prev_sel: Vec<Vec<usize>> = vec![vec![]; n_kv];
    // dropping: held pages + last-important timestamp (RaaS rule) and
    // the set of permanently dropped pages.
    let mut held: Vec<Vec<usize>> = vec![vec![]; n_kv];
    let mut last_hot: Vec<HashMap<usize, usize>> = vec![HashMap::new(); n_kv];
    let mut dropped: Vec<Vec<bool>> = vec![vec![]; n_kv];
    // razor: which kv heads are retrieval heads.
    let retrieval_head: Vec<bool> =
        (0..n_kv).map(|m| (m as f64 + 0.5) / n_kv as f64 <= knobs.razor_rho).collect();
    // shadowkv: last summary refresh step.
    let mut last_refresh = 0usize;

    let mut mass_sum = 0.0f64;
    let mut mass_n = 0usize;
    let mut req_hits_f = 0.0f64;
    let mut req_total = 0usize;
    let mut corrections = 0usize;
    let mut sim_sum = 0.0f64;
    let mut sim_n = 0usize;
    // per hot-window coverage for CR: (window id -> (covered, total)).
    let mut window_cover: HashMap<(usize, usize), (usize, usize)> = HashMap::new();

    for (t, st) in trace.steps.iter().enumerate() {
        for &s in &st.query_sim {
            sim_sum += s as f64;
            sim_n += 1;
        }
        let res = resident(st, budget);
        let can = selectable(st, budget);

        // ---- choose selected pages per kv head ----
        let sel: Vec<Vec<usize>> = match method {
            Method::Full => vec![(0..st.n_pages).collect(); n_kv],
            Method::Streaming => vec![vec![]; n_kv],
            Method::Razor => (0..n_kv)
                .map(|m| if retrieval_head[m] { (0..st.n_pages).collect() } else { vec![] })
                .collect(),
            Method::RaaS => {
                // dynamic dropping with the timestamp rule: held pages are
                // scored by realized attention (visible only for held).
                for m in 0..n_kv {
                    dropped[m].resize(st.n_pages, false);
                    if t == 0 {
                        // prefill snapshot (SnapKV/RaaS style): admit the
                        // top-k pages by observed prompt attention.
                        let mut agg = vec![0.0f32; st.n_pages];
                        for j in 0..g {
                            for (pg, &w) in st.weights[m * g + j].iter().enumerate() {
                                agg[pg] += w;
                            }
                        }
                        for pg in linalg::top_k(&agg, k_sel) {
                            if can(pg) {
                                held[m].push(pg);
                                last_hot[m].insert(pg, 0);
                            }
                        }
                    }
                    // admit pages leaving the window (they must be held or
                    // dropped permanently).
                    let leaving = st.n_pages.saturating_sub(budget.window);
                    if leaving > budget.sink {
                        let pg = leaving - 1;
                        if !held[m].contains(&pg) && !dropped[m][pg] {
                            if held[m].len() < k_sel {
                                held[m].push(pg);
                                last_hot[m].insert(pg, t);
                            } else {
                                // evict the page with the oldest last-hot
                                let (&victim, _) = last_hot[m]
                                    .iter()
                                    .min_by_key(|(_, &ts)| ts)
                                    .unwrap();
                                if last_hot[m][&victim] < t {
                                    held[m].retain(|&x| x != victim);
                                    last_hot[m].remove(&victim);
                                    dropped[m][victim] = true;
                                    held[m].push(pg);
                                    last_hot[m].insert(pg, t);
                                } else {
                                    dropped[m][pg] = true;
                                }
                            }
                        }
                    }
                    // update timestamps from realized attention over held
                    for j in 0..g {
                        let w = &st.weights[m * g + j];
                        for &pg in &held[m] {
                            if w[pg] > 1.0 / (k_sel + budget.sink + budget.window) as f32 {
                                last_hot[m].insert(pg, t);
                            }
                        }
                    }
                }
                held.clone()
            }
            Method::Quest | Method::ArkVale => {
                // current-step selection; Quest was adapted to group-max in
                // the paper's baselines, ArkVale pools means over weights.
                let v = if method == Method::Quest { SelectVariant::MaxQK } else { SelectVariant::MeanQK };
                let scores = pool_scores(st, n_kv, g, v, &can);
                scores.iter().map(|row| linalg::top_k(row, k_sel)).collect()
            }
            Method::ShadowKv => {
                // current-step selection with reconstruction/staleness
                // noise on generated pages.
                if t.saturating_sub(last_refresh) >= knobs.shadowkv_refresh {
                    last_refresh = t;
                }
                let prompt_pages = trace.spec.prompt_pages;
                let mut scores = pool_scores(st, n_kv, g, SelectVariant::MeanS, &can);
                for row in scores.iter_mut() {
                    for (pg, s) in row.iter_mut().enumerate() {
                        if pg >= prompt_pages && *s > -1e29 {
                            let birth =
                                prompt_pages + (pg - prompt_pages) * trace.spec.tokens_per_page;
                            let stale = t.saturating_sub(last_refresh.max(birth)) as f32
                                / knobs.shadowkv_refresh as f32;
                            *s += knobs.shadowkv_stale_noise
                                * stale.min(2.0)
                                * rng.normal_f32(0.0, 1.0);
                        }
                    }
                }
                scores.iter().map(|row| linalg::top_k(row, k_sel)).collect()
            }
            Method::InfiniGen => {
                // degraded query proxy: blend true scores with noise.
                let scores = pool_scores(st, n_kv, g, SelectVariant::MaxQK, &can);
                let noisy: Vec<Vec<f32>> = scores
                    .iter()
                    .map(|row| {
                        row.iter()
                            .map(|&s| {
                                if s < -1e29 {
                                    s
                                } else {
                                    knobs.infinigen_mix * s
                                        + (1.0 - knobs.infinigen_mix) * rng.normal_f32(0.0, 1.0)
                                }
                            })
                            .collect()
                    })
                    .collect();
                noisy.iter().map(|row| linalg::top_k(row, k_sel)).collect()
            }
            Method::FreeKv => {
                // Speculative retrieval (Fig. 4a): step i's attention
                // reuses the pages selected+recalled during step i-1 (with
                // q_{i-1}); correction re-selects with q_i for kv heads
                // whose pooled query similarity drops below tau.
                let cur_scores = if knobs.freekv_last_layer_proxy {
                    // Appendix B.1: selection driven by the *last layer's*
                    // query instead of the last step's — a degraded proxy
                    // with no correction signal.
                    let base = pool_scores(st, n_kv, g, variant, &can);
                    base.iter()
                        .map(|row| {
                            row.iter()
                                .map(|&s| {
                                    if s < -1e29 {
                                        s
                                    } else {
                                        0.65 * s + 0.35 * rng.normal_f32(0.0, 1.0)
                                    }
                                })
                                .collect()
                        })
                        .collect()
                } else {
                    pool_scores(st, n_kv, g, variant, &can)
                };
                let mut sel: Vec<Vec<usize>> = Vec::with_capacity(n_kv);
                for m in 0..n_kv {
                    let pooled_sim = if knobs.freekv.correction_pool_max {
                        // most-deviated head (conservative; more corrections)
                        (0..g)
                            .map(|j| st.query_sim[m * g + j])
                            .fold(f32::INFINITY, f32::min)
                    } else {
                        (0..g).map(|j| st.query_sim[m * g + j]).sum::<f32>() / g as f32
                    };
                    let tau =
                        if knobs.freekv.no_speculation { 1.01 } else { knobs.freekv.tau };
                    let corrected = !knobs.freekv_last_layer_proxy && pooled_sim < tau;
                    let use_current = t == 0 || prev_sel[m].is_empty() || corrected;
                    if corrected && t > 0 {
                        corrections += 1;
                    }
                    let row: Vec<usize> = if use_current {
                        linalg::top_k(&cur_scores[m], k_sel)
                    } else {
                        // reuse the selection recalled during step i-1
                        prev_sel[m].clone()
                    };
                    sel.push(row);
                }
                // The selection computed *this* step (with q_i) is what
                // gets recalled for reuse at step i+1.
                prev_sel =
                    cur_scores.iter().map(|row| linalg::top_k(row, k_sel)).collect();
                sel
            }
        };

        // ---- metrics ----
        let budget_pages = budget.sink + budget.window + budget.select;
        let mut any_head_kept = vec![false; st.n_pages];
        for m in 0..n_kv {
            // dedup: selected pages may overlap sink/window
            let mut kept = vec![false; st.n_pages];
            for &pg in res.iter().chain(sel[m].iter()) {
                if pg < st.n_pages {
                    kept[pg] = true;
                    any_head_kept[pg] = true;
                }
            }
            for j in 0..g {
                let w = &st.weights[m * g + j];
                let mass: f32 = w.iter().zip(&kept).filter(|(_, &k)| k).map(|(x, _)| x).sum();
                // normalize by the best achievable mass under the same
                // page budget (ideal top-B coverage) -> attention recall.
                let mut order: Vec<f32> = w.clone();
                order.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let ideal: f32 = order.iter().take(budget_pages).sum();
                mass_sum += (mass / ideal.max(1e-9)).min(1.0) as f64;
                mass_n += 1;
            }
        }
        // task hit semantics: short lookups (NIAH) succeed if ANY kv head
        // surfaces the page (RazorAttention's retrieval-head premise);
        // sustained generation (LongGen/Reasoning) needs broad head
        // participation, so hits count the fraction of kv heads covering.
        for &pg in &st.required_pages {
            req_total += 1;
            let heads_with = (0..n_kv)
                .filter(|&m| {
                    let r = resident(st, budget);
                    pg < st.n_pages
                        && (r.contains(&pg) || sel[m].contains(&pg))
                })
                .count();
            let hit_frac = match trace.spec.kind {
                TaskKind::Niah => {
                    if pg < st.n_pages && any_head_kept[pg] { 1.0 } else { 0.0 }
                }
                _ => heads_with as f64 / n_kv as f64,
            };
            req_hits_f += hit_frac;
            let entry = window_cover.entry((pg, t / 24)).or_insert((0, 0));
            entry.1 += 1;
            if hit_frac >= 0.5 {
                entry.0 += 1;
            }
        }
    }

    let task_score =
        if req_total > 0 { req_hits_f / req_total as f64 } else { mass_sum / mass_n.max(1) as f64 };
    let completion_rate = if window_cover.is_empty() {
        task_score
    } else {
        let done = window_cover.values().filter(|(c, n)| *c * 2 >= *n).count();
        done as f64 / window_cover.len() as f64
    };
    EpisodeResult {
        mass_recall: mass_sum / mass_n.max(1) as f64,
        task_score,
        completion_rate,
        solved: match trace.spec.kind {
            TaskKind::Reasoning => task_score >= 0.8,
            _ => task_score >= 0.9,
        },
        correction_rate: corrections as f64 / ((trace.steps.len().max(2) - 1) * n_kv) as f64,
        mean_query_sim: sim_sum / sim_n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::{generate, OracleParams, TaskSpec};

    fn trace(kind: TaskKind, seed: u64) -> Trace {
        generate(&TaskSpec::default_for(kind), 8, 2, &OracleParams::default(), seed)
    }

    fn score(method: Method, kind: TaskKind) -> f64 {
        let mut acc = 0.0;
        for seed in 0..4 {
            let tr = trace(kind, seed);
            acc += run_episode(
                method,
                SelectVariant::MeanS,
                &tr,
                &AccBudget::default(),
                &AccKnobs::default(),
                seed,
            )
            .task_score;
        }
        acc / 4.0
    }

    #[test]
    fn full_cache_is_upper_bound() {
        for kind in [TaskKind::Niah, TaskKind::Reasoning] {
            let full = score(Method::Full, kind);
            let stream = score(Method::Streaming, kind);
            assert!(full >= stream, "{:?}", kind);
            assert!(full > 0.99, "full {:?} = {}", kind, full);
        }
    }

    #[test]
    fn dropping_fails_on_reasoning_retrieval_holds() {
        // The paper's central accuracy claim (Fig. 1 left).
        let raas = score(Method::RaaS, TaskKind::Reasoning);
        let freekv = score(Method::FreeKv, TaskKind::Reasoning);
        let quest = score(Method::Quest, TaskKind::Reasoning);
        assert!(
            freekv > raas + 0.1,
            "freekv {} should beat raas {} on reasoning",
            freekv,
            raas
        );
        assert!(quest > raas, "quest {} raas {}", quest, raas);
    }

    #[test]
    fn freekv_close_to_current_step_retrieval() {
        for kind in [TaskKind::Summarization, TaskKind::LongGen] {
            let fk = score(Method::FreeKv, kind);
            let qs = score(Method::Quest, kind);
            assert!(fk > qs - 0.08, "{:?}: freekv {} quest {}", kind, fk, qs);
        }
    }

    #[test]
    fn correction_rate_increases_with_tau() {
        let tr = trace(TaskKind::Reasoning, 9);
        let mut rates = Vec::new();
        for tau in [0.0f32, 0.8, 0.9, 1.0] {
            let knobs = AccKnobs {
                freekv: FreeKvParams { tau, no_speculation: tau >= 1.0, ..Default::default() },
                ..Default::default()
            };
            let r = run_episode(Method::FreeKv, SelectVariant::MeanS, &tr, &AccBudget::default(), &knobs, 1);
            rates.push(r.correction_rate);
        }
        assert!(rates[0] < 0.05);
        assert!(rates[1] <= rates[2] + 1e-9);
        assert!(rates[3] > 0.95);
    }

    #[test]
    fn speculation_with_correction_beats_no_correction_on_reasoning() {
        let mut with = 0.0;
        let mut without = 0.0;
        for seed in 0..6 {
            let tr = trace(TaskKind::Reasoning, 100 + seed);
            let k_with = AccKnobs {
                freekv: FreeKvParams { tau: 0.9, ..Default::default() },
                ..Default::default()
            };
            let k_without = AccKnobs {
                freekv: FreeKvParams { tau: 0.0, ..Default::default() },
                ..Default::default()
            };
            with += run_episode(Method::FreeKv, SelectVariant::MeanS, &tr, &AccBudget::default(), &k_with, seed).task_score;
            without += run_episode(Method::FreeKv, SelectVariant::MeanS, &tr, &AccBudget::default(), &k_without, seed).task_score;
        }
        assert!(with >= without, "with {} without {}", with, without);
    }

    #[test]
    fn streaming_misses_needle() {
        let niah = score(Method::Streaming, TaskKind::Niah);
        assert!(niah < 0.35, "streaming niah {}", niah);
    }
}
