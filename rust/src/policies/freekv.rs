//! FreeKV algorithm core (paper §3): speculative retrieval state and the
//! fine-grained correction rule. Pure functions + small state machines so
//! the real engine (coordinator), the latency simulator, and the accuracy
//! simulator all share the same logic.

use crate::config::FreeKvParams;
use crate::linalg;

/// Per-layer speculative state: the previous step's query vectors and the
/// selection they produced (already recalled, resident on GPU).
#[derive(Debug, Clone)]
pub struct SpecState {
    /// previous step's q, `[n_qo][d]` flattened.
    pub prev_q: Option<Vec<f32>>,
    /// Query heads.
    pub n_qo: usize,
    /// KV heads.
    pub n_kv: usize,
    /// Head dimension.
    pub d: usize,
}

impl SpecState {
    /// Fresh state with no previous query recorded.
    pub fn new(n_qo: usize, n_kv: usize, d: usize) -> SpecState {
        SpecState { prev_q: None, n_qo, n_kv, d }
    }

    /// Query heads per kv head (GQA group size).
    pub fn group(&self) -> usize {
        self.n_qo / self.n_kv
    }

    /// Per-query-head cosine similarity between the current and previous
    /// step's query vectors (the paper's C_i, §3.1).
    pub fn head_similarities(&self, q: &[f32]) -> Option<Vec<f32>> {
        let prev = self.prev_q.as_ref()?;
        debug_assert_eq!(q.len(), self.n_qo * self.d);
        Some(
            (0..self.n_qo)
                .map(|h| {
                    linalg::cosine(&q[h * self.d..(h + 1) * self.d], &prev[h * self.d..(h + 1) * self.d])
                })
                .collect(),
        )
    }

    /// Record the current step's queries for the next step's check.
    pub fn store(&mut self, q: &[f32]) {
        debug_assert_eq!(q.len(), self.n_qo * self.d);
        match &mut self.prev_q {
            Some(buf) => buf.copy_from_slice(q),
            None => self.prev_q = Some(q.to_vec()),
        }
    }
}

/// Outcome of the correction check for one layer (paper §3.3).
#[derive(Debug, Clone, PartialEq)]
pub struct CorrectionDecision {
    /// group-pooled similarity per kv head.
    pub group_sim: Vec<f32>,
    /// kv heads whose pooled similarity dropped below tau: these get a
    /// blocking select+recall before attention.
    pub corrected_heads: Vec<usize>,
}

impl CorrectionDecision {
    /// Whether any kv head needs correction.
    pub fn any(&self) -> bool {
        !self.corrected_heads.is_empty()
    }
}

/// Apply the query-based identification rule: pool C_i across the head
/// group (mean by default, max for the Appendix B.3 ablation), compare
/// with tau.
pub fn correction_check(
    head_sims: &[f32],
    n_kv: usize,
    params: &FreeKvParams,
) -> CorrectionDecision {
    let g = head_sims.len() / n_kv;
    let mut group_sim = Vec::with_capacity(n_kv);
    let mut corrected = Vec::new();
    for m in 0..n_kv {
        let grp = &head_sims[m * g..(m + 1) * g];
        // "max pooling" pools the *dissimilarity* (i.e. takes the most
        // deviated head) — the conservative variant the paper reports as
        // triggering more corrections with similar accuracy (App. B.3).
        let pooled = if params.correction_pool_max {
            grp.iter().cloned().fold(f32::INFINITY, f32::min)
        } else {
            grp.iter().sum::<f32>() / g as f32
        };
        group_sim.push(pooled);
        let tau = if params.no_speculation { 1.0 + 1e-6 } else { params.tau };
        if pooled < tau {
            corrected.push(m);
        }
    }
    CorrectionDecision { group_sim, corrected_heads: corrected }
}

/// Group-consistent page scoring on the coordinator side (used by the
/// simulators and as a fallback/reference for the select artifact).
///
/// q `[n_qo][d]`, smin/smax `[n_kv][P][d]`, mask `[P]` -> scores `[n_kv][P]`.
pub fn select_scores(
    q: &[f32],
    smin: &[f32],
    smax: &[f32],
    mask: &[f32],
    n_kv: usize,
    n_qo: usize,
    d: usize,
    variant: crate::config::SelectVariant,
) -> Vec<Vec<f32>> {
    use crate::config::SelectVariant as V;
    let g = n_qo / n_kv;
    let p = mask.len();
    let neg = -1e30f32;
    let bound = |qh: &[f32], m: usize, pg: usize| -> f32 {
        let base = (m * p + pg) * d;
        let mut s = 0.0f32;
        for dim in 0..d {
            let lo = qh[dim] * smin[base + dim];
            let hi = qh[dim] * smax[base + dim];
            s += lo.max(hi);
        }
        s
    };
    let mut out = Vec::with_capacity(n_kv);
    for m in 0..n_kv {
        let scores = match variant {
            V::MeanQ | V::MaxQ => {
                let mut qp = vec![0.0f32; d];
                for j in 0..g {
                    let qh = &q[(m * g + j) * d..(m * g + j + 1) * d];
                    for dim in 0..d {
                        qp[dim] = if variant == V::MeanQ {
                            qp[dim] + qh[dim] / g as f32
                        } else if j == 0 {
                            qh[dim]
                        } else {
                            qp[dim].max(qh[dim])
                        };
                    }
                }
                (0..p)
                    .map(|pg| if mask[pg] > 0.0 { bound(&qp, m, pg) } else { neg })
                    .collect::<Vec<f32>>()
            }
            V::MeanQK | V::MaxQK => {
                let mut pooled = vec![if variant == V::MaxQK { neg } else { 0.0 }; p];
                for j in 0..g {
                    let qh = &q[(m * g + j) * d..(m * g + j + 1) * d];
                    for pg in 0..p {
                        let b = bound(qh, m, pg);
                        if variant == V::MeanQK {
                            pooled[pg] += b / g as f32;
                        } else {
                            pooled[pg] = pooled[pg].max(b);
                        }
                    }
                }
                (0..p).map(|pg| if mask[pg] > 0.0 { pooled[pg] } else { neg }).collect()
            }
            V::MeanS | V::MaxS => {
                let mut pooled = vec![0.0f32; p];
                for j in 0..g {
                    let qh = &q[(m * g + j) * d..(m * g + j + 1) * d];
                    let mut row: Vec<f32> =
                        (0..p).map(|pg| if mask[pg] > 0.0 { bound(qh, m, pg) } else { neg }).collect();
                    linalg::softmax_inplace(&mut row);
                    for pg in 0..p {
                        let v = if mask[pg] > 0.0 { row[pg] } else { 0.0 };
                        if variant == V::MeanS {
                            pooled[pg] += v / g as f32;
                        } else {
                            pooled[pg] = pooled[pg].max(v);
                        }
                    }
                }
                pooled
            }
        };
        out.push(scores);
    }
    out
}

/// Top-k selection from per-head scores.
pub fn select_pages(scores: &[Vec<f32>], k: usize) -> Vec<Vec<usize>> {
    scores.iter().map(|row| linalg::top_k(row, k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FreeKvParams, SelectVariant};

    #[test]
    fn spec_state_similarity() {
        let mut st = SpecState::new(4, 2, 3);
        let q1 = vec![
            1.0, 0.0, 0.0, /**/ 0.0, 1.0, 0.0, /**/ 1.0, 1.0, 0.0, /**/ 0.0, 0.0, 1.0,
        ];
        assert!(st.head_similarities(&q1).is_none());
        st.store(&q1);
        let mut q2 = q1.clone();
        q2[0..3].copy_from_slice(&[0.0, 1.0, 0.0]); // head 0 rotated 90 deg
        let sims = st.head_similarities(&q2).unwrap();
        assert!((sims[0] - 0.0).abs() < 1e-6);
        assert!((sims[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn correction_thresholds() {
        let p = FreeKvParams { tau: 0.8, ..Default::default() };
        // n_kv=2, G=2: head sims [0.9, 0.95 | 0.5, 0.9]
        let d = correction_check(&[0.9, 0.95, 0.5, 0.9], 2, &p);
        assert_eq!(d.corrected_heads, vec![1]); // mean 0.7 < 0.8
        assert!((d.group_sim[0] - 0.925).abs() < 1e-6);

        // "max" pooling is conservative (most-deviated head): head 0's
        // group dips to 0.9 >= 0.8 (no correction) but head 1's dips to
        // 0.5 -> corrected, and it triggers at least as often as mean.
        let pmax = FreeKvParams { tau: 0.8, correction_pool_max: true, ..Default::default() };
        let d2 = correction_check(&[0.9, 0.95, 0.5, 0.9], 2, &pmax);
        assert_eq!(d2.corrected_heads, vec![1]);
        let d3 = correction_check(&[0.75, 0.95, 0.95, 0.95], 2, &pmax);
        assert_eq!(d3.corrected_heads, vec![0]); // mean (0.85) would not trigger

        // tau = 0 -> never corrects; no_speculation -> always corrects
        let p0 = FreeKvParams { tau: 0.0, ..Default::default() };
        assert!(!correction_check(&[0.2, 0.2, 0.2, 0.2], 2, &p0).any());
        let p1 = FreeKvParams { no_speculation: true, ..Default::default() };
        assert_eq!(correction_check(&[1.0, 1.0, 1.0, 1.0], 2, &p1).corrected_heads, vec![0, 1]);
    }

    #[test]
    fn select_scores_group_consistent_and_masked() {
        // n_kv=1, G=2, d=2, P=3; head0 aligned with page0 summary, head1
        // with page2; MeanS must produce one shared ranking.
        let q = vec![1.0, 0.0, /**/ 0.0, 1.0];
        let smin = vec![
            0.9, 0.0, /*pg0*/ 0.1, 0.1, /*pg1*/ 0.0, 0.9, /*pg2*/
        ];
        let smax = smin.clone();
        let mask = vec![1.0, 1.0, 0.0];
        for variant in SelectVariant::all() {
            let scores = select_scores(&q, &smin, &smax, &mask, 1, 2, 2, variant);
            assert_eq!(scores.len(), 1);
            assert_eq!(scores[0].len(), 3);
            // masked page 2 never wins even though head1 loves it
            let top = select_pages(&scores, 1);
            assert_ne!(top[0][0], 2, "{:?}", variant);
        }
        // MeanS with full mask: both hot pages beat the dud page 1.
        let scores =
            select_scores(&q, &smin, &smax, &[1.0, 1.0, 1.0], 1, 2, 2, SelectVariant::MeanS);
        let top2 = select_pages(&scores, 2);
        assert!(top2[0].contains(&0) && top2[0].contains(&2));
    }

    #[test]
    fn rust_select_matches_quest_bound() {
        // bound = sum_d max(q*min, q*max); negative q flips which side wins.
        let q = vec![1.0, -1.0];
        let smin = vec![-2.0, -3.0];
        let smax = vec![5.0, 4.0];
        let s = select_scores(&q, &smin, &smax, &[1.0], 1, 1, 2, SelectVariant::MeanQK);
        // max(1*-2, 1*5) + max(-1*-3, -1*4) = 5 + 3 = 8
        assert!((s[0][0] - 8.0).abs() < 1e-6);
    }
}
