//! Latency simulator: per-method decode timelines on the discrete-event
//! substrate, parameterized by the paper's model geometries and device
//! profiles. Regenerates the shapes of Fig. 1 (right), Fig. 7, Fig. 8,
//! Fig. 9 and Fig. 10.
//!
//! Each method schedules, per decode step and per layer, its compute ops
//! on the Compute stream and its selection/recall work on the H2D /
//! Convert streams with the dependency structure the paper describes
//! (Fig. 2a): blocking for ArkVale/ShadowKV/Quest, next-layer prefetch
//! for InfiniGen, previous-step speculation (off the critical path) for
//! FreeKV, with fine-grained correction re-inserting blocking recalls at
//! the measured correction rate.

use crate::config::ModelConfig;
use crate::sim::{CostModel, EventId, Stream, Timeline};
use crate::util::rng::Rng;

/// KV compression methods compared in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Dense attention over the full KV cache (no sparsity).
    Full,
    /// Quest: query-aware page selection, all pages resident on GPU.
    Quest,
    /// ArkVale: page selection with CPU offload and page-cache recall.
    ArkVale,
    /// ShadowKV: low-rank keys on GPU, values recalled from CPU.
    ShadowKv,
    /// InfiniGen: speculative per-token prefetch from CPU.
    InfiniGen,
    /// RaaS: retrieval-attention with persistent top-k reuse.
    RaaS,
    /// RazorAttention: retrieval heads dense, other heads windowed.
    Razor,
    /// StreamingLLM: attention sinks plus a sliding window.
    Streaming,
    /// FreeKV: speculative recall with correction (this paper).
    FreeKv,
}

impl Method {
    /// Lower-case method name (CLI / table rows).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::Quest => "quest",
            Method::ArkVale => "arkvale",
            Method::ShadowKv => "shadowkv",
            Method::InfiniGen => "infinigen",
            Method::RaaS => "raas",
            Method::Razor => "razor",
            Method::Streaming => "streaming",
            Method::FreeKv => "freekv",
        }
    }

    /// Parse a method name as produced by [`Method::name`].
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "full" => Method::Full,
            "quest" => Method::Quest,
            "arkvale" => Method::ArkVale,
            "shadowkv" => Method::ShadowKv,
            "infinigen" => Method::InfiniGen,
            "raas" => Method::RaaS,
            "razor" => Method::Razor,
            "streaming" => Method::Streaming,
            "freekv" => Method::FreeKv,
            _ => return None,
        })
    }

    /// All methods, in table order.
    pub fn all() -> [Method; 9] {
        [
            Method::Full,
            Method::Quest,
            Method::ArkVale,
            Method::ShadowKv,
            Method::InfiniGen,
            Method::RaaS,
            Method::Razor,
            Method::Streaming,
            Method::FreeKv,
        ]
    }

    /// Does the method keep the full KV cache on CPU and recall?
    pub fn offloads(&self) -> bool {
        matches!(self, Method::ArkVale | Method::ShadowKv | Method::InfiniGen | Method::FreeKv)
    }
}

/// Simulation knobs; defaults follow the paper's settings and measured
/// rates (Appendix A / F). `churn` is the per-step fraction of selected
/// pages that change (1 - selection overlap between adjacent steps) —
/// the complement of the query-similarity effect the paper measures.
#[derive(Debug, Clone)]
pub struct SimKnobs {
    /// fraction of selected pages newly fetched per step (page-cache miss).
    pub churn: f64,
    /// fraction of decode steps where FreeKV correction triggers.
    pub correction_rate: f64,
    /// fraction of kv heads corrected when correction triggers.
    pub corrected_frac: f64,
    /// InfiniGen per-layer token miss fraction of the budget.
    pub infinigen_miss: f64,
    /// RazorAttention retrieval-head fraction (paper sparsity 0.15).
    pub razor_rho: f64,
    /// ShadowKV low-rank r / d_head fraction kept on GPU.
    pub shadowkv_rank_frac: f64,
    /// FreeKV ablation switches (Fig. 9): hybrid layouts, double-buffered
    /// streamed recall, speculative retrieval.
    pub hybrid_layout: bool,
    /// Double-buffered streamed recall (Fig. 9 ablation).
    pub double_buffer: bool,
    /// Speculative retrieval with the stale query (Fig. 9 ablation).
    pub speculative: bool,
    /// Dispatch speculative recall on the copy stream concurrently with
    /// compute (the real engine's `FreeKvParams::overlap`); when false
    /// the recall serializes with the next layer's compute, modeling the
    /// serial in-thread dispatch ablation.
    pub overlap: bool,
    /// Score FreeKV's page selection on an executor-pool worker
    /// (`Stream::Exec`) instead of the compute stream — the modeled
    /// analog of the real engine's `FreeKvParams::exec_workers`.
    /// Defaults to false so the paper-exhibit figures keep modeling the
    /// single-stream GPU engine the paper measures; the dispatch bench
    /// and serving configs flip it.
    pub pooled_selection: bool,
    /// Decode microbatch lanes for [`simulate_lane_scaling`] — the
    /// modeled analog of `FreeKvParams::max_lanes` /
    /// `Engine::decode_step_lanes`. `1` models joint single-stream
    /// decode; `simulate_request` ignores this (the paper exhibits stay
    /// single-lane).
    pub decode_lanes: usize,
    /// Modeled executor streams backing the lanes (the pool's worker
    /// count): lane `i` executes on `Lane(i % exec_streams)`, so lanes
    /// beyond this serialize like jobs sharing a pool worker.
    pub exec_streams: usize,
    /// GPU memory capacity for OOM accounting (A100-40G).
    pub gpu_mem_bytes: f64,
    /// runtime reserve (CUDA context, activations, workspace) subtracted
    /// from capacity before the OOM check.
    pub runtime_reserve: f64,
}

impl Default for SimKnobs {
    fn default() -> Self {
        SimKnobs {
            churn: 0.15,
            correction_rate: 0.12,
            corrected_frac: 0.3,
            infinigen_miss: 0.05,
            razor_rho: 0.15,
            shadowkv_rank_frac: 160.0 / 1024.0,
            hybrid_layout: true,
            double_buffer: true,
            speculative: true,
            overlap: true,
            pooled_selection: false,
            decode_lanes: 1,
            exec_streams: 2,
            gpu_mem_bytes: 40e9,
            runtime_reserve: 7e9,
        }
    }
}

impl SimKnobs {
    /// Long-generation scenario (tau = 0.9): more corrections (Table 9).
    pub fn long_generation() -> SimKnobs {
        SimKnobs { correction_rate: 0.3, corrected_frac: 0.35, ..Default::default() }
    }
}

/// Aggregate result of simulating one request.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    /// Method name (see [`Method::name`]).
    pub method: String,
    /// Modeled prefill wall time, seconds.
    pub prefill_secs: f64,
    /// Modeled decode wall time, seconds.
    pub decode_secs: f64,
    /// Decode steps simulated.
    pub steps: usize,
    /// busy time by class, for the Fig. 1 (right) breakdown.
    pub compute_busy: f64,
    /// Busy seconds scoring page selection.
    pub selection_busy: f64,
    /// Busy seconds recalling pages from CPU.
    pub recall_busy: f64,
    /// recall/selection time NOT hidden under compute (exposed).
    pub recall_exposed: f64,
    /// Selection time NOT hidden under compute (exposed).
    pub selection_exposed: f64,
    /// peak GPU bytes for KV-related state.
    pub gpu_kv_bytes: f64,
    /// Whether the modeled run exceeded GPU capacity.
    pub oom: bool,
}

impl RunRecord {
    /// Prefill + decode wall time, seconds.
    pub fn total(&self) -> f64 {
        self.prefill_secs + self.decode_secs
    }
    /// Mean decode seconds per generated token.
    pub fn per_token(&self) -> f64 {
        if self.steps == 0 { 0.0 } else { self.decode_secs / self.steps as f64 }
    }
}

/// Simulate one batched request: `input_len` prompt tokens, `output_len`
/// decode steps, batch size `b` (all requests in the batch share shape).
pub fn simulate_request(
    method: Method,
    cm: &CostModel,
    b: usize,
    input_len: usize,
    output_len: usize,
    knobs: &SimKnobs,
) -> RunRecord {
    let m = &cm.model;
    let mut rng = Rng::new(0xF4EE ^ (method as u64) << 8 ^ b as u64);
    let mut rec = RunRecord { method: method.name().into(), ..Default::default() };

    // ---- prefill: compute + (for offloading methods) page offload ----
    let mut tl = Timeline::new();
    let pre = tl.schedule(Stream::Compute, &[], cm.prefill_compute(input_len) * b as f64, "prefill");
    if method.offloads() {
        let pages = (input_len / m.page_size) * m.n_layers * b;
        // offload overlaps prefill compute; only the tail is exposed.
        tl.schedule(Stream::D2H, &[], cm.offload_page() * pages as f64, "offload");
        let _ = pre;
    }
    rec.prefill_secs = tl.makespan();

    // ---- decode ----
    let slots = m.budget_slots();
    let sel_k = m.select_pages;
    let mut tl = Timeline::new();
    // carried dependency: the speculative recall each step issues for the
    // next one (FreeKV), or InfiniGen's next-layer prefetch.
    let mut spec_recall_done: Vec<Option<usize>> = vec![None; m.n_layers];
    // serial-dispatch gate: with overlap off, the engine thread performs
    // the speculative recall inline, so the next compute op waits for it.
    let mut serial_gate: Option<usize> = None;

    for step in 0..output_len {
        let ctx = input_len + step;
        let ctx_pages = ctx / m.page_size;
        let full_slots = ctx;
        let mut prev_compute: Option<usize> = None;

        for layer in 0..m.n_layers {
            // deferred serial-dispatch speculative recall (sel event,
            // missed pages), scheduled once this layer's attn exists.
            let mut serial_spec: Option<(usize, usize)> = None;
            // -- linear part of the layer --
            let mut lin_deps: Vec<usize> = prev_compute.into_iter().collect();
            if let Some(g) = serial_gate.take() {
                lin_deps.push(g);
            }
            let lin = tl.schedule(
                Stream::Compute,
                &lin_deps,
                cm.layer_linear(b),
                "compute:linear",
            );

            // -- method-specific selection + recall before attention --
            let mut attn_deps: Vec<usize> = vec![lin];
            let mut attn_slots = slots;
            match method {
                Method::Full => attn_slots = full_slots,
                Method::Streaming => {}
                Method::Razor => {
                    // retrieval heads attend the full context: model as a
                    // weighted extra attention cost.
                    let extra = cm.attention(b, full_slots) * knobs.razor_rho;
                    let e = tl.schedule(Stream::Compute, &[lin], extra, "compute:razor-full-heads");
                    attn_deps = vec![e];
                }
                Method::RaaS => {
                    // online scoring of resident tokens.
                    let s = tl.schedule(
                        Stream::Compute,
                        &[lin],
                        cm.selection(b, slots / m.page_size),
                        "selection:raas",
                    );
                    attn_deps = vec![s];
                }
                Method::Quest => {
                    let s = tl.schedule(
                        Stream::Compute,
                        &[lin],
                        cm.selection(b, ctx_pages) + cm.gather(b, slots),
                        "selection:quest",
                    );
                    attn_deps = vec![s];
                }
                Method::ArkVale => {
                    // blocking: select, then recall missing pages (NHD pool).
                    let s = tl.schedule(
                        Stream::Compute,
                        &[lin],
                        cm.selection(b, ctx_pages),
                        "selection:arkvale",
                    );
                    let miss_pages =
                        ((sel_k as f64 * knobs.churn).ceil() as usize).max(1) * b;
                    let r = tl.schedule(
                        Stream::H2D,
                        &[s],
                        cm.recall_pages(miss_pages, false),
                        "recall:arkvale",
                    );
                    attn_deps = vec![r];
                }
                Method::ShadowKv => {
                    let s = tl.schedule(
                        Stream::Compute,
                        &[lin],
                        cm.selection(b, ctx_pages),
                        "selection:shadowkv",
                    );
                    // reconstruct keys of the selected pages from low rank.
                    let rank = (knobs.shadowkv_rank_frac * (m.n_kv * m.d_head) as f64) as usize;
                    let rc = tl.schedule(
                        Stream::Compute,
                        &[s],
                        cm.svd_reconstruct(b, sel_k * m.page_size, rank.max(16)),
                        "compute:reconstruct",
                    );
                    // blocking value-only recall (half the bytes, page-
                    // contiguous values, no per-head planes to merge).
                    let r = tl.schedule(
                        Stream::H2D,
                        &[s],
                        cm.recall_pages(sel_k * b, true) * 0.5,
                        "recall:shadowkv",
                    );
                    attn_deps = vec![rc, r];
                }
                Method::InfiniGen => {
                    // re-projection + token-wise selection for layer l+1,
                    // prefetch overlapped with this layer's compute; this
                    // layer's attention depends on the prefetch issued at
                    // layer l-1 (steady state: model as dependency on the
                    // previous layer's recall event).
                    let rp = tl.schedule(
                        Stream::Compute,
                        &[lin],
                        cm.reprojection(b, 0.3) + cm.token_selection(b, ctx, 0.3),
                        "selection:infinigen",
                    );
                    let miss_toks =
                        ((slots as f64 * knobs.infinigen_miss).ceil() as usize).max(1) * b;
                    let r = tl.schedule(
                        Stream::H2D,
                        &[rp],
                        cm.recall_tokens(miss_toks),
                        "recall:infinigen",
                    );
                    if let Some(prev) = spec_recall_done[layer] {
                        attn_deps.push(prev);
                    }
                    spec_recall_done[layer] = Some(r);
                }
                Method::FreeKv => {
                    // Pooled dispatch scores selection on an executor
                    // worker; the dependency edges (attention waits for
                    // correction recall, recall waits for selection) are
                    // identical — only compute-stream occupancy changes.
                    let sel_stream =
                        if knobs.pooled_selection { Stream::Exec } else { Stream::Compute };
                    if knobs.speculative {
                        // attention reuses the pages recalled during the
                        // previous step; only correction blocks.
                        if let Some(prev) = spec_recall_done[layer] {
                            attn_deps.push(prev);
                        }
                        let corrected = rng.f64() < knobs.correction_rate;
                        if corrected {
                            let heads =
                                (m.n_kv as f64 * knobs.corrected_frac).ceil().max(1.0);
                            let s = tl.schedule(
                                sel_stream,
                                &[lin],
                                cm.selection(b, ctx_pages),
                                "selection:freekv-correct",
                            );
                            let miss = ((sel_k as f64 * knobs.churn).ceil() as usize).max(1)
                                * b
                                * heads as usize;
                            // per-head recall: chunks proportional to heads
                            let frac = heads / m.n_kv as f64;
                            let r = tl.schedule(
                                Stream::H2D,
                                &[s],
                                cm.recall_pages(miss, knobs.hybrid_layout) * frac,
                                "recall:freekv-correct",
                            );
                            let conv_t = if knobs.double_buffer {
                                cm.convert_pages(1)
                            } else {
                                cm.convert_pages(miss)
                            };
                            let cv = tl.schedule(
                                Stream::Convert,
                                &[r],
                                conv_t,
                                "convert:freekv-correct",
                            );
                            attn_deps.push(cv);
                        }
                        // speculative select+recall for the NEXT step,
                        // overlapped with this layer's remaining compute.
                        let s = tl.schedule(
                            sel_stream,
                            &[lin],
                            cm.selection(b, ctx_pages),
                            "selection:freekv",
                        );
                        let miss_pages =
                            ((sel_k as f64 * knobs.churn).ceil() as usize).max(1) * b;
                        if knobs.overlap {
                            let r = tl.schedule(
                                Stream::H2D,
                                &[s],
                                cm.recall_pages(miss_pages, knobs.hybrid_layout),
                                "recall:freekv",
                            );
                            let conv = if knobs.double_buffer {
                                // pipelined: per-page conversion overlaps
                                // the next page's transfer; only the tail
                                // shows.
                                tl.schedule(
                                    Stream::Convert,
                                    &[r],
                                    cm.convert_pages(1),
                                    "convert:freekv",
                                )
                            } else {
                                // serialized on the copy stream.
                                tl.schedule(
                                    Stream::H2D,
                                    &[r],
                                    cm.convert_pages(miss_pages),
                                    "convert:freekv",
                                )
                            };
                            // Platforms with imperfect copy/compute
                            // overlap (Appendix D, Ascend) expose part of
                            // the side-stream work on the compute stream.
                            let eff = cm.dev.overlap_efficiency;
                            if eff < 1.0 {
                                let exposed = (cm.recall_pages(miss_pages, knobs.hybrid_layout)
                                    + cm.convert_pages(miss_pages))
                                    * (1.0 - eff);
                                let e = tl.schedule(
                                    Stream::Compute,
                                    &[lin],
                                    exposed,
                                    "recall:unoverlapped",
                                );
                                attn_deps.push(e);
                            }
                            spec_recall_done[layer] = Some(conv);
                        } else {
                            // Serial dispatch (the real engine's
                            // overlap=false ablation): the engine thread
                            // itself moves the pages after this layer's
                            // attention, so the recall starts once the
                            // attention finishes and gates the next
                            // compute op. Deferred below until the attn
                            // event exists.
                            serial_spec = Some((s, miss_pages));
                        }
                    } else {
                        // SR ablation off: blocking select + recall.
                        let s = tl.schedule(
                            sel_stream,
                            &[lin],
                            cm.selection(b, ctx_pages),
                            "selection:freekv",
                        );
                        let miss_pages =
                            ((sel_k as f64 * knobs.churn).ceil() as usize).max(1) * b;
                        let r = tl.schedule(
                            Stream::H2D,
                            &[s],
                            cm.recall_pages(miss_pages, knobs.hybrid_layout),
                            "recall:freekv",
                        );
                        // DB pipelines per-page conversion under the
                        // transfer stream; only the final page's
                        // conversion is exposed (Fig. 6 right).
                        let conv_t = if knobs.double_buffer {
                            cm.convert_pages(1)
                        } else {
                            cm.convert_pages(miss_pages)
                        };
                        let cv = tl.schedule(
                            if knobs.double_buffer { Stream::Convert } else { Stream::H2D },
                            &[r],
                            conv_t,
                            "convert:freekv",
                        );
                        attn_deps = vec![lin, cv];
                    }
                }
            }

            let attn = tl.schedule(
                Stream::Compute,
                &attn_deps,
                cm.attention(b, attn_slots),
                "compute:attn",
            );
            prev_compute = Some(attn);

            // serial-dispatch speculative recall: runs on the engine
            // thread after attention and gates the next compute op.
            if let Some((s, miss_pages)) = serial_spec.take() {
                let r = tl.schedule(
                    Stream::H2D,
                    &[s, attn],
                    cm.recall_pages(miss_pages, knobs.hybrid_layout),
                    "recall:freekv",
                );
                let conv_t = if knobs.double_buffer {
                    cm.convert_pages(1)
                } else {
                    cm.convert_pages(miss_pages)
                };
                let cv = tl.schedule(
                    if knobs.double_buffer { Stream::Convert } else { Stream::H2D },
                    &[r],
                    conv_t,
                    "convert:freekv",
                );
                serial_gate = Some(cv);
                spec_recall_done[layer] = Some(cv);
            }

            // offloading methods push completed pages out (overlapped).
            if method.offloads() && (ctx + 1) % m.page_size == 0 {
                tl.schedule(Stream::D2H, &[attn], cm.offload_page() * b as f64, "offload");
            }
        }
        let mut logits_deps: Vec<usize> = prev_compute.into_iter().collect();
        if let Some(g) = serial_gate.take() {
            logits_deps.push(g);
        }
        let _ = tl.schedule(Stream::Compute, &logits_deps, cm.logits(b), "compute:logits");
        let _ = step;
    }

    rec.steps = output_len;
    rec.decode_secs = tl.makespan();
    rec.compute_busy = tl.busy(Stream::Compute);
    rec.selection_busy = tl.busy_labeled("selection:");
    rec.recall_busy = tl.busy_labeled("recall:") + tl.busy_labeled("convert:");
    rec.recall_exposed = tl.exposed("recall:") + tl.exposed("convert:");
    // Selections scheduled on the compute stream overlap themselves, so
    // this is 0 unless pooled dispatch moved them to `Stream::Exec`.
    rec.selection_exposed = tl.exposed("selection:");
    rec.gpu_kv_bytes = gpu_kv_bytes(method, m, b, input_len + output_len, knobs);
    rec.oom = rec.gpu_kv_bytes + weight_bytes(m, cm.weight_elem_bytes) + knobs.runtime_reserve
        > knobs.gpu_mem_bytes;
    rec
}

/// Model N-lane microbatched decode (`knobs.decode_lanes`): the batch
/// splits into balanced lanes whose artifact execution runs on per-lane
/// executor streams (`Stream::Lane(i % exec_streams)`) while every
/// lane's host-side gather/bookkeeping serializes on the engine thread
/// (`Stream::Cpu`) — the modeled twin of `Engine::decode_step_lanes`.
/// With `decode_lanes == 1` the whole batch runs the classic
/// single-stream pipeline (compute and host work serialized), which is
/// the lane-sweep baseline. Selection/recall are omitted: this isolates
/// the lane-scheduling effect the real `--max-lanes` sweep measures.
pub fn simulate_lane_scaling(
    cm: &CostModel,
    b: usize,
    output_len: usize,
    knobs: &SimKnobs,
) -> RunRecord {
    let m = &cm.model;
    let lanes = knobs.decode_lanes.max(1).min(b.max(1));
    let streams = knobs.exec_streams.max(1);
    let slots = m.budget_slots();
    let lane_b = crate::util::balanced_widths(b, lanes);
    let lane_stream = |i: usize| {
        if lanes == 1 { Stream::Compute } else { Stream::Lane((i % streams) as u8) }
    };
    let mut tl = Timeline::new();
    let mut prev: Vec<Option<EventId>> = vec![None; lanes];
    for _step in 0..output_len {
        for _layer in 0..m.n_layers {
            for i in 0..lanes {
                let deps: Vec<EventId> = prev[i].into_iter().collect();
                let qkv =
                    tl.schedule(lane_stream(i), &deps, cm.layer_linear(lane_b[i]), "compute:qkv");
                // host-side gather serializes on the engine thread
                let host =
                    tl.schedule(Stream::Cpu, &[qkv], cm.gather(lane_b[i], slots), "host:gather");
                let attn =
                    tl.schedule(lane_stream(i), &[host], cm.attention(lane_b[i], slots), "compute:attn");
                prev[i] = Some(attn);
            }
        }
        for i in 0..lanes {
            let deps: Vec<EventId> = prev[i].into_iter().collect();
            prev[i] = Some(tl.schedule(lane_stream(i), &deps, cm.logits(lane_b[i]), "compute:logits"));
        }
    }
    let mut compute_busy = tl.busy(Stream::Compute);
    for s in 0..streams {
        compute_busy += tl.busy(Stream::Lane(s as u8));
    }
    RunRecord {
        method: format!("freekv-lanes{}", lanes),
        steps: output_len,
        decode_secs: tl.makespan(),
        compute_busy,
        ..Default::default()
    }
}

/// GPU memory for KV-related state per method (Table 1 row "GPU Mem").
pub fn gpu_kv_bytes(
    method: Method,
    m: &ModelConfig,
    b: usize,
    ctx: usize,
    knobs: &SimKnobs,
) -> f64 {
    let full = (m.n_layers * m.kv_bytes_per_layer(ctx) * b) as f64;
    let budget = (m.n_layers * m.kv_bytes_per_layer(m.budget_slots()) * b) as f64;
    match method {
        Method::Full | Method::Quest => full,
        Method::Razor => knobs.razor_rho * full + (1.0 - knobs.razor_rho) * budget,
        Method::Streaming | Method::RaaS | Method::ArkVale | Method::InfiniGen => budget,
        Method::ShadowKv => budget + knobs.shadowkv_rank_frac * full / 2.0,
        Method::FreeKv => budget,
    }
}

/// Modeled CPU-pool pages for `n_requests` whose prompts share a
/// `prefix_tokens`-token prefix and then diverge for `unique_tokens`
/// each — the shared-prefix memory model behind the rust engine's
/// copy-on-write page sharing (`kvcache::alloc`). Without sharing every
/// request stores its full context privately; with sharing the common
/// prefix's completed pages exist once process-wide and only the
/// per-request tails multiply. (A prefix page straddling the divergence
/// point is charged to the tails, matching the hash-chain keying: a
/// page is shareable only if *all* its tokens are common.)
pub fn shared_prefix_pool_pages(
    m: &ModelConfig,
    n_requests: usize,
    prefix_tokens: usize,
    unique_tokens: usize,
    sharing: bool,
) -> u64 {
    let p = m.page_size;
    let layers = m.n_layers as u64;
    let total = prefix_tokens + unique_tokens;
    if !sharing {
        return layers * (n_requests as u64) * (total / p) as u64;
    }
    let shared_pages = (prefix_tokens / p) as u64;
    let tail_pages = (total / p) as u64 - shared_pages;
    layers * (shared_pages + (n_requests as u64) * tail_pages)
}

/// Model weight bytes (for completeness of the OOM check).
pub fn weight_bytes(m: &ModelConfig, elem: usize) -> f64 {
    let per_layer = m.d_model * (m.n_qo + 2 * m.n_kv) * m.d_head
        + m.n_qo * m.d_head * m.d_model
        + 3 * m.d_model * m.d_ffn;
    ((m.n_layers * per_layer + 2 * m.vocab * m.d_model) * elem) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::sim::DeviceProfile;

    fn cm() -> CostModel {
        CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b())
    }

    fn run(method: Method, knobs: &SimKnobs) -> RunRecord {
        simulate_request(method, &cm(), 1, 4096, 64, knobs)
    }

    #[test]
    fn freekv_beats_blocking_retrieval() {
        let k = SimKnobs::default();
        let fk = run(Method::FreeKv, &k);
        let av = run(Method::ArkVale, &k);
        let sv = run(Method::ShadowKv, &k);
        let ig = run(Method::InfiniGen, &k);
        assert!(av.per_token() / fk.per_token() > 4.0, "arkvale/freekv {}", av.per_token() / fk.per_token());
        assert!(sv.per_token() > fk.per_token());
        assert!(ig.per_token() > fk.per_token());
        // ArkVale is the slowest of the retrieval baselines (Fig. 1/7).
        assert!(av.per_token() >= sv.per_token() && av.per_token() >= ig.per_token());
    }

    #[test]
    fn shared_prefix_memory_model_is_consistent() {
        let m = ModelConfig::llama31_8b(); // page 32, 32 layers
        // one request: sharing changes nothing
        assert_eq!(
            shared_prefix_pool_pages(&m, 1, 3200, 320, true),
            shared_prefix_pool_pages(&m, 1, 3200, 320, false)
        );
        // 8 requests, fully shared prompt, no unique tail: 8x savings
        let private = shared_prefix_pool_pages(&m, 8, 3200, 0, false);
        let shared = shared_prefix_pool_pages(&m, 8, 3200, 0, true);
        assert_eq!(private, 8 * shared);
        // with tails, shared is strictly between one copy and N copies
        let shared_t = shared_prefix_pool_pages(&m, 8, 3200, 320, true);
        let private_t = shared_prefix_pool_pages(&m, 8, 3200, 320, false);
        assert!(shared_t < private_t);
        assert!(shared_t > private_t / 8);
    }

    #[test]
    fn freekv_comparable_to_dropping() {
        let k = SimKnobs::default();
        let fk = run(Method::FreeKv, &k);
        let raas = run(Method::RaaS, &k);
        assert!(fk.per_token() < raas.per_token() * 2.0);
    }

    #[test]
    fn recall_mostly_hidden_for_freekv_exposed_for_arkvale() {
        let k = SimKnobs::default();
        let fk = run(Method::FreeKv, &k);
        let av = run(Method::ArkVale, &k);
        assert!(
            fk.recall_exposed < 0.25 * fk.recall_busy,
            "freekv exposed {} busy {}",
            fk.recall_exposed,
            fk.recall_busy
        );
        assert!(av.recall_exposed > 0.8 * av.recall_busy);
        // ArkVale: recall+selection dominate total latency (Fig. 1 right ~94%).
        let frac = (av.recall_exposed + av.selection_busy) / av.decode_secs;
        assert!(frac > 0.7, "arkvale recall+sel frac {}", frac);
    }

    #[test]
    fn int8_pages_cut_wire_time_not_compute() {
        use crate::kvcache::quant::KvDtype;
        let k = SimKnobs::default();
        let cm8 = CostModel::with_kv_dtype(
            DeviceProfile::a100_pcie4(),
            ModelConfig::llama31_8b(),
            KvDtype::Int8,
        );
        // The recall stream itself shrinks with the codec's wire bytes.
        let f = run(Method::FreeKv, &k);
        let q = simulate_request(Method::FreeKv, &cm8, 1, 4096, 64, &k);
        assert!(q.recall_busy < f.recall_busy, "int8 {} f32 {}", q.recall_busy, f.recall_busy);
        // For a blocking retriever the smaller wire shows up directly in
        // per-token latency...
        let av_f = run(Method::ArkVale, &k);
        let av_q = simulate_request(Method::ArkVale, &cm8, 1, 4096, 64, &k);
        assert!(av_q.per_token() < av_f.per_token());
        // ...while FreeKV already hides recall under compute, so its
        // per-token latency barely moves (GPU ops are dtype-independent).
        assert!(q.per_token() <= f.per_token());
        assert!(f.per_token() - q.per_token() < 0.1 * f.per_token());
    }

    #[test]
    fn serial_dispatch_exposes_recall_and_slows_decode() {
        // The modeled analog of the real engine's overlap ablation: with
        // serial dispatch the speculative recall gates the next layer's
        // compute, so it is (almost) fully exposed and per-token latency
        // grows; with overlap it hides under compute.
        let on = SimKnobs::default();
        let off = SimKnobs { overlap: false, ..Default::default() };
        let fk_on = run(Method::FreeKv, &on);
        let fk_off = run(Method::FreeKv, &off);
        assert!(
            fk_off.per_token() > fk_on.per_token(),
            "serial {} <= overlapped {}",
            fk_off.per_token(),
            fk_on.per_token()
        );
        assert!(
            fk_off.recall_exposed > 0.7 * fk_off.recall_busy,
            "serial dispatch should expose recall: exposed {} busy {}",
            fk_off.recall_exposed,
            fk_off.recall_busy
        );
        assert!(fk_on.recall_exposed < 0.25 * fk_on.recall_busy);
    }

    #[test]
    fn pooled_selection_dispatch_frees_the_compute_stream() {
        // Modeled analog of the executor pool: selection scoring moves
        // to Stream::Exec, so per-token latency can only improve, and
        // most of the selection time hides behind compute (only layers
        // where correction gates attention expose it).
        let serial = SimKnobs::default();
        let pooled = SimKnobs { pooled_selection: true, ..Default::default() };
        let fk_serial = run(Method::FreeKv, &serial);
        let fk_pooled = run(Method::FreeKv, &pooled);
        assert!(
            fk_pooled.per_token() <= fk_serial.per_token() * (1.0 + 1e-9),
            "pooled {} > serial {}",
            fk_pooled.per_token(),
            fk_serial.per_token()
        );
        assert!(
            fk_pooled.compute_busy < fk_serial.compute_busy,
            "selection left the compute stream: {} vs {}",
            fk_pooled.compute_busy,
            fk_serial.compute_busy
        );
        assert_eq!(fk_serial.selection_exposed, 0.0, "compute-stream selection self-overlaps");
        assert!(fk_pooled.selection_busy > 0.0);
        assert!(
            fk_pooled.selection_exposed < 0.5 * fk_pooled.selection_busy,
            "pooled selection mostly hidden: exposed {} busy {}",
            fk_pooled.selection_exposed,
            fk_pooled.selection_busy
        );
    }

    #[test]
    fn lane_scaling_overlaps_host_work_but_oversplitting_costs_weights() {
        // The modeled lane sweep: 2 lanes on 2 executor streams beat
        // the joint single-stream pipeline (one lane's host gather and
        // attention hide under the other's), but 4 lanes on the same 2
        // streams re-read the (batch-independent) weight bytes once per
        // lane and lose — exactly the over-splitting penalty the real
        // engine's bucket-aware planner exists to avoid.
        let cm = cm();
        let run = |lanes: usize| {
            let k = SimKnobs { decode_lanes: lanes, exec_streams: 2, ..Default::default() };
            simulate_lane_scaling(&cm, 8, 32, &k).per_token()
        };
        let (l1, l2, l4) = (run(1), run(2), run(4));
        assert!(l2 < l1, "2 lanes {} must beat joint {}", l2, l1);
        assert!(
            l4 > l2,
            "over-splitting (4 lanes, 2 streams) should pay weight re-reads: {} vs {}",
            l4,
            l2
        );
    }

    #[test]
    fn hybrid_layout_is_the_biggest_lever() {
        let on = SimKnobs::default();
        let off = SimKnobs { hybrid_layout: false, ..Default::default() };
        let fk_on = run(Method::FreeKv, &on);
        let fk_off = run(Method::FreeKv, &off);
        assert!(
            fk_off.per_token() / fk_on.per_token() > 2.0,
            "HL speedup {}",
            fk_off.per_token() / fk_on.per_token()
        );
    }

    #[test]
    fn quest_ooms_at_long_context_large_batch() {
        let k = SimKnobs::default();
        let m = ModelConfig::llama31_8b();
        // batch 4 x 32K context (paper: Quest OOMs here on 40 GB).
        let kv = gpu_kv_bytes(Method::Quest, &m, 4, 32768, &k);
        assert!(kv + weight_bytes(&m, 2) + k.runtime_reserve > k.gpu_mem_bytes);
        let fkv = gpu_kv_bytes(Method::FreeKv, &m, 4, 32768, &k);
        assert!(fkv + weight_bytes(&m, 2) + k.runtime_reserve < k.gpu_mem_bytes);
    }

    #[test]
    fn full_cache_attention_dominates_at_32k() {
        let k = SimKnobs::default();
        let full = simulate_request(Method::Full, &cm(), 1, 32768, 16, &k);
        let fk = simulate_request(Method::FreeKv, &cm(), 1, 32768, 16, &k);
        assert!(full.per_token() > fk.per_token());
    }

    #[test]
    fn ascend_gap_smaller_than_a100() {
        // Fig. 10: FreeKV speedup over ArkVale is ~4x on Ascend vs much
        // larger on A100.
        let k = SimKnobs::default();
        let a = cm();
        let n = CostModel::new(DeviceProfile::ascend_910b(), ModelConfig::llama31_8b());
        let a_ratio = simulate_request(Method::ArkVale, &a, 1, 4096, 32, &k).per_token()
            / simulate_request(Method::FreeKv, &a, 1, 4096, 32, &k).per_token();
        let n_ratio = simulate_request(Method::ArkVale, &n, 1, 4096, 32, &k).per_token()
            / simulate_request(Method::FreeKv, &n, 1, 4096, 32, &k).per_token();
        assert!(a_ratio > n_ratio * 1.2, "a100 {} ascend {}", a_ratio, n_ratio);
        assert!(n_ratio > 2.0, "ascend ratio still substantial: {}", n_ratio);
    }
}
