//! Retrieval/dropping policies: the FreeKV algorithm core shared with the
//! real engine, plus the per-method latency and accuracy simulators used
//! to regenerate the paper's tables and figures.

pub mod accuracy;
pub mod freekv;
pub mod latency;

pub use latency::{Method, RunRecord, SimKnobs};
