//! GPU-resident KV state for one (request, layer), split into the two
//! ownership halves the overlapped recall pipeline hands around:
//!
//! * [`GpuLayerCache`] — the **compute half**: sink + local-window slabs,
//!   the window ring, sequence length, and the incrementally-maintained
//!   min/max page summaries. This half never leaves the engine thread
//!   (selection and append need it every layer).
//! * [`SelectSlots`] — the **transfer half**: the per-kv-head selected
//!   page slab and its page table. Together with the CPU `LayerPool` it
//!   forms the `LayerXfer` bundle that can be checked out to the
//!   background recall worker while the engine computes other layers.
//!
//! Slot map (per the paper's budget decomposition B = S + W + selected):
//!   [0, SP)            sink pages (logical pages 0..SP, fixed)
//!   [SP, SP+WP)        local-window ring: page g at slot SP + g % WP
//!   [SP+WP, BP)        selected pages, tracked per kv head
//!
//! Both slabs are NHD `[slot][tok][head][d]`; sink/window slots hold the
//! same logical page for every head, selected slots hold head-specific
//! pages in each head's lane (selection is per-kv-head).
//!
//! Gather is **incremental**: every slot write (append, ring rotation,
//! selected-page install/evict) marks a dirty bit, and `gather_dirty`
//! rewrites only dirty slot regions of the caller's persistent
//! destination buffers, zero-filling the invalid tail of each region so
//! the result is bit-identical to a from-scratch `gather_full`.

/// A page whose last token was just written; ready for offload.
#[derive(Debug, Clone)]
pub struct CompletedPage {
    /// Logical page index within the sequence.
    pub page: usize,
    /// NHD token-major content `[tok][head][d]` — K then V.
    pub k_nhd: Vec<f32>,
    /// NHD token-major V content `[tok][head][d]`.
    pub v_nhd: Vec<f32>,
}

/// Compute half: sink + window slabs, ring, summaries, dirty bits.
#[derive(Debug)]
pub struct GpuLayerCache {
    /// KV heads.
    pub n_kv: usize,
    /// Per-head dimension.
    pub d: usize,
    /// Tokens per page.
    pub p: usize,
    /// Sink pages (slots `[0, sink_pages)`).
    pub sink_pages: usize,
    /// Local-window ring pages.
    pub window_pages: usize,
    /// Select-slot budget (pages recalled per step).
    pub select_pages: usize,
    /// Max logical pages of a full-context sequence (summary extent).
    pub n_pages_max: usize,
    /// NHD K/V slabs for the shared slots: `[sink+window][p][n_kv][d]`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// logical page held by each window-ring slot.
    ring_pages: Vec<Option<usize>>,
    /// tokens appended so far (absolute sequence length).
    pub len: usize,
    /// min page summaries `[head][page][d]` over post-RoPE keys.
    pub smin: Vec<f32>,
    /// max page summaries `[head][page][d]` over post-RoPE keys.
    pub smax: Vec<f32>,
    /// shared (all-head) slots written since the last incremental gather.
    dirty_shared: Vec<bool>,
}

impl GpuLayerCache {
    /// Empty compute-half cache with the given geometry and page budget.
    pub fn new(
        n_kv: usize,
        d: usize,
        p: usize,
        sink_pages: usize,
        window_pages: usize,
        select_pages: usize,
        n_pages_max: usize,
    ) -> GpuLayerCache {
        let sw = sink_pages + window_pages;
        GpuLayerCache {
            n_kv,
            d,
            p,
            sink_pages,
            window_pages,
            select_pages,
            n_pages_max,
            k: vec![0.0; sw * p * n_kv * d],
            v: vec![0.0; sw * p * n_kv * d],
            ring_pages: vec![None; window_pages],
            len: 0,
            smin: vec![f32::INFINITY; n_kv * n_pages_max * d],
            smax: vec![f32::NEG_INFINITY; n_kv * n_pages_max * d],
            dirty_shared: vec![false; sw],
        }
    }

    /// A matching (empty) transfer-half select slab.
    pub fn new_select_slots(&self) -> SelectSlots {
        SelectSlots::new(self.n_kv, self.d, self.p, self.select_pages)
    }

    /// Total page budget B = sink + window + select.
    pub fn budget_pages(&self) -> usize {
        self.sink_pages + self.window_pages + self.select_pages
    }

    /// Token slots the decode attention kernel sees (budget × page size).
    pub fn budget_slots(&self) -> usize {
        self.budget_pages() * self.p
    }

    /// Logical page currently being filled.
    pub fn cur_page(&self) -> usize {
        self.len / self.p
    }

    /// Bytes of GPU-resident state this half owns (slabs + summaries).
    pub fn gpu_bytes(&self) -> usize {
        (self.k.len() + self.v.len() + self.smin.len() + self.smax.len()) * 4
    }

    #[inline]
    fn nhd_off(&self, slot: usize, tok: usize, head: usize) -> usize {
        ((slot * self.p + tok) * self.n_kv + head) * self.d
    }

    /// Append the new token's K/V (`[head][d]` flattened, post-RoPE).
    /// Returns the page content when this token completes a page.
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32]) -> Option<CompletedPage> {
        let (m, d, p) = (self.n_kv, self.d, self.p);
        assert_eq!(k_new.len(), m * d);
        let pos = self.len;
        let g = pos / p;
        let tok = pos % p;
        assert!(g < self.n_pages_max, "context overflow: page {}", g);
        let slot = if g < self.sink_pages {
            g
        } else {
            // claim the ring slot at page start
            if tok == 0 || self.ring_pages[g % self.window_pages] != Some(g) {
                self.ring_pages[g % self.window_pages] = Some(g);
            }
            self.sink_pages + g % self.window_pages
        };
        self.dirty_shared[slot] = true;
        for head in 0..m {
            let o = self.nhd_off(slot, tok, head);
            self.k[o..o + d].copy_from_slice(&k_new[head * d..(head + 1) * d]);
            self.v[o..o + d].copy_from_slice(&v_new[head * d..(head + 1) * d]);
            // incremental min/max summary
            let so = (head * self.n_pages_max + g) * d;
            for dim in 0..d {
                let x = k_new[head * d + dim];
                if x < self.smin[so + dim] {
                    self.smin[so + dim] = x;
                }
                if x > self.smax[so + dim] {
                    self.smax[so + dim] = x;
                }
            }
        }
        self.len += 1;
        if tok == p - 1 {
            Some(self.extract_page(slot, g))
        } else {
            None
        }
    }

    fn extract_page(&self, slot: usize, page: usize) -> CompletedPage {
        let (m, d, p) = (self.n_kv, self.d, self.p);
        let mut k_nhd = vec![0.0; p * m * d];
        let mut v_nhd = vec![0.0; p * m * d];
        for tok in 0..p {
            for head in 0..m {
                let src = self.nhd_off(slot, tok, head);
                let dst = (tok * m + head) * d;
                k_nhd[dst..dst + d].copy_from_slice(&self.k[src..src + d]);
                v_nhd[dst..dst + d].copy_from_slice(&self.v[src..src + d]);
            }
        }
        CompletedPage { page, k_nhd, v_nhd }
    }

    /// Bulk-load prefill output: K/V `[head][T][d]` (the layer_prefill
    /// artifact's HND-ish output, possibly padded to `stride` >= t).
    /// Fills sink + window slots and the summaries; returns the completed
    /// pages for the caller to offload to the CPU pool.
    pub fn load_prefill(&mut self, k: &[f32], v: &[f32], t: usize, stride: usize) -> Vec<CompletedPage> {
        let (m, d) = (self.n_kv, self.d);
        assert!(stride >= t);
        assert_eq!(k.len(), m * stride * d);
        self.len = 0;
        let mut completed = Vec::new();
        for pos in 0..t {
            // reuse append for slot/summary management (O(T*m*d), fine at
            // prefill granularity; the artifact did the heavy math).
            let mut kn = vec![0.0; m * d];
            let mut vn = vec![0.0; m * d];
            for head in 0..m {
                let src = (head * stride + pos) * d;
                kn[head * d..(head + 1) * d].copy_from_slice(&k[src..src + d]);
                vn[head * d..(head + 1) * d].copy_from_slice(&v[src..src + d]);
            }
            if let Some(cp) = self.append(&kn, &vn) {
                completed.push(cp);
            }
        }
        completed
    }

    /// Pages eligible for selection: complete, offloaded, not sink, not in
    /// the window ring. Returned as the 0/1 mask the select artifact takes.
    pub fn selectable_mask(&self) -> Vec<f32> {
        let mut mask = vec![0.0f32; self.n_pages_max];
        self.selectable_mask_into(&mut mask);
        mask
    }

    /// Allocation-free variant writing into a caller slice of len
    /// `n_pages_max` (the per-step selection scratch reuses one buffer).
    pub fn selectable_mask_into(&self, mask: &mut [f32]) {
        assert_eq!(mask.len(), self.n_pages_max);
        mask.iter_mut().for_each(|x| *x = 0.0);
        let cur = self.cur_page();
        let horizon = cur.saturating_sub(self.window_pages);
        for m in mask.iter_mut().take(horizon).skip(self.sink_pages) {
            *m = 1.0;
        }
        // Exclude any page still held by the ring (can happen right after
        // prefill when T is not page-aligned).
        for rp in self.ring_pages.iter().flatten() {
            if *rp < self.n_pages_max {
                mask[*rp] = 0.0;
            }
        }
    }

    /// Number of selectable pages.
    pub fn selectable_count(&self) -> usize {
        self.selectable_mask().iter().filter(|&&x| x > 0.0).count()
    }

    /// Gather the attention operands: K/V `[head][S][d]` and the validity
    /// mask `[head][S]`, with S = budget_slots. Slot order per head:
    /// sink, window ring, then that head's selected slots. Writes every
    /// slot region (zero-filling invalid tails), so the destination need
    /// not be pre-zeroed. Clears all dirty bits.
    pub fn gather_full(
        &mut self,
        sel: &mut SelectSlots,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        dst_valid: &mut [f32],
    ) {
        self.gather_impl(sel, dst_k, dst_v, dst_valid, false);
    }

    /// Incremental gather: rewrite only the slot regions dirtied since the
    /// last gather into the caller's *persistent* buffers. Equivalent to
    /// `gather_full` when the buffers have been maintained by this method
    /// since creation (zero-initialized).
    pub fn gather_dirty(
        &mut self,
        sel: &mut SelectSlots,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        dst_valid: &mut [f32],
    ) {
        self.gather_impl(sel, dst_k, dst_v, dst_valid, true);
    }

    fn gather_impl(
        &mut self,
        sel: &mut SelectSlots,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
        dst_valid: &mut [f32],
        only_dirty: bool,
    ) {
        let (m, d, p) = (self.n_kv, self.d, self.p);
        let s = self.budget_slots();
        assert_eq!(dst_k.len(), m * s * d);
        assert_eq!(dst_v.len(), m * s * d);
        assert_eq!(dst_valid.len(), m * s);
        assert_eq!(sel.n_kv, m);
        assert_eq!(sel.select_pages, self.select_pages);
        let sw = self.sink_pages + self.window_pages;
        let bp = self.budget_pages();
        for head in 0..m {
            for slot in 0..bp {
                // which logical page does this slot hold for this head?
                let (page, per_head, dirty) = if slot < self.sink_pages {
                    (Some(slot), false, self.dirty_shared[slot])
                } else if slot < sw {
                    (self.ring_pages[slot - self.sink_pages], false, self.dirty_shared[slot])
                } else {
                    let j = slot - sw;
                    (sel.select_table[head][j], true, sel.dirty[head * sel.select_pages + j])
                };
                if only_dirty && !dirty {
                    continue;
                }
                // Tokens of the slot's page that are real; ring slots of a
                // partially-written page expose only the written prefix.
                let valid_toks = match page {
                    None => 0,
                    Some(_) if per_head => p, // only complete pages are selectable
                    Some(g) => self.len.saturating_sub(g * p).min(p),
                };
                for tok in 0..p {
                    let dst = (head * s + slot * p + tok) * d;
                    if tok < valid_toks {
                        let src = if per_head {
                            sel.nhd_off(slot - sw, tok, head)
                        } else {
                            self.nhd_off(slot, tok, head)
                        };
                        let (sk, sv) = if per_head {
                            (&sel.k[src..src + d], &sel.v[src..src + d])
                        } else {
                            (&self.k[src..src + d], &self.v[src..src + d])
                        };
                        dst_k[dst..dst + d].copy_from_slice(sk);
                        dst_v[dst..dst + d].copy_from_slice(sv);
                        dst_valid[head * s + slot * p + tok] = 1.0;
                    } else {
                        dst_k[dst..dst + d].iter_mut().for_each(|x| *x = 0.0);
                        dst_v[dst..dst + d].iter_mut().for_each(|x| *x = 0.0);
                        dst_valid[head * s + slot * p + tok] = 0.0;
                    }
                }
            }
        }
        self.dirty_shared.iter_mut().for_each(|x| *x = false);
        sel.dirty.iter_mut().for_each(|x| *x = false);
    }

    /// Summary planes in the `[head][page][d]` order the select artifact
    /// expects; untouched pages are +/-inf which the mask suppresses.
    pub fn summaries(&self) -> (&[f32], &[f32]) {
        (&self.smin, &self.smax)
    }

    /// Sanitized summaries with untouched pages zeroed (artifact inputs
    /// must be finite: 0 * masked-out is fine, inf * 0 is NaN).
    pub fn summaries_sanitized(&self) -> (Vec<f32>, Vec<f32>) {
        let fix = |xs: &[f32]| xs.iter().map(|&x| if x.is_finite() { x } else { 0.0 }).collect();
        (fix(&self.smin), fix(&self.smax))
    }

    /// Allocation-free sanitize into caller slices (per-step selection
    /// scratch): same values as `summaries_sanitized`.
    pub fn summaries_sanitized_into(&self, lo: &mut [f32], hi: &mut [f32]) {
        assert_eq!(lo.len(), self.smin.len());
        assert_eq!(hi.len(), self.smax.len());
        for (dst, &x) in lo.iter_mut().zip(&self.smin) {
            *dst = if x.is_finite() { x } else { 0.0 };
        }
        for (dst, &x) in hi.iter_mut().zip(&self.smax) {
            *dst = if x.is_finite() { x } else { 0.0 };
        }
    }
}

/// Transfer half: the per-kv-head selected-page slab and page table.
/// Owned by the engine between steps; checked out (inside a `LayerXfer`)
/// to the background recall worker while speculative recall runs.
#[derive(Debug)]
pub struct SelectSlots {
    /// KV heads.
    pub n_kv: usize,
    /// Per-head dimension.
    pub d: usize,
    /// Tokens per page.
    pub p: usize,
    /// Select slots per head.
    pub select_pages: usize,
    /// NHD K/V slabs for the select slots: `[select_pages][p][n_kv][d]`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// selected logical page per (kv head, select slot).
    select_table: Vec<Vec<Option<usize>>>,
    /// per (head, slot) dirty bits for incremental gather.
    dirty: Vec<bool>,
}

impl SelectSlots {
    /// Empty select slab: no pages installed, all slots clean.
    pub fn new(n_kv: usize, d: usize, p: usize, select_pages: usize) -> SelectSlots {
        SelectSlots {
            n_kv,
            d,
            p,
            select_pages,
            k: vec![0.0; select_pages * p * n_kv * d],
            v: vec![0.0; select_pages * p * n_kv * d],
            select_table: vec![vec![None; select_pages]; n_kv],
            dirty: vec![false; n_kv * select_pages],
        }
    }

    #[inline]
    fn nhd_off(&self, slot_j: usize, tok: usize, head: usize) -> usize {
        ((slot_j * self.p + tok) * self.n_kv + head) * self.d
    }

    /// Bytes of GPU-resident state this half owns (K + V slabs).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Current selected pages for a head.
    pub fn selected(&self, head: usize) -> &[Option<usize>] {
        &self.select_table[head]
    }

    /// Install a recalled page into a select slot of one head. `k_head` /
    /// `v_head` are `[tok][d]` for that head (post layout conversion).
    pub fn install(
        &mut self,
        head: usize,
        slot_j: usize,
        page: usize,
        k_head: &[f32],
        v_head: &[f32],
    ) {
        let (d, p) = (self.d, self.p);
        assert_eq!(k_head.len(), p * d);
        for tok in 0..p {
            let o = self.nhd_off(slot_j, tok, head);
            self.k[o..o + d].copy_from_slice(&k_head[tok * d..(tok + 1) * d]);
            self.v[o..o + d].copy_from_slice(&v_head[tok * d..(tok + 1) * d]);
        }
        self.select_table[head][slot_j] = Some(page);
        self.dirty[head * self.select_pages + slot_j] = true;
    }

    /// Diff a new selection against the resident set: returns
    /// (slot assignments to fill, pages already resident). Evicts
    /// non-reselected pages. This is the page-cache behaviour that makes
    /// speculative recall cheap when consecutive selections overlap.
    pub fn plan_selection(&mut self, head: usize, pages: &[usize]) -> Vec<(usize, usize)> {
        let sp = self.select_pages;
        let table = &mut self.select_table[head];
        let keep: Vec<bool> = table
            .iter()
            .map(|slot| slot.map_or(false, |pg| pages.contains(&pg)))
            .collect();
        let mut to_fill: Vec<(usize, usize)> = Vec::new();
        let mut free: Vec<usize> = (0..table.len()).filter(|&j| !keep[j]).collect();
        for &pg in pages {
            if table.iter().any(|s| *s == Some(pg)) {
                continue;
            }
            if let Some(j) = free.pop() {
                table[j] = None; // evicted; filled by install
                self.dirty[head * sp + j] = true;
                to_fill.push((j, pg));
            }
        }
        to_fill
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cache() -> GpuLayerCache {
        // n_kv=2, d=4, p=4, sink=1, window=2, select=2, pages_max=16
        GpuLayerCache::new(2, 4, 4, 1, 2, 2, 16)
    }

    fn tok(rng: &mut Rng, m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        (
            (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            (0..m * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        )
    }

    #[test]
    fn append_completes_pages() {
        let mut c = cache();
        let mut rng = Rng::new(1);
        let mut completed = Vec::new();
        for i in 0..12 {
            let (k, v) = tok(&mut rng, 2, 4);
            if let Some(cp) = c.append(&k, &v) {
                completed.push((i, cp.page));
            }
        }
        assert_eq!(completed, vec![(3, 0), (7, 1), (11, 2)]);
        assert_eq!(c.len, 12);
        assert_eq!(c.cur_page(), 3);
    }

    #[test]
    fn selectable_mask_excludes_sink_and_window() {
        let mut c = cache();
        let mut rng = Rng::new(2);
        // write 6 pages (24 tokens): cur_page = 6
        for _ in 0..24 {
            let (k, v) = tok(&mut rng, 2, 4);
            c.append(&k, &v);
        }
        let mask = c.selectable_mask();
        // sink page 0 excluded; window covers pages 5,6(current);
        // horizon = 6 - 2 = 4 -> selectable 1,2,3
        assert_eq!(&mask[0..5], &[0.0, 1.0, 1.0, 1.0, 0.0]);
        assert!(mask[5..].iter().all(|&x| x == 0.0));
        assert_eq!(c.selectable_count(), 3);
    }

    #[test]
    fn gather_marks_partial_page_validity() {
        let mut c = cache();
        let mut sel = c.new_select_slots();
        let mut rng = Rng::new(3);
        for _ in 0..6 {
            // 1.5 pages
            let (k, v) = tok(&mut rng, 2, 4);
            c.append(&k, &v);
        }
        let s = c.budget_slots();
        let mut gk = vec![0.0; 2 * s * 4];
        let mut gv = vec![0.0; 2 * s * 4];
        let mut valid = vec![0.0; 2 * s];
        c.gather_full(&mut sel, &mut gk, &mut gv, &mut valid);
        for head in 0..2 {
            let v_head = &valid[head * s..(head + 1) * s];
            // sink slot 0: page 0 complete -> 4 valid
            assert_eq!(&v_head[0..4], &[1.0; 4]);
            // ring: page 1 at slot sink+1%2=2? page1 slot = 1 + 1%2 = 2 -> toks 4..6 written, 2 valid
            let ring1 = &v_head[2 * 4..2 * 4 + 4];
            assert_eq!(ring1, &[1.0, 1.0, 0.0, 0.0]);
            // select slots empty
            assert!(v_head[3 * 4..].iter().all(|&x| x == 0.0));
        }
        let total: f32 = valid.iter().sum();
        assert_eq!(total, 2.0 * 6.0); // every appended token visible once
    }

    #[test]
    fn gather_never_duplicates_tokens() {
        // After many pages, each valid token position must appear exactly
        // once per head (no sink/ring/select overlap).
        let mut c = cache();
        let mut sel = c.new_select_slots();
        let mut rng = Rng::new(4);
        for _ in 0..40 {
            let (k, v) = tok(&mut rng, 2, 4);
            c.append(&k, &v);
        }
        // install selected pages = 2 oldest selectable
        let mask = c.selectable_mask();
        let pages: Vec<usize> =
            mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).take(2).collect();
        for head in 0..2 {
            let fills = sel.plan_selection(head, &pages);
            for (j, pg) in fills {
                let kd = vec![pg as f32; 16];
                let vd = vec![-(pg as f32); 16];
                sel.install(head, j, pg, &kd, &vd);
            }
        }
        let s = c.budget_slots();
        let mut gk = vec![0.0; 2 * s * 4];
        let mut gv = vec![0.0; 2 * s * 4];
        let mut valid = vec![0.0; 2 * s];
        c.gather_full(&mut sel, &mut gk, &mut gv, &mut valid);
        // count valid tokens: sink 4 + ring full page 4 + partial 0 (len=40
        // = page 10 boundary; ring holds pages 8,9 -> 8 toks) + select 8
        let per_head: f32 = valid[0..s].iter().sum();
        assert_eq!(per_head, 4.0 + 8.0 + 8.0);
    }

    #[test]
    fn plan_selection_reuses_resident_pages() {
        let mut sel = SelectSlots::new(2, 4, 4, 2);
        let fills = sel.plan_selection(0, &[1, 2]);
        assert_eq!(fills.len(), 2);
        for (j, pg) in &fills {
            sel.install(0, *j, *pg, &vec![0.0; 16], &vec![0.0; 16]);
        }
        // Re-selecting {2, 3}: page 2 resident -> only 3 transfers.
        let fills2 = sel.plan_selection(0, &[2, 3]);
        assert_eq!(fills2.len(), 1);
        assert_eq!(fills2[0].1, 3);
        // Page 1's slot was freed.
        assert!(sel.selected(0).iter().any(|s| *s == Some(2)));
        assert!(!sel.selected(0).iter().any(|s| *s == Some(1)));
    }

    #[test]
    fn summaries_bracket_appended_keys() {
        let mut c = cache();
        let mut rng = Rng::new(6);
        let mut keys: Vec<Vec<f32>> = Vec::new();
        for _ in 0..8 {
            let (k, v) = tok(&mut rng, 2, 4);
            keys.push(k.clone());
            c.append(&k, &v);
        }
        let (smin, smax) = c.summaries();
        for head in 0..2 {
            for (pos, k) in keys.iter().enumerate() {
                let g = pos / 4;
                let so = (head * 16 + g) * 4;
                for dim in 0..4 {
                    let x = k[head * 4 + dim];
                    assert!(smin[so + dim] <= x + 1e-6);
                    assert!(smax[so + dim] >= x - 1e-6);
                }
            }
        }
        let (fmin, fmax) = c.summaries_sanitized();
        assert!(fmin.iter().chain(fmax.iter()).all(|x| x.is_finite()));
        // the _into variant must agree exactly
        let mut lo = vec![1.0f32; fmin.len()];
        let mut hi = vec![1.0f32; fmax.len()];
        c.summaries_sanitized_into(&mut lo, &mut hi);
        assert_eq!(lo, fmin);
        assert_eq!(hi, fmax);
    }

    #[test]
    fn load_prefill_equivalent_to_appends() {
        let mut rng = Rng::new(7);
        let (m, d, t) = (2, 4, 10);
        let k: Vec<f32> = (0..m * t * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..m * t * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut a = cache();
        let mut sel_a = a.new_select_slots();
        let completed = a.load_prefill(&k, &v, t, t);
        assert_eq!(completed.len(), t / 4);
        let mut b = cache();
        let mut sel_b = b.new_select_slots();
        for pos in 0..t {
            let mut kn = vec![0.0; m * d];
            let mut vn = vec![0.0; m * d];
            for head in 0..m {
                let src = (head * t + pos) * d;
                kn[head * d..(head + 1) * d].copy_from_slice(&k[src..src + d]);
                vn[head * d..(head + 1) * d].copy_from_slice(&v[src..src + d]);
            }
            b.append(&kn, &vn);
        }
        assert_eq!(a.len, b.len);
        let s = a.budget_slots();
        let (mut ka, mut va, mut ma) = (vec![0.0; m * s * d], vec![0.0; m * s * d], vec![0.0; m * s]);
        let (mut kb, mut vb, mut mb) = (ka.clone(), va.clone(), ma.clone());
        a.gather_full(&mut sel_a, &mut ka, &mut va, &mut ma);
        b.gather_full(&mut sel_b, &mut kb, &mut vb, &mut mb);
        assert_eq!(ka, kb);
        assert_eq!(ma, mb);
    }

    #[test]
    fn gather_dirty_matches_full_rebuild() {
        // Maintain one destination incrementally across a random schedule
        // of appends and select installs; a from-scratch gather into a
        // fresh buffer must agree bit-for-bit after every round.
        let mut c = cache();
        let mut sel = c.new_select_slots();
        let mut rng = Rng::new(8);
        let (m, d, s) = (2usize, 4usize, cache().budget_slots());
        let mut ik = vec![0.0f32; m * s * d];
        let mut iv = ik.clone();
        let mut ivalid = vec![0.0f32; m * s];
        for round in 0..30 {
            // a few appends
            for _ in 0..1 + rng.below(5) {
                if c.len + 1 >= 16 * 4 {
                    break;
                }
                let (k, v) = tok(&mut rng, m, d);
                c.append(&k, &v);
            }
            // occasionally install a fresh selection
            if round % 3 == 0 {
                let mask = c.selectable_mask();
                let mut cands: Vec<usize> =
                    mask.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(g, _)| g).collect();
                rng.shuffle(&mut cands);
                let take = cands.len().min(1 + rng.below(2));
                for head in 0..m {
                    let fills = sel.plan_selection(head, &cands[..take]);
                    for (j, pg) in fills {
                        let kd: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        let vd: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        sel.install(head, j, pg, &kd, &vd);
                    }
                }
            }
            c.gather_dirty(&mut sel, &mut ik, &mut iv, &mut ivalid);
            let mut fk = vec![0.0f32; m * s * d];
            let mut fv = fk.clone();
            let mut fvalid = vec![0.0f32; m * s];
            c.gather_full(&mut sel, &mut fk, &mut fv, &mut fvalid);
            assert_eq!(ik, fk, "round {} k diverged", round);
            assert_eq!(iv, fv, "round {} v diverged", round);
            assert_eq!(ivalid, fvalid, "round {} validity diverged", round);
        }
    }
}
