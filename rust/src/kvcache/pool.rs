//! CPU-side KV page pool (the offload target) — a *view* over the
//! shared page allocator (`kvcache::alloc`).
//!
//! The paper's hybrid-layout design (§4.2): FreeKV keeps the *CPU* pool
//! in HND layout, `(n_kv, 2, p, d)` per page, so recalling one page for
//! one kv head moves a single contiguous `2*p*d` chunk; the mainstream
//! NHD layout `([K|V], p, n_kv, d)` per page fragments the same recall
//! into `2*p` chunks of `d` elements. Both layouts are implemented so
//! the ablation (Fig. 9) and the baselines can run on their native
//! layout. The layout governs element order *within* a page; pages
//! themselves are refcounted slots handed out by the allocator, so
//! memory scales with pages actually offloaded (not `max_context`),
//! identical prompt prefixes can alias one physical page across
//! requests, and everything frees when the last view drops.

use std::cell::RefCell;
use std::sync::Arc;

use crate::kvcache::alloc::{PageAllocator, Slot};
use crate::kvcache::quant::{bf16_bits_to_f32, KvDtype, PageCodec};

/// Bounded seqlock retries for [`LayerPool::copy_chunks`]: a reader
/// holds a refcount on the slot it snapshots, so the only legal
/// concurrent mutations are this request's own CoW/rewrite races —
/// unbounded churn means the refcount protocol is already broken, and
/// the loop panics instead of spinning forever.
const SNAPSHOT_RETRIES: usize = 64;

thread_local! {
    /// Per-thread scratch for the copy-outside-critical-section paths:
    /// staged f32 page + encoded payload + scale sidecar. Reused across
    /// calls so the hot offload/gather loops allocate nothing.
    static PAGE_SCRATCH: RefCell<(Vec<f32>, Vec<u8>, Vec<u16>)> =
        RefCell::new((Vec::new(), Vec::new(), Vec::new()));
}

/// Memory organization of a page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// `([K|V], p, n_kv, d)` per page — natural projection output.
    Nhd,
    /// `(n_kv, [K|V], p, d)` per page — FreeKV's CPU layout.
    Hnd,
}

/// One layer's pool view: logical pages in [0, n_pages) mapped to
/// allocator slots on demand.
pub struct LayerPool {
    /// Page memory layout (NHD or HND).
    pub layout: Layout,
    /// Logical pages this view addresses.
    pub n_pages: usize,
    /// KV heads per page.
    pub n_kv: usize,
    /// Tokens per page.
    pub p: usize,
    /// Per-head dimension.
    pub d: usize,
    /// Page codec (dtype + geometry) of the backing allocator: encode
    /// on `write_page*`, decode in `copy_chunks` / `read_page_head`.
    codec: PageCodec,
    alloc: Arc<PageAllocator>,
    layer: usize,
    /// logical page -> allocator slot (None = never offloaded).
    table: Vec<Option<Slot>>,
    /// occupied table entries, maintained incrementally so byte
    /// accounting is O(1) on the per-step checkout path.
    held: usize,
}

impl std::fmt::Debug for LayerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LayerPool")
            .field("layout", &self.layout)
            .field("n_pages", &self.n_pages)
            .field("held_pages", &self.held_pages())
            .finish()
    }
}

/// A contiguous source range within one page (offsets are
/// page-relative; pair with the page id for [`LayerPool::copy_chunks`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Chunk {
    /// First token slot within the page.
    pub offset: usize,
    /// Number of token slots.
    pub len: usize,
}

impl LayerPool {
    /// Standalone pool backed by its own private, unbounded allocator
    /// (tests, benches, single-request tools). Serving stacks share one
    /// allocator across requests via [`LayerPool::with_alloc`].
    pub fn new(layout: Layout, n_pages: usize, n_kv: usize, p: usize, d: usize) -> LayerPool {
        LayerPool::new_dtype(layout, n_pages, n_kv, p, d, KvDtype::F32)
    }

    /// Standalone pool with an explicit page codec dtype.
    pub fn new_dtype(
        layout: Layout,
        n_pages: usize,
        n_kv: usize,
        p: usize,
        d: usize,
        dtype: KvDtype,
    ) -> LayerPool {
        let alloc = PageAllocator::with_dtype(1, n_kv, p, d, 0, false, 0, dtype);
        LayerPool::with_alloc(layout, n_pages, n_kv, p, d, alloc, 0)
    }

    /// View over `layer` of a shared allocator.
    pub fn with_alloc(
        layout: Layout,
        n_pages: usize,
        n_kv: usize,
        p: usize,
        d: usize,
        alloc: Arc<PageAllocator>,
        layer: usize,
    ) -> LayerPool {
        assert_eq!(
            alloc.page_elems,
            n_kv * 2 * p * d,
            "allocator geometry does not match the pool view"
        );
        assert!(layer < alloc.n_layers, "layer {} outside allocator", layer);
        let codec = alloc.codec();
        LayerPool {
            layout,
            n_pages,
            n_kv,
            p,
            d,
            codec,
            alloc,
            layer,
            table: vec![None; n_pages],
            held: 0,
        }
    }

    /// Element dtype of this pool's pages.
    pub fn dtype(&self) -> KvDtype {
        self.codec.dtype
    }

    /// Encoded payload bytes covering `elems` logical f32 elements —
    /// the wire size of a chunk transfer out of this pool.
    pub fn encoded_bytes(&self, elems: usize) -> usize {
        self.codec.encoded_len(elems)
    }

    /// Encoded bytes of one whole page, scale sidecar included.
    pub fn page_encoded_bytes(&self) -> usize {
        self.codec.page_bytes()
    }

    /// Scale-sidecar bytes that ride along when one head's K+V regions
    /// move (0 for F32, two 2-byte scales otherwise).
    pub fn head_scale_bytes(&self) -> usize {
        if self.codec.dtype == KvDtype::F32 {
            0
        } else {
            2 * 2
        }
    }

    /// Logical pages currently holding a slot reference.
    pub fn held_pages(&self) -> usize {
        debug_assert_eq!(self.held, self.table.iter().flatten().count());
        self.held
    }

    /// Bytes of pool pages this view references. Shared pages count
    /// fully for each holder here; the process-wide figure (shared
    /// counted once) is `PageAllocator::stats().cpu_bytes_used`.
    pub fn bytes(&self) -> usize {
        self.held_pages() * self.alloc.page_bytes()
    }

    /// Whether logical `page` maps to a slot whose payload has been
    /// committed (written once and immutable) — e.g. an adopted or
    /// CoW-shared prefix page the request never needs to offload again.
    pub fn is_written(&self, page: usize) -> bool {
        self.table[page].map_or(false, |s| self.alloc.slot_written(self.layer, s))
    }

    /// Flat page-relative offset of element (head, plane 0=K/1=V, tok, dim).
    #[inline]
    fn off(&self, head: usize, plane: usize, tok: usize, dim: usize) -> usize {
        match self.layout {
            Layout::Hnd => ((head * 2 + plane) * self.p + tok) * self.d + dim,
            Layout::Nhd => {
                // two NHD planes per page: K then V, each (p, n_kv, d)
                plane * self.p * self.n_kv * self.d + (tok * self.n_kv + head) * self.d + dim
            }
        }
    }

    /// A slot this view may write: allocates on first touch, and
    /// copy-on-writes a page that is aliased by another view (a shared
    /// page is never mutated in place).
    fn ensure_private_slot(&mut self, page: usize) -> Slot {
        match self.table[page] {
            Some(s) => {
                let fresh = self.alloc.make_unique(self.layer, s);
                self.table[page] = Some(fresh);
                fresh
            }
            None => {
                let s = self.alloc.alloc_slot(self.layer);
                self.table[page] = Some(s);
                self.held += 1;
                s
            }
        }
    }

    /// Store one page given K/V in NHD token-major order
    /// (`k[tok][head][dim]` flattened) — exactly what the GPU cache
    /// holds. For HND this performs the offload-time transpose the
    /// paper amortizes here rather than on the per-step decode path.
    pub fn write_page(&mut self, page: usize, k_nhd: &[f32], v_nhd: &[f32]) {
        self.write_page_keyed(page, k_nhd, v_nhd, None);
    }

    /// `write_page` plus a prefix-cache registration: a later request
    /// offloading a page with the same token-prefix hash aliases this
    /// one instead of writing a duplicate ([`LayerPool::try_adopt`]).
    pub fn write_page_keyed(
        &mut self,
        page: usize,
        k_nhd: &[f32],
        v_nhd: &[f32],
        key: Option<u128>,
    ) {
        let (p, m, d) = (self.p, self.n_kv, self.d);
        assert_eq!(k_nhd.len(), p * m * d);
        assert_eq!(v_nhd.len(), p * m * d);
        // Stage the page in layout element order and encode it
        // (quantize-on-offload) entirely *outside* the allocator
        // locks; the critical section is then one memcpy of the
        // encoded bytes. The transpose here is the offload-time HND
        // transpose the paper amortizes off the decode path.
        let codec = self.codec;
        let layout = self.layout;
        let slot = PAGE_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (staged, payload, scales) = &mut *scratch;
            staged.clear();
            staged.resize(codec.page_elems(), 0.0);
            for tok in 0..p {
                for head in 0..m {
                    let src = (tok * m + head) * d;
                    let ko = self.off(head, 0, tok, 0);
                    staged[ko..ko + d].copy_from_slice(&k_nhd[src..src + d]);
                    let vo = self.off(head, 1, tok, 0);
                    staged[vo..vo + d].copy_from_slice(&v_nhd[src..src + d]);
                }
            }
            codec.encode_page(layout, staged, payload, scales);
            let slot = self.ensure_private_slot(page);
            self.alloc.write_slot_encoded(self.layer, slot, payload, scales);
            slot
        });
        self.alloc.set_written(self.layer, slot);
        if let Some(h) = key {
            self.alloc.register_prefix(self.layer, self.layout, h, slot);
        }
    }

    /// Try to satisfy an offload by aliasing a resident page committed
    /// under the same prefix key (refcounted; no bytes move). Returns
    /// whether the adoption happened.
    pub fn try_adopt(&mut self, page: usize, key: u128) -> bool {
        match self.alloc.adopt(self.layer, self.layout, key) {
            Some(slot) => {
                match self.table[page].replace(slot) {
                    Some(old) => self.alloc.release_slot(self.layer, old),
                    None => self.held += 1,
                }
                true
            }
            None => false,
        }
    }

    /// Install a slot that [`PageAllocator::adopt_stack`] already
    /// refcounted for this view — the longest-common-prefix adoption
    /// path, where the whole cross-layer page was claimed atomically
    /// and each layer's view just records its slot. The logical page
    /// must be untouched (LCP adoption happens before any offload).
    pub(crate) fn install_adopted(&mut self, page: usize, slot: Slot) {
        assert!(
            self.table[page].is_none(),
            "LCP-adopting into page {} which already holds a slot",
            page
        );
        self.table[page] = Some(slot);
        self.held += 1;
    }

    /// Contiguous chunks to move one (page, head) pair — the layout-
    /// dependent transfer plan whose chunk count drives recall cost.
    /// Offsets are relative to the page ([`LayerPool::copy_chunks`]).
    pub fn recall_chunks(&self, _page: usize, head: usize) -> Vec<Chunk> {
        match self.layout {
            Layout::Hnd => {
                // K and V adjacent: one chunk of 2*p*d.
                vec![Chunk { offset: self.off(head, 0, 0, 0), len: 2 * self.p * self.d }]
            }
            Layout::Nhd => {
                // p chunks of d per plane.
                let mut out = Vec::with_capacity(2 * self.p);
                for plane in 0..2 {
                    for tok in 0..self.p {
                        out.push(Chunk { offset: self.off(head, plane, tok, 0), len: self.d });
                    }
                }
                out
            }
        }
    }

    /// Stream `chunks` of `page` into `dst` back to back (the transfer
    /// engine's "DMA" read). The encoded bytes are *snapshotted* under
    /// the shard lock and decoded with no lock held; a seqlock-style
    /// generation re-check detects a concurrent mutation of the slot
    /// (a CoW `make_unique` recycling it, a rewrite) and retries the
    /// snapshot. Returns the elements copied.
    pub fn copy_chunks(&self, page: usize, chunks: &[Chunk], dst: &mut [f32]) -> usize {
        let slot = self.table[page].expect("reading a page that was never offloaded");
        let codec = self.codec;
        let layout = self.layout;
        // Byte-range plan, one range per chunk. INT4 packs two elements
        // per byte, so a chunk's range snaps out to the enclosing byte
        // (nibble-pair) boundary; `base` is the first element the
        // snapshotted range covers, giving the relative element index
        // used to address the snapshot (parity-preserving: `base` is
        // even whenever it matters).
        let mut plan = Vec::with_capacity(chunks.len()); // (base elem, snapshot byte start)
        let mut ranges = Vec::with_capacity(chunks.len());
        let mut snap_bytes = 0usize;
        for c in chunks {
            let base = if codec.dtype == KvDtype::Int4 { c.offset & !1 } else { c.offset };
            let byte_off = codec.encoded_len(base);
            let byte_len = codec.encoded_len(c.offset + c.len) - byte_off;
            plan.push((base, snap_bytes));
            ranges.push((byte_off, byte_len));
            snap_bytes += byte_len;
        }
        PAGE_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (_, payload, scales) = &mut *scratch;
            for attempt in 0..=SNAPSHOT_RETRIES {
                let gen =
                    self.alloc.snapshot_slot_ranges(self.layer, slot, &ranges, payload, scales);
                let mut off = 0usize;
                for (c, &(base, snap_start)) in chunks.iter().zip(&plan) {
                    let buf = &payload[snap_start..];
                    // Chunk offsets/lens are logical f32 elements.
                    // Decode in scale-homogeneous runs: a chunk may
                    // span regions (an HND head chunk covers its K and
                    // V regions).
                    let mut e = c.offset;
                    let end = c.offset + c.len;
                    while e < end {
                        let run = codec.region_run_len(layout, e).min(end - e);
                        let scale = match codec.dtype {
                            KvDtype::F32 => 1.0,
                            _ => bf16_bits_to_f32(scales[codec.region_of(layout, e)]),
                        };
                        codec.decode_run(buf, e - base, run, scale, &mut dst[off..off + run]);
                        off += run;
                        e += run;
                    }
                }
                if self.alloc.slot_generation(self.layer, slot) == gen {
                    return off;
                }
                assert!(
                    attempt < SNAPSHOT_RETRIES,
                    "KV slot {} (layer {}) mutated concurrently through {} snapshot retries — \
                     refcount protocol violated",
                    slot,
                    self.layer,
                    SNAPSHOT_RETRIES
                );
            }
            unreachable!()
        })
    }

    /// Read one (page, head) pair back into NHD-slot order
    /// (`[tok][dim]` for K then V), independent of layout — used by
    /// tests and by the recall fallback path.
    pub fn read_page_head(&self, page: usize, head: usize) -> (Vec<f32>, Vec<f32>) {
        let (p, d) = (self.p, self.d);
        let slot = self.table[page].expect("reading a page that was never offloaded");
        let codec = self.codec;
        let mut k = vec![0.0; p * d];
        let mut v = vec![0.0; p * d];
        self.alloc.read_slot(self.layer, slot, |buf, scales| {
            let scale_of = |region: usize| match codec.dtype {
                KvDtype::F32 => 1.0,
                _ => bf16_bits_to_f32(scales[region]),
            };
            let (ks, vs) = (scale_of(head * 2), scale_of(head * 2 + 1));
            for tok in 0..p {
                let ko = self.off(head, 0, tok, 0);
                codec.decode_run(buf, ko, d, ks, &mut k[tok * d..(tok + 1) * d]);
                let vo = self.off(head, 1, tok, 0);
                codec.decode_run(buf, vo, d, vs, &mut v[tok * d..(tok + 1) * d]);
            }
        });
        (k, v)
    }
}

impl Drop for LayerPool {
    fn drop(&mut self) {
        for slot in self.table.iter().flatten() {
            self.alloc.release_slot(self.layer, *slot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn roundtrip_both_layouts() {
        let mut rng = Rng::new(1);
        let (pages, m, p, d) = (4, 2, 8, 16);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        for layout in [Layout::Nhd, Layout::Hnd] {
            let mut pool = LayerPool::new(layout, pages, m, p, d);
            pool.write_page(2, &k, &v);
            assert!(pool.is_written(2) && !pool.is_written(1));
            for head in 0..m {
                let (kr, vr) = pool.read_page_head(2, head);
                for tok in 0..p {
                    for dim in 0..d {
                        let src = (tok * m + head) * d + dim;
                        assert_eq!(kr[tok * d + dim], k[src]);
                        assert_eq!(vr[tok * d + dim], v[src]);
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_counts_match_paper() {
        let (pages, m, p, d) = (4, 2, 32, 128);
        let hnd = LayerPool::new(Layout::Hnd, pages, m, p, d);
        let nhd = LayerPool::new(Layout::Nhd, pages, m, p, d);
        // HND: 1 chunk of 2*p*d = 8192 elems (32 KB f32 / 8 KB fp16 in paper).
        let hc = hnd.recall_chunks(0, 1);
        assert_eq!(hc.len(), 1);
        assert_eq!(hc[0].len, 2 * p * d);
        // NHD: 2*p chunks of d elems (256 B fp16 in paper).
        let nc = nhd.recall_chunks(0, 1);
        assert_eq!(nc.len(), 2 * p);
        assert!(nc.iter().all(|c| c.len == d));
    }

    #[test]
    fn hnd_chunks_are_truly_contiguous_per_head() {
        let mut rng = Rng::new(2);
        let (pages, m, p, d) = (2, 3, 4, 8);
        let mut pool = LayerPool::new(Layout::Hnd, pages, m, p, d);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        pool.write_page(1, &k, &v);
        for head in 0..m {
            let chunks = pool.recall_chunks(1, head);
            assert_eq!(chunks.len(), 1, "HND is one contiguous chunk per head");
            let mut s = vec![0.0f32; chunks[0].len];
            pool.copy_chunks(1, &chunks, &mut s);
            // First p*d elems = K tokens in order, next p*d = V.
            for tok in 0..p {
                for dim in 0..d {
                    assert_eq!(s[tok * d + dim], k[(tok * m + head) * d + dim]);
                    assert_eq!(s[p * d + tok * d + dim], v[(tok * m + head) * d + dim]);
                }
            }
        }
    }

    #[test]
    fn chunks_cover_disjoint_ranges() {
        let nhd = LayerPool::new(Layout::Nhd, 2, 2, 4, 8);
        let mut seen: Vec<(usize, usize)> = Vec::new();
        for head in 0..2 {
            for c in nhd.recall_chunks(0, head) {
                for &(o, l) in &seen {
                    assert!(c.offset + c.len <= o || o + l <= c.offset, "overlap");
                }
                seen.push((c.offset, c.len));
            }
        }
    }

    #[test]
    fn pool_grows_on_demand_and_frees_on_drop() {
        let alloc = PageAllocator::new(1, 2, 4, 8, 0, false, 0);
        {
            let mut pool = LayerPool::with_alloc(Layout::Hnd, 64, 2, 4, 8, alloc.clone(), 0);
            assert_eq!(pool.bytes(), 0, "no up-front reservation");
            let page = vec![0.5f32; 4 * 2 * 8];
            pool.write_page(0, &page, &page);
            pool.write_page(5, &page, &page);
            assert_eq!(pool.held_pages(), 2);
            assert_eq!(alloc.stats().pages_used, 2, "only written pages are allocated");
            assert_eq!(pool.bytes(), 2 * alloc.page_bytes());
        }
        assert_eq!(alloc.stats().pages_used, 0, "drop released every slot");
    }

    #[test]
    fn adopted_page_is_shared_then_cow_materializes_privately() {
        let alloc = PageAllocator::new(1, 2, 4, 8, 0, true, 1);
        let (m, p, d) = (2usize, 4usize, 8usize);
        let mut rng = Rng::new(3);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        let mut a = LayerPool::with_alloc(Layout::Hnd, 8, m, p, d, alloc.clone(), 0);
        let mut b = LayerPool::with_alloc(Layout::Hnd, 8, m, p, d, alloc.clone(), 0);
        a.write_page_keyed(0, &k, &v, Some(77));
        assert!(b.try_adopt(0, 77), "same-key offload aliases the resident page");
        assert!(b.is_written(0));
        assert_eq!(alloc.stats().pages_used, 1, "one physical page for two views");
        assert_eq!(alloc.stats().pages_shared, 1);
        assert_eq!(b.read_page_head(0, 1), a.read_page_head(0, 1));
        // CoW: rewriting through one view must not touch the other's data
        let k2 = fill(&mut rng, p * m * d);
        let v2 = fill(&mut rng, p * m * d);
        b.write_page(0, &k2, &v2);
        assert_eq!(alloc.stats().pages_used, 2);
        assert_eq!(alloc.stats().pages_shared, 0);
        let (ka, _) = a.read_page_head(0, 0);
        for tok in 0..p {
            for dim in 0..d {
                assert_eq!(ka[tok * d + dim], k[(tok * m) * d + dim], "shared page mutated");
            }
        }
        // a key that nobody registered does not adopt
        assert!(!b.try_adopt(1, 999));
    }

    /// One scale per (head, plane) region: dequantized values stay
    /// within half a quantization step of the originals, under both
    /// layouts and through both read paths (chunks + page head).
    #[test]
    fn quantized_roundtrip_stays_within_error_bound() {
        let mut rng = Rng::new(17);
        let (pages, m, p, d) = (4, 3, 8, 16);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        for (dtype, qmax) in [(KvDtype::Int8, 127.0f32), (KvDtype::Int4, 7.0)] {
            for layout in [Layout::Nhd, Layout::Hnd] {
                let mut pool = LayerPool::new_dtype(layout, pages, m, p, d, dtype);
                pool.write_page(2, &k, &v);
                let max_abs = k
                    .iter()
                    .chain(v.iter())
                    .fold(0.0f32, |a, &x| a.max(x.abs()));
                let bound = max_abs / qmax * 0.51 + max_abs / 256.0;
                for head in 0..m {
                    let (kr, vr) = pool.read_page_head(2, head);
                    for tok in 0..p {
                        for dim in 0..d {
                            let src = (tok * m + head) * d + dim;
                            assert!(
                                (kr[tok * d + dim] - k[src]).abs() <= bound,
                                "{:?} {:?} K: {} vs {}",
                                dtype,
                                layout,
                                kr[tok * d + dim],
                                k[src]
                            );
                            assert!((vr[tok * d + dim] - v[src]).abs() <= bound);
                        }
                    }
                    // copy_chunks decodes to the same values
                    let chunks = pool.recall_chunks(2, head);
                    let n: usize = chunks.iter().map(|c| c.len).sum();
                    let mut s = vec![0.0f32; n];
                    pool.copy_chunks(2, &chunks, &mut s);
                    let (sk, sv) = s.split_at(p * d);
                    assert_eq!(sk, &kr[..], "{:?} {:?}", dtype, layout);
                    assert_eq!(sv, &vr[..]);
                }
            }
        }
    }

    /// Writing the same data twice decodes identically — quantization
    /// is deterministic, so prefix-shared quantized pages are exact
    /// replicas of what a private write would have produced.
    #[test]
    fn quantization_is_deterministic_across_pools() {
        let mut rng = Rng::new(23);
        let (m, p, d) = (2, 4, 8);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        for dtype in KvDtype::all() {
            let mut a = LayerPool::new_dtype(Layout::Hnd, 2, m, p, d, dtype);
            let mut b = LayerPool::new_dtype(Layout::Hnd, 2, m, p, d, dtype);
            a.write_page(0, &k, &v);
            b.write_page(0, &k, &v);
            for head in 0..m {
                assert_eq!(a.read_page_head(0, head), b.read_page_head(0, head), "{:?}", dtype);
            }
        }
    }

    #[test]
    fn pool_bytes_shrink_with_the_codec() {
        let (m, p, d) = (2, 4, 8);
        let page = vec![0.5f32; p * m * d];
        let mut sizes = Vec::new();
        for dtype in KvDtype::all() {
            let mut pool = LayerPool::new_dtype(Layout::Hnd, 4, m, p, d, dtype);
            pool.write_page(0, &page, &page);
            assert_eq!(pool.bytes(), pool.page_encoded_bytes());
            assert_eq!(pool.encoded_bytes(d), (d as f64 * dtype.bytes_per_elem()) as usize);
            sizes.push(pool.bytes());
        }
        assert!(sizes[1] * 100 <= sizes[0] * 30, "int8 page <= 30% of f32: {:?}", sizes);
        assert!(sizes[2] < sizes[1], "int4 < int8: {:?}", sizes);
    }
}
