//! CPU-side KV page pool (the offload target).
//!
//! The paper's hybrid-layout design (§4.2): FreeKV keeps the *CPU* pool in
//! HND layout, `(n_page, n_kv, 2, p, d)`, so recalling one page for one kv
//! head moves a single contiguous `2*p*d` chunk; the mainstream NHD layout
//! `(n_page, p, n_kv, d)` fragments the same recall into `2*p` chunks of
//! `d` elements. Both layouts are implemented so the ablation (Fig. 9) and
//! the baselines can run on their native layout.

/// Memory organization of the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// `(page, p, n_kv, d)` per K/V plane — natural projection output.
    Nhd,
    /// `(page, n_kv, [K|V], p, d)` — FreeKV's CPU layout.
    Hnd,
}

/// One layer's pool. Pages are dense in [0, n_pages).
#[derive(Debug)]
pub struct LayerPool {
    pub layout: Layout,
    pub n_pages: usize,
    pub n_kv: usize,
    pub p: usize,
    pub d: usize,
    /// K and V for NHD (two planes); single slab for HND.
    data: Vec<f32>,
    /// per-page write flag.
    written: Vec<bool>,
}

/// A contiguous source range within the pool (for chunked transfer).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Chunk {
    pub offset: usize,
    pub len: usize,
}

impl LayerPool {
    pub fn new(layout: Layout, n_pages: usize, n_kv: usize, p: usize, d: usize) -> LayerPool {
        LayerPool {
            layout,
            n_pages,
            n_kv,
            p,
            d,
            data: vec![0.0; n_pages * n_kv * 2 * p * d],
            written: vec![false; n_pages],
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn is_written(&self, page: usize) -> bool {
        self.written[page]
    }

    /// Flat offset of element (page, head, plane 0=K/1=V, tok, dim).
    #[inline]
    fn off(&self, page: usize, head: usize, plane: usize, tok: usize, dim: usize) -> usize {
        match self.layout {
            Layout::Hnd => {
                (((page * self.n_kv + head) * 2 + plane) * self.p + tok) * self.d + dim
            }
            Layout::Nhd => {
                // two NHD planes: K then V, each (page, p, n_kv, d)
                let plane_size = self.n_pages * self.p * self.n_kv * self.d;
                plane * plane_size + ((page * self.p + tok) * self.n_kv + head) * self.d + dim
            }
        }
    }

    /// Store one page given K/V in NHD token-major order
    /// (`k[tok][head][dim]` flattened) — exactly what the GPU cache holds.
    /// For HND this performs the offload-time transpose the paper
    /// amortizes here rather than on the per-step decode path.
    pub fn write_page(&mut self, page: usize, k_nhd: &[f32], v_nhd: &[f32]) {
        let (p, m, d) = (self.p, self.n_kv, self.d);
        assert_eq!(k_nhd.len(), p * m * d);
        assert_eq!(v_nhd.len(), p * m * d);
        for tok in 0..p {
            for head in 0..m {
                let src = (tok * m + head) * d;
                let ko = self.off(page, head, 0, tok, 0);
                self.data[ko..ko + d].copy_from_slice(&k_nhd[src..src + d]);
                let vo = self.off(page, head, 1, tok, 0);
                self.data[vo..vo + d].copy_from_slice(&v_nhd[src..src + d]);
            }
        }
        self.written[page] = true;
    }

    /// Contiguous chunks to move one (page, head) pair — the layout-
    /// dependent transfer plan whose chunk count drives recall cost.
    pub fn recall_chunks(&self, page: usize, head: usize) -> Vec<Chunk> {
        match self.layout {
            Layout::Hnd => {
                // K and V adjacent: one chunk of 2*p*d.
                vec![Chunk { offset: self.off(page, head, 0, 0, 0), len: 2 * self.p * self.d }]
            }
            Layout::Nhd => {
                // p chunks of d per plane.
                let mut out = Vec::with_capacity(2 * self.p);
                for plane in 0..2 {
                    for tok in 0..self.p {
                        out.push(Chunk {
                            offset: self.off(page, head, plane, tok, 0),
                            len: self.d,
                        });
                    }
                }
                out
            }
        }
    }

    /// Raw read access for the transfer engine.
    pub fn slice(&self, chunk: Chunk) -> &[f32] {
        &self.data[chunk.offset..chunk.offset + chunk.len]
    }

    /// Read one (page, head) pair back into NHD-slot order
    /// (`[tok][dim]` for K then V), independent of layout — used by tests
    /// and by the recall fallback path.
    pub fn read_page_head(&self, page: usize, head: usize) -> (Vec<f32>, Vec<f32>) {
        let (p, d) = (self.p, self.d);
        let mut k = vec![0.0; p * d];
        let mut v = vec![0.0; p * d];
        for tok in 0..p {
            let ko = self.off(page, head, 0, tok, 0);
            k[tok * d..(tok + 1) * d].copy_from_slice(&self.data[ko..ko + d]);
            let vo = self.off(page, head, 1, tok, 0);
            v[tok * d..(tok + 1) * d].copy_from_slice(&self.data[vo..vo + d]);
        }
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn roundtrip_both_layouts() {
        let mut rng = Rng::new(1);
        let (pages, m, p, d) = (4, 2, 8, 16);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        for layout in [Layout::Nhd, Layout::Hnd] {
            let mut pool = LayerPool::new(layout, pages, m, p, d);
            pool.write_page(2, &k, &v);
            assert!(pool.is_written(2) && !pool.is_written(1));
            for head in 0..m {
                let (kr, vr) = pool.read_page_head(2, head);
                for tok in 0..p {
                    for dim in 0..d {
                        let src = (tok * m + head) * d + dim;
                        assert_eq!(kr[tok * d + dim], k[src]);
                        assert_eq!(vr[tok * d + dim], v[src]);
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_counts_match_paper() {
        let (pages, m, p, d) = (4, 2, 32, 128);
        let hnd = LayerPool::new(Layout::Hnd, pages, m, p, d);
        let nhd = LayerPool::new(Layout::Nhd, pages, m, p, d);
        // HND: 1 chunk of 2*p*d = 8192 elems (32 KB f32 / 8 KB fp16 in paper).
        let hc = hnd.recall_chunks(0, 1);
        assert_eq!(hc.len(), 1);
        assert_eq!(hc[0].len, 2 * p * d);
        // NHD: 2*p chunks of d elems (256 B fp16 in paper).
        let nc = nhd.recall_chunks(0, 1);
        assert_eq!(nc.len(), 2 * p);
        assert!(nc.iter().all(|c| c.len == d));
    }

    #[test]
    fn hnd_chunks_are_truly_contiguous_per_head() {
        let mut rng = Rng::new(2);
        let (pages, m, p, d) = (2, 3, 4, 8);
        let mut pool = LayerPool::new(Layout::Hnd, pages, m, p, d);
        let k = fill(&mut rng, p * m * d);
        let v = fill(&mut rng, p * m * d);
        pool.write_page(1, &k, &v);
        for head in 0..m {
            let c = pool.recall_chunks(1, head)[0];
            let s = pool.slice(c);
            // First p*d elems = K tokens in order, next p*d = V.
            for tok in 0..p {
                for dim in 0..d {
                    assert_eq!(s[tok * d + dim], k[(tok * m + head) * d + dim]);
                    assert_eq!(s[p * d + tok * d + dim], v[(tok * m + head) * d + dim]);
                }
            }
        }
    }

    #[test]
    fn chunks_cover_disjoint_ranges() {
        let nhd = LayerPool::new(Layout::Nhd, 2, 2, 4, 8);
        let mut seen: Vec<(usize, usize)> = Vec::new();
        for head in 0..2 {
            for c in nhd.recall_chunks(0, head) {
                for &(o, l) in &seen {
                    assert!(c.offset + c.len <= o || o + l <= c.offset, "overlap");
                }
                seen.push((c.offset, c.len));
            }
        }
    }
}
