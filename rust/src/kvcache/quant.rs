//! KV page codecs: quantized page payloads for the shared slab.
//!
//! The paged allocator stores every page through a [`PageCodec`]
//! instead of a hardcoded f32 stride. Three codecs:
//!
//! * **F32** — 4 bytes/elem, no scales, bit-exact (the default; every
//!   pre-existing exhibit runs on it unchanged).
//! * **Int8Sym** — symmetric per-(head, plane) INT8: one scale per
//!   (kv head, K|V plane) region of a page, `q = round(x / s)` clamped
//!   to ±127. 1 byte/elem + a 2-byte scale per region.
//! * **Int4Packed** — symmetric INT4 packed two elements per byte,
//!   `q = round(x / s)` clamped to ±7, stored biased (`q + 8`) in a
//!   nibble. 0.5 bytes/elem + a 2-byte scale per region.
//!
//! Scales live in a sidecar slab next to the payload (`kvcache::alloc`)
//! as bf16 bit patterns (upper 16 bits of the f32, round-to-nearest).
//! Quantization uses the *roundtripped* scale, so encode and decode
//! agree exactly and the error bound `|x - dq(q(x))| <= s/2` holds with
//! the stored scale `s`.
//!
//! Quantize-on-offload, dequantize-on-gather: only the CPU pool and the
//! transfers touching it are encoded. The GPU-resident sink + local
//! window (and the select slabs the recall installs into) stay full
//! precision — the near-lossless design point from the KV-cache
//! quantization literature (see ROADMAP / PAPERS 2407.18003, 2412.19442).

use crate::kvcache::pool::Layout;

/// Per-pool element dtype knob, selected alongside HND/NHD.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvDtype {
    /// Full precision, bit-exact (default).
    #[default]
    F32,
    /// Symmetric INT8, per-(head, plane) scales.
    Int8,
    /// Packed INT4 (two elems/byte), per-(head, plane) scales.
    Int4,
}

impl KvDtype {
    /// Canonical lowercase name (CLI / report key).
    pub fn as_str(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
            KvDtype::Int4 => "int4",
        }
    }

    /// Parse a CLI dtype name (`f32`/`fp32`, `int8`/`i8`, `int4`/`i4`).
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s {
            "f32" | "fp32" => Some(KvDtype::F32),
            "int8" | "i8" => Some(KvDtype::Int8),
            "int4" | "i4" => Some(KvDtype::Int4),
            _ => None,
        }
    }

    /// All dtypes, in ablation-sweep order.
    pub fn all() -> [KvDtype; 3] {
        [KvDtype::F32, KvDtype::Int8, KvDtype::Int4]
    }

    /// Payload bytes per element on the wire / in the slab.
    pub fn bytes_per_elem(&self) -> f64 {
        match self {
            KvDtype::F32 => 4.0,
            KvDtype::Int8 => 1.0,
            KvDtype::Int4 => 0.5,
        }
    }

    /// Largest representable quantized magnitude (0 for F32).
    fn qmax(&self) -> f32 {
        match self {
            KvDtype::F32 => 0.0,
            KvDtype::Int8 => 127.0,
            KvDtype::Int4 => 7.0,
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Round an f32 to the nearest bf16 bit pattern (ties to even).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let round = 0x7fff + ((b >> 16) & 1);
    ((b.wrapping_add(round)) >> 16) as u16
}

/// Expand a bf16 bit pattern back to f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Page geometry + dtype: everything needed to size and transcode one
/// page of the slab. Cheap `Copy`; derived once per allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageCodec {
    /// Element dtype of the slab payload.
    pub dtype: KvDtype,
    /// KV heads per page.
    pub n_kv: usize,
    /// Tokens per page.
    pub page_size: usize,
    /// Per-head dimension.
    pub d_head: usize,
}

impl PageCodec {
    /// Codec for the given dtype and page geometry.
    pub fn new(dtype: KvDtype, n_kv: usize, page_size: usize, d_head: usize) -> PageCodec {
        PageCodec { dtype, n_kv, page_size, d_head }
    }

    /// Logical f32 elements of one page (all kv heads, K+V planes).
    pub fn page_elems(&self) -> usize {
        self.n_kv * 2 * self.page_size * self.d_head
    }

    /// Encoded payload bytes covering `elems` logical elements.
    pub fn encoded_len(&self, elems: usize) -> usize {
        match self.dtype {
            KvDtype::F32 => elems * 4,
            KvDtype::Int8 => elems,
            KvDtype::Int4 => elems.div_ceil(2),
        }
    }

    /// Encoded payload bytes of one whole page.
    pub fn payload_bytes(&self) -> usize {
        self.encoded_len(self.page_elems())
    }

    /// Scale-sidecar entries per page: one per (kv head, plane) region
    /// for the quantized codecs, none for F32.
    pub fn scales_per_page(&self) -> usize {
        match self.dtype {
            KvDtype::F32 => 0,
            _ => 2 * self.n_kv,
        }
    }

    /// Total slab bytes of one page: payload + 2-byte scale sidecar.
    pub fn page_bytes(&self) -> usize {
        self.payload_bytes() + self.scales_per_page() * 2
    }

    /// Scale-region index of element `e` under `layout`: always
    /// `head * 2 + plane`, independent of layout, so a page re-encoded
    /// under the other layout carries the same scales.
    #[inline]
    pub fn region_of(&self, layout: Layout, e: usize) -> usize {
        let (p, m, d) = (self.page_size, self.n_kv, self.d_head);
        match layout {
            Layout::Hnd => e / (p * d),
            Layout::Nhd => {
                let plane = e / (p * m * d);
                let head = (e / d) % m;
                head * 2 + plane
            }
        }
    }

    /// Elements from `e` (inclusive) to the next region boundary.
    #[inline]
    pub fn region_run_len(&self, layout: Layout, e: usize) -> usize {
        let (p, d) = (self.page_size, self.d_head);
        match layout {
            Layout::Hnd => p * d - e % (p * d),
            Layout::Nhd => d - e % d,
        }
    }

    /// Quantization scale for a region with max magnitude `max_abs`,
    /// roundtripped through the bf16 sidecar representation so encode
    /// and decode use the identical value. Returns `(scale, bits)`.
    pub fn scale_for(&self, max_abs: f32) -> (f32, u16) {
        let raw = if max_abs > 0.0 { max_abs / self.dtype.qmax().max(1.0) } else { 1.0 };
        let bits = f32_to_bf16_bits(raw);
        (bf16_bits_to_f32(bits), bits)
    }

    /// Encode `src` into `payload` starting at logical element `e0`,
    /// using `scale` (ignored for F32).
    pub fn encode_run(&self, src: &[f32], payload: &mut [u8], e0: usize, scale: f32) {
        match self.dtype {
            KvDtype::F32 => {
                for (i, &x) in src.iter().enumerate() {
                    payload[(e0 + i) * 4..(e0 + i) * 4 + 4].copy_from_slice(&x.to_le_bytes());
                }
            }
            KvDtype::Int8 => {
                let inv = 1.0 / scale;
                for (i, &x) in src.iter().enumerate() {
                    payload[e0 + i] = (x * inv).round().clamp(-127.0, 127.0) as i8 as u8;
                }
            }
            KvDtype::Int4 => {
                let inv = 1.0 / scale;
                for (i, &x) in src.iter().enumerate() {
                    let q = ((x * inv).round().clamp(-7.0, 7.0) as i32 + 8) as u8;
                    let e = e0 + i;
                    let b = &mut payload[e / 2];
                    if e % 2 == 0 {
                        *b = (*b & 0xf0) | q;
                    } else {
                        *b = (*b & 0x0f) | (q << 4);
                    }
                }
            }
        }
    }

    /// Decode `len` elements starting at logical element `e0` of
    /// `payload` into `dst`, using `scale` (ignored for F32).
    pub fn decode_run(&self, payload: &[u8], e0: usize, len: usize, scale: f32, dst: &mut [f32]) {
        match self.dtype {
            KvDtype::F32 => {
                for (i, slot) in dst.iter_mut().enumerate().take(len) {
                    let o = (e0 + i) * 4;
                    *slot = f32::from_le_bytes(payload[o..o + 4].try_into().unwrap());
                }
            }
            KvDtype::Int8 => {
                for (i, slot) in dst.iter_mut().enumerate().take(len) {
                    *slot = payload[e0 + i] as i8 as f32 * scale;
                }
            }
            KvDtype::Int4 => {
                for (i, slot) in dst.iter_mut().enumerate().take(len) {
                    let e = e0 + i;
                    let b = payload[e / 2];
                    let q = if e % 2 == 0 { b & 0x0f } else { b >> 4 };
                    *slot = (q as i32 - 8) as f32 * scale;
                }
            }
        }
    }

    /// Encode one whole staged page (`page_elems` f32s in `layout`
    /// element order) into an encoded payload + scale sidecar, both
    /// caller-owned scratch. This is the lock-free half of the offload
    /// path: the pool view stages and encodes outside any allocator
    /// lock, then installs the bytes with one memcpy under the shard
    /// lock (`PageAllocator::write_slot_encoded`).
    pub fn encode_page(
        &self,
        layout: Layout,
        staged: &[f32],
        payload: &mut Vec<u8>,
        scales: &mut Vec<u16>,
    ) {
        debug_assert_eq!(staged.len(), self.page_elems());
        payload.resize(self.payload_bytes(), 0);
        scales.resize(self.scales_per_page(), 0);
        if self.dtype == KvDtype::F32 {
            self.encode_run(staged, payload, 0, 1.0);
            return;
        }
        // Pass 1: per-region max magnitude (region = (head, plane)).
        let mut max_abs = vec![0.0f32; self.scales_per_page()];
        let mut e = 0;
        while e < staged.len() {
            let run = self.region_run_len(layout, e);
            let r = self.region_of(layout, e);
            let m = staged[e..e + run].iter().fold(max_abs[r], |a, &x| a.max(x.abs()));
            max_abs[r] = m;
            e += run;
        }
        let mut region_scale = vec![1.0f32; max_abs.len()];
        for (r, &m) in max_abs.iter().enumerate() {
            let (s, bits) = self.scale_for(m);
            region_scale[r] = s;
            scales[r] = bits;
        }
        // Pass 2: quantize each region run with its stored scale.
        let mut e = 0;
        while e < staged.len() {
            let run = self.region_run_len(layout, e);
            let r = self.region_of(layout, e);
            self.encode_run(&staged[e..e + run], payload, e, region_scale[r]);
            e += run;
        }
    }
}

/// Roundtrip a whole f32 slice through the codec with one shared
/// symmetric scale — the analytic counterpart of storing it in a
/// quantized page region. Identity for F32. Used by the accuracy
/// dtype-ablation exhibit to inject the codec's error into oracle
/// traces (which carry scores, not raw K/V).
pub fn roundtrip_f32s(dtype: KvDtype, xs: &[f32]) -> Vec<f32> {
    if dtype == KvDtype::F32 || xs.is_empty() {
        return xs.to_vec();
    }
    let codec = PageCodec::new(dtype, 1, 1, xs.len());
    let max_abs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let (scale, _) = codec.scale_for(max_abs);
    let mut payload = vec![0u8; codec.encoded_len(xs.len())];
    codec.encode_run(xs, &mut payload, 0, scale);
    let mut out = vec![0.0f32; xs.len()];
    codec.decode_run(&payload, 0, xs.len(), scale, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn dtype_parse_roundtrip() {
        for d in KvDtype::all() {
            assert_eq!(KvDtype::parse(d.as_str()), Some(d));
        }
        assert_eq!(KvDtype::parse("fp16"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
    }

    #[test]
    fn byte_sizing_matches_dtype() {
        let (m, p, d) = (2usize, 4usize, 8usize);
        let elems = m * 2 * p * d; // 128
        let f32c = PageCodec::new(KvDtype::F32, m, p, d);
        let i8c = PageCodec::new(KvDtype::Int8, m, p, d);
        let i4c = PageCodec::new(KvDtype::Int4, m, p, d);
        assert_eq!(f32c.page_bytes(), elems * 4);
        assert_eq!(i8c.page_bytes(), elems + 2 * m * 2);
        assert_eq!(i4c.page_bytes(), elems / 2 + 2 * m * 2);
        assert!(i8c.page_bytes() * 100 <= f32c.page_bytes() * 30, "int8 page <= 30% of f32");
        assert!(i4c.page_bytes() < i8c.page_bytes());
    }

    #[test]
    fn bf16_bits_roundtrip_is_close_and_stable() {
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let x = rng.normal_f32(0.0, 3.0).abs() + 1e-6;
            let y = bf16_bits_to_f32(f32_to_bf16_bits(x));
            assert!((x - y).abs() <= x * (1.0 / 256.0), "{} vs {}", x, y);
            // the roundtripped value is a fixed point
            assert_eq!(f32_to_bf16_bits(y), f32_to_bf16_bits(x));
        }
    }

    /// Quant/dequant error bound: with the stored (bf16-roundtripped)
    /// scale s, every in-range element obeys |x - dq| <= s/2 + eps;
    /// clamped elements (possible when bf16 rounds the scale down) stay
    /// within s/2 + max_abs/256.
    #[test]
    fn roundtrip_error_is_bounded_by_half_a_step() {
        check("quant-roundtrip-bound", 50, |rng| {
            let n = 1 + (rng.next_u64() % 64) as usize * 2;
            let sigma = 10f32.powi((rng.next_u64() % 7) as i32 - 3);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect();
            let max_abs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            for dtype in [KvDtype::Int8, KvDtype::Int4] {
                let codec = PageCodec::new(dtype, 1, 1, n);
                let (scale, _) = codec.scale_for(max_abs);
                let mut payload = vec![0u8; codec.encoded_len(n)];
                codec.encode_run(&xs, &mut payload, 0, scale);
                let mut back = vec![0.0f32; n];
                codec.decode_run(&payload, 0, n, scale, &mut back);
                let bound = scale * 0.5 + max_abs / 256.0 + 1e-7;
                for (i, (&x, &y)) in xs.iter().zip(&back).enumerate() {
                    crate::prop_assert!(
                        (x - y).abs() <= bound,
                        "{:?} elem {}: {} -> {} (scale {}, bound {})",
                        dtype,
                        i,
                        x,
                        y,
                        scale,
                        bound
                    );
                }
            }
            // F32 is bit-exact through the byte payload
            let codec = PageCodec::new(KvDtype::F32, 1, 1, n);
            let mut payload = vec![0u8; codec.encoded_len(n)];
            codec.encode_run(&xs, &mut payload, 0, 1.0);
            let mut back = vec![0.0f32; n];
            codec.decode_run(&payload, 0, n, 1.0, &mut back);
            crate::prop_assert!(xs == back, "f32 payload roundtrip must be exact");
            Ok(())
        });
    }

    #[test]
    fn int4_nibble_packing_is_position_exact() {
        // odd/even element offsets must hit the right nibbles
        let codec = PageCodec::new(KvDtype::Int4, 1, 1, 6);
        let xs = [7.0f32, -7.0, 1.0, 0.0, 3.0, -3.0];
        let (scale, _) = codec.scale_for(7.0);
        let mut payload = vec![0u8; codec.encoded_len(6)];
        // encode one element at a time at arbitrary offsets
        for (e, &x) in xs.iter().enumerate() {
            codec.encode_run(&[x], &mut payload, e, scale);
        }
        let mut back = vec![0.0f32; 6];
        codec.decode_run(&payload, 0, 6, scale, &mut back);
        for (&x, &y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= scale * 0.5 + 7.0 / 256.0 + 1e-6, "{} vs {}", x, y);
        }
        // the max-magnitude elements roundtrip essentially exactly
        assert!((back[0] - 7.0).abs() < 0.05 && (back[1] + 7.0).abs() < 0.05);
    }

    #[test]
    fn region_indexing_covers_both_layouts() {
        let (m, p, d) = (3usize, 4usize, 8usize);
        for dtype in [KvDtype::Int8, KvDtype::Int4] {
            let codec = PageCodec::new(dtype, m, p, d);
            for layout in [Layout::Hnd, Layout::Nhd] {
                let mut counts = vec![0usize; codec.scales_per_page()];
                let mut e = 0;
                while e < codec.page_elems() {
                    let run = codec.region_run_len(layout, e);
                    let r = codec.region_of(layout, e);
                    // a run never crosses a region boundary
                    for i in 0..run {
                        assert_eq!(codec.region_of(layout, e + i), r);
                    }
                    counts[r] += run;
                    e += run;
                }
                // every region sees exactly its p*d elements
                assert!(counts.iter().all(|&c| c == p * d), "{:?} {:?}", dtype, layout);
            }
        }
    }

    #[test]
    fn roundtrip_f32s_helper_identity_and_bounds() {
        let mut rng = Rng::new(77);
        let xs: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        assert_eq!(roundtrip_f32s(KvDtype::F32, &xs), xs);
        let max_abs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        for (dtype, qmax) in [(KvDtype::Int8, 127.0f32), (KvDtype::Int4, 7.0)] {
            let back = roundtrip_f32s(dtype, &xs);
            let bound = max_abs / qmax * 0.51 + max_abs / 256.0;
            for (&x, &y) in xs.iter().zip(&back) {
                assert!((x - y).abs() <= bound, "{:?}: {} vs {}", dtype, x, y);
            }
        }
    }
}
