//! Paged KV-cache manager: GPU-resident budget cache (NHD) + CPU offload
//! pool (HND for FreeKV, NHD for the layout ablation/baselines), page
//! tables, and min/max page summaries.
//!
//! Ownership is split per layer into a compute half ([`GpuLayerCache`])
//! that never leaves the engine thread, and a transfer half
//! ([`LayerXfer`] = select slots + CPU pool) that can be checked out to
//! the background recall worker (`transfer::pipeline`) while the engine
//! computes other layers. While checked out, `LayerState::xfer` is
//! `None`; the engine re-attaches it at the drain point before the next
//! use of that layer's selection state.

pub mod gpu;
pub mod pool;

use crate::config::ModelConfig;
use crate::transfer::TransferEngine;

pub use gpu::{CompletedPage, GpuLayerCache, SelectSlots};
pub use pool::{Chunk, LayerPool, Layout};

/// All KV state for one request across layers.
pub struct RequestKv {
    pub layers: Vec<LayerState>,
    pool_bytes_per_layer: usize,
    select_bytes_per_layer: usize,
}

pub struct LayerState {
    pub gpu: GpuLayerCache,
    /// Transfer half; `None` while checked out to the recall worker.
    xfer: Option<LayerXfer>,
}

/// The per-layer state the recall worker needs exclusive access to:
/// the CPU page pool it reads and the GPU select slots it fills.
pub struct LayerXfer {
    pub select: SelectSlots,
    pub pool: LayerPool,
}

impl LayerState {
    /// Is the transfer half currently checked out to the recall worker?
    pub fn in_flight(&self) -> bool {
        self.xfer.is_none()
    }

    pub fn xfer(&self) -> &LayerXfer {
        self.xfer.as_ref().expect("transfer half is checked out to the recall worker")
    }

    pub fn xfer_mut(&mut self) -> &mut LayerXfer {
        self.xfer.as_mut().expect("transfer half is checked out to the recall worker")
    }

    /// Check the transfer half out (for handing to the recall worker).
    pub fn take_xfer(&mut self) -> LayerXfer {
        self.xfer.take().expect("transfer half already checked out")
    }

    /// Re-attach the transfer half returned by the recall worker.
    pub fn put_xfer(&mut self, x: LayerXfer) {
        debug_assert!(self.xfer.is_none(), "transfer half re-attached twice");
        self.xfer = Some(x);
    }

    /// Convenience read access to the select page table.
    pub fn select(&self) -> &SelectSlots {
        &self.xfer().select
    }

    /// Split borrow: the compute half and the transfer half of this
    /// layer simultaneously (gather needs both mutably).
    pub fn parts_mut(&mut self) -> (&mut GpuLayerCache, &mut LayerXfer) {
        let x = self.xfer.as_mut().expect("transfer half is checked out to the recall worker");
        (&mut self.gpu, x)
    }

    /// Convenience read access to the CPU pool.
    pub fn pool(&self) -> &LayerPool {
        &self.xfer().pool
    }
}

/// Install one head's selection into the select slots: diffs against the
/// resident pages and recalls only the missing ones from the pool.
/// Shared between the engine's blocking path (via
/// [`RequestKv::apply_selection`]) and the background recall worker,
/// which runs it on a checked-out [`LayerXfer`]. Returns pages moved.
pub fn apply_selection_parts(
    select: &mut SelectSlots,
    pool: &LayerPool,
    head: usize,
    pages: &[usize],
    engine: &mut TransferEngine,
) -> usize {
    let fills = select.plan_selection(head, pages);
    let n = fills.len();
    for (slot_j, page) in fills {
        debug_assert!(pool.is_written(page), "recalling unwritten page {}", page);
        engine.recall_page(pool, page, head, select, slot_j);
    }
    n
}

impl RequestKv {
    pub fn new(cfg: &ModelConfig, cpu_layout: Layout) -> RequestKv {
        let layers: Vec<LayerState> = (0..cfg.n_layers)
            .map(|_| {
                let gpu = GpuLayerCache::new(
                    cfg.n_kv,
                    cfg.d_head,
                    cfg.page_size,
                    cfg.sink_pages,
                    cfg.window_pages,
                    cfg.select_pages,
                    cfg.n_pages_max(),
                );
                let select = gpu.new_select_slots();
                let pool = LayerPool::new(
                    cpu_layout,
                    cfg.n_pages_max(),
                    cfg.n_kv,
                    cfg.page_size,
                    cfg.d_head,
                );
                LayerState { gpu, xfer: Some(LayerXfer { select, pool }) }
            })
            .collect();
        let pool_bytes_per_layer = layers.first().map_or(0, |l| l.pool().bytes());
        let select_bytes_per_layer = layers.first().map_or(0, |l| l.select().bytes());
        RequestKv { layers, pool_bytes_per_layer, select_bytes_per_layer }
    }

    pub fn len(&self) -> usize {
        // the compute half (which owns `len`) never leaves the engine, so
        // this is safe even while transfer halves are in flight.
        self.layers.first().map_or(0, |l| l.gpu.len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append a token's K/V to a layer, offloading the page if completed.
    pub fn append(
        &mut self,
        layer: usize,
        k_new: &[f32],
        v_new: &[f32],
        engine: &mut TransferEngine,
    ) {
        let st = &mut self.layers[layer];
        if let Some(cp) = st.gpu.append(k_new, v_new) {
            let x = st.xfer.as_mut().expect("append while transfer half is on the recall worker");
            engine.offload_page(&cp, &mut x.pool);
        }
    }

    /// Install a selection for one (layer, head): diffs against resident
    /// pages and recalls only the missing ones. Returns pages transferred.
    pub fn apply_selection(
        &mut self,
        layer: usize,
        head: usize,
        pages: &[usize],
        engine: &mut TransferEngine,
    ) -> usize {
        let st = &mut self.layers[layer];
        let x = st.xfer.as_mut().expect("selection while transfer half is on the recall worker");
        apply_selection_parts(&mut x.select, &x.pool, head, pages, engine)
    }

    /// Total host bytes of the CPU pools (the offloaded cache). Derived
    /// from geometry so it stays answerable while halves are in flight.
    pub fn cpu_bytes(&self) -> usize {
        self.layers.len() * self.pool_bytes_per_layer
    }

    /// Total bytes of GPU-resident state (budget cache + summaries).
    pub fn gpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.gpu.gpu_bytes()).sum::<usize>()
            + self.layers.len() * self.select_bytes_per_layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 16,
            n_qo: 4,
            n_kv: 2,
            d_head: 4,
            d_ffn: 32,
            vocab: 16,
            rope_theta: 1e4,
            rms_eps: 1e-5,
            page_size: 4,
            max_context: 64,
            sink_pages: 1,
            window_pages: 2,
            select_pages: 2,
            kv_elem_bytes: 4,
        }
    }

    #[test]
    fn request_kv_lifecycle() {
        let cfg = tiny_cfg();
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            for l in 0..cfg.n_layers {
                let k: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(l, &k, &v, &mut eng);
            }
        }
        assert_eq!(kv.len(), 20);
        assert_eq!(eng.counters.offloaded_pages, 2 * 5);
        // select two offloaded pages on layer 0, head 1
        let n = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n, 2);
        // re-apply same selection: zero transfers (page cache hit)
        let n2 = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n2, 0);
        assert!(kv.cpu_bytes() > 0 && kv.gpu_bytes() > 0);
    }

    #[test]
    fn transfer_half_checkout_roundtrip() {
        let cfg = tiny_cfg();
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        assert!(!kv.layers[0].in_flight());
        let cpu_bytes = kv.cpu_bytes();
        let x = kv.layers[0].take_xfer();
        assert!(kv.layers[0].in_flight());
        // length and byte accounting stay answerable while checked out
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.cpu_bytes(), cpu_bytes);
        kv.layers[0].put_xfer(x);
        assert!(!kv.layers[0].in_flight());
        assert_eq!(kv.layers[0].select().selected(0).len(), cfg.select_pages);
    }
}
