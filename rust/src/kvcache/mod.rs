//! Paged KV-cache manager: GPU-resident budget cache (NHD) + CPU offload
//! pool (HND for FreeKV, NHD for the layout ablation/baselines), page
//! tables, and min/max page summaries.

pub mod gpu;
pub mod pool;

use crate::config::ModelConfig;
use crate::transfer::TransferEngine;

pub use gpu::{CompletedPage, GpuLayerCache};
pub use pool::{Chunk, LayerPool, Layout};

/// All KV state for one request across layers.
pub struct RequestKv {
    pub layers: Vec<LayerState>,
}

pub struct LayerState {
    pub gpu: GpuLayerCache,
    pub pool: LayerPool,
}

impl RequestKv {
    pub fn new(cfg: &ModelConfig, cpu_layout: Layout) -> RequestKv {
        let layers = (0..cfg.n_layers)
            .map(|_| LayerState {
                gpu: GpuLayerCache::new(
                    cfg.n_kv,
                    cfg.d_head,
                    cfg.page_size,
                    cfg.sink_pages,
                    cfg.window_pages,
                    cfg.select_pages,
                    cfg.n_pages_max(),
                ),
                pool: LayerPool::new(
                    cpu_layout,
                    cfg.n_pages_max(),
                    cfg.n_kv,
                    cfg.page_size,
                    cfg.d_head,
                ),
            })
            .collect();
        RequestKv { layers }
    }

    pub fn len(&self) -> usize {
        self.layers.first().map_or(0, |l| l.gpu.len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append a token's K/V to a layer, offloading the page if completed.
    pub fn append(
        &mut self,
        layer: usize,
        k_new: &[f32],
        v_new: &[f32],
        engine: &mut TransferEngine,
    ) {
        let st = &mut self.layers[layer];
        if let Some(cp) = st.gpu.append(k_new, v_new) {
            engine.offload_page(&cp, &mut st.pool);
        }
    }

    /// Install a selection for one (layer, head): diffs against resident
    /// pages and recalls only the missing ones. Returns pages transferred.
    pub fn apply_selection(
        &mut self,
        layer: usize,
        head: usize,
        pages: &[usize],
        engine: &mut TransferEngine,
    ) -> usize {
        let st = &mut self.layers[layer];
        let fills = st.gpu.plan_selection(head, pages);
        let n = fills.len();
        for (slot_j, page) in fills {
            debug_assert!(st.pool.is_written(page), "recalling unwritten page {}", page);
            engine.recall_page(&st.pool, page, head, &mut st.gpu, slot_j);
        }
        n
    }

    /// Total host bytes of the CPU pools (the offloaded cache).
    pub fn cpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.pool.bytes()).sum()
    }

    /// Total bytes of GPU-resident state (budget cache + summaries).
    pub fn gpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.gpu.gpu_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 16,
            n_qo: 4,
            n_kv: 2,
            d_head: 4,
            d_ffn: 32,
            vocab: 16,
            rope_theta: 1e4,
            rms_eps: 1e-5,
            page_size: 4,
            max_context: 64,
            sink_pages: 1,
            window_pages: 2,
            select_pages: 2,
            kv_elem_bytes: 4,
        }
    }

    #[test]
    fn request_kv_lifecycle() {
        let cfg = tiny_cfg();
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            for l in 0..cfg.n_layers {
                let k: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(l, &k, &v, &mut eng);
            }
        }
        assert_eq!(kv.len(), 20);
        assert_eq!(eng.counters.offloaded_pages, 2 * 5);
        // select two offloaded pages on layer 0, head 1
        let n = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n, 2);
        // re-apply same selection: zero transfers (page cache hit)
        let n2 = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n2, 0);
        assert!(kv.cpu_bytes() > 0 && kv.gpu_bytes() > 0);
    }
}
