//! Paged KV-cache manager: GPU-resident budget cache (NHD) + CPU offload
//! pool (HND for FreeKV, NHD for the layout ablation/baselines), page
//! tables, and min/max page summaries — all CPU pages drawn from the
//! shared refcounted [`PageAllocator`] (`kvcache::alloc`), which also
//! provides copy-on-write prefix sharing and the capacity ledger the
//! scheduler admits against.
//!
//! Ownership is split per layer into a compute half ([`GpuLayerCache`])
//! that never leaves the engine thread, and a transfer half
//! ([`LayerXfer`] = select slots + CPU pool view) that can be checked
//! out to the background recall worker (`transfer::pipeline`) while the
//! engine computes other layers. While checked out, `LayerState::xfer`
//! is `None`; the engine re-attaches it at the drain point before the
//! next use of that layer's selection state. The pool view is only a
//! page table plus an `Arc` of the allocator, so checking it out moves
//! no page data.

pub mod alloc;
pub mod gpu;
pub mod pool;
pub mod quant;

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::transfer::TransferEngine;

pub use self::alloc::{AdmitDecision, KvLockMode, KvPoolStats, PageAllocator, PrefixCacheMode};
pub use gpu::{CompletedPage, GpuLayerCache, SelectSlots};
pub use pool::{Chunk, LayerPool, Layout};
pub use quant::{KvDtype, PageCodec};

/// All KV state for one request across layers.
pub struct RequestKv {
    /// Per-layer KV state (compute half + transfer half).
    pub layers: Vec<LayerState>,
    select_bytes_per_layer: usize,
    alloc: Arc<PageAllocator>,
    /// GPU-ledger charge taken at construction, released on drop.
    gpu_charged: usize,
    /// prefix sharing active on the allocator (cached).
    sharing: bool,
    page_size: usize,
    /// two independent incremental chains over the token stream (FNV-1a
    /// and a splitmix-style mixer) folded into 128-bit prefix keys...
    hash_state: u64,
    mix_state: u64,
    hashed_tokens: usize,
    /// ...snapshotted at every page boundary: `boundary_hashes[g]` keys
    /// the page covering tokens `[0, (g+1)*page_size)`.
    boundary_hashes: Vec<u128>,
}

/// One layer's KV state: the engine-resident compute half plus the
/// checkout-able transfer half.
pub struct LayerState {
    /// Compute half: sink/window slabs, ring, summaries.
    pub gpu: GpuLayerCache,
    /// Transfer half; `None` while checked out to the recall worker.
    xfer: Option<LayerXfer>,
    /// Pool bytes snapshot taken when the transfer half was checked
    /// out, so byte accounting stays answerable while it is in flight
    /// (the worker only reads the pool; it never allocates pages).
    cached_pool_bytes: usize,
}

/// The per-layer state the recall worker needs exclusive access to:
/// the CPU page pool it reads and the GPU select slots it fills.
pub struct LayerXfer {
    /// GPU select-slot slab the recall worker fills.
    pub select: SelectSlots,
    /// CPU page-pool view the recall worker reads.
    pub pool: LayerPool,
}

impl LayerState {
    /// Is the transfer half currently checked out to the recall worker?
    pub fn in_flight(&self) -> bool {
        self.xfer.is_none()
    }

    /// The attached transfer half; panics if checked out.
    pub fn xfer(&self) -> &LayerXfer {
        self.xfer.as_ref().expect("transfer half is checked out to the recall worker")
    }

    /// Mutable access to the attached transfer half; panics if checked out.
    pub fn xfer_mut(&mut self) -> &mut LayerXfer {
        self.xfer.as_mut().expect("transfer half is checked out to the recall worker")
    }

    /// Check the transfer half out (for handing to the recall worker).
    pub fn take_xfer(&mut self) -> LayerXfer {
        let x = self.xfer.take().expect("transfer half already checked out");
        self.cached_pool_bytes = x.pool.bytes();
        x
    }

    /// Re-attach the transfer half returned by the recall worker.
    pub fn put_xfer(&mut self, x: LayerXfer) {
        debug_assert!(self.xfer.is_none(), "transfer half re-attached twice");
        self.xfer = Some(x);
    }

    /// Convenience read access to the select page table.
    pub fn select(&self) -> &SelectSlots {
        &self.xfer().select
    }

    /// Split borrow: the compute half and the transfer half of this
    /// layer simultaneously (gather needs both mutably).
    pub fn parts_mut(&mut self) -> (&mut GpuLayerCache, &mut LayerXfer) {
        let x = self.xfer.as_mut().expect("transfer half is checked out to the recall worker");
        (&mut self.gpu, x)
    }

    /// Convenience read access to the CPU pool.
    pub fn pool(&self) -> &LayerPool {
        &self.xfer().pool
    }

    /// This layer's pool-page bytes, live when the transfer half is
    /// attached, last-known while it is on the recall worker.
    pub fn pool_bytes(&self) -> usize {
        match &self.xfer {
            Some(x) => x.pool.bytes(),
            None => self.cached_pool_bytes,
        }
    }
}

/// Install one head's selection into the select slots: diffs against the
/// resident pages and recalls only the missing ones from the pool.
/// Shared between the engine's blocking path (via
/// [`RequestKv::apply_selection`]) and the background recall worker,
/// which runs it on a checked-out [`LayerXfer`]. Returns pages moved.
pub fn apply_selection_parts(
    select: &mut SelectSlots,
    pool: &LayerPool,
    head: usize,
    pages: &[usize],
    engine: &mut TransferEngine,
) -> usize {
    let fills = select.plan_selection(head, pages);
    let n = fills.len();
    for (slot_j, page) in fills {
        debug_assert!(pool.is_written(page), "recalling unwritten page {}", page);
        engine.recall_page(pool, page, head, select, slot_j);
    }
    n
}

impl RequestKv {
    /// KV state over a private, unbounded allocator — the standalone
    /// path (tests, single-request tools). Serving stacks share one
    /// allocator across requests via [`RequestKv::with_alloc`].
    pub fn new(cfg: &ModelConfig, cpu_layout: Layout) -> RequestKv {
        RequestKv::with_alloc(cfg, cpu_layout, PageAllocator::for_model(cfg, 0, false))
    }

    /// KV state drawing CPU pages from a shared allocator. Charges the
    /// GPU-side bytes (budget cache + summaries + select slabs) to the
    /// allocator's GPU ledger; the charge releases on drop.
    pub fn with_alloc(
        cfg: &ModelConfig,
        cpu_layout: Layout,
        alloc: Arc<PageAllocator>,
    ) -> RequestKv {
        let layers: Vec<LayerState> = (0..cfg.n_layers)
            .map(|l| {
                let gpu = GpuLayerCache::new(
                    cfg.n_kv,
                    cfg.d_head,
                    cfg.page_size,
                    cfg.sink_pages,
                    cfg.window_pages,
                    cfg.select_pages,
                    cfg.n_pages_max(),
                );
                let select = gpu.new_select_slots();
                let pool = LayerPool::with_alloc(
                    cpu_layout,
                    cfg.n_pages_max(),
                    cfg.n_kv,
                    cfg.page_size,
                    cfg.d_head,
                    alloc.clone(),
                    l,
                );
                LayerState { gpu, xfer: Some(LayerXfer { select, pool }), cached_pool_bytes: 0 }
            })
            .collect();
        let select_bytes_per_layer = layers.first().map_or(0, |l| l.select().bytes());
        let gpu_charged = layers.iter().map(|l| l.gpu.gpu_bytes()).sum::<usize>()
            + layers.len() * select_bytes_per_layer;
        alloc.charge_gpu(gpu_charged);
        let sharing = alloc.sharing();
        RequestKv {
            layers,
            select_bytes_per_layer,
            alloc,
            gpu_charged,
            sharing,
            page_size: cfg.page_size,
            hash_state: self::alloc::FNV_OFFSET,
            mix_state: self::alloc::MIX2_SEED,
            hashed_tokens: 0,
            boundary_hashes: Vec::new(),
        }
    }

    /// The allocator backing this request's CPU pages.
    pub fn allocator(&self) -> &Arc<PageAllocator> {
        &self.alloc
    }

    /// Feed the request's token stream for prefix keying (no-op unless
    /// the allocator has sharing enabled). Call with the tokens known
    /// so far before appending their K/V; only the unseen suffix is
    /// hashed, and the chain state is snapshotted at page boundaries so
    /// each completed page gets the hash of exactly the tokens it
    /// covers.
    pub fn feed_tokens(&mut self, tokens: &[i32]) {
        if !self.sharing {
            return;
        }
        while self.hashed_tokens < tokens.len() {
            let tok = tokens[self.hashed_tokens];
            self.hash_state = self::alloc::fnv1a_i32(self.hash_state, tok);
            self.mix_state = self::alloc::mix2_i32(self.mix_state, tok);
            self.hashed_tokens += 1;
            if self.hashed_tokens % self.page_size == 0 {
                let h = self::alloc::fold_key(self.hash_state, self.mix_state);
                self.boundary_hashes.push(h);
                // Debug-only collision oracle: record the exact token
                // block behind this boundary hash so a real FNV+splitmix
                // collision fails loudly before any adoption can alias
                // the wrong page (release builds compile this away).
                self.alloc.verify_token_block(
                    h,
                    &tokens[self.hashed_tokens - self.page_size..self.hashed_tokens],
                );
            }
        }
    }

    /// Adopt the longest common prefix of this request's token stream
    /// from the shared prefix cache: walk the page-boundary chain
    /// hashes from page 0 and claim each whole cross-layer page that is
    /// still committed in the allocator — resident pages of a live
    /// request or refcount-0 pages pinned by the retained tier alike —
    /// stopping at the first miss. Returns the number of tokens whose
    /// completed-page offload is now already satisfied; the caller
    /// prefills normally and [`RequestKv::append`] /
    /// [`RequestKv::offload_completed`] skip the redundant page writes.
    ///
    /// Must run at the prefill entry point: after [`RequestKv::feed_tokens`]
    /// has hashed the prompt, before any K/V lands (no-op otherwise).
    pub fn adopt_prefix(&mut self) -> usize {
        if !self.sharing || self.layers.is_empty() || self.len() != 0 {
            return 0;
        }
        let layout = self.layers[0].pool().layout;
        let mut pages = 0usize;
        for g in 0..self.boundary_hashes.len() {
            let Some(slots) = self.alloc.adopt_stack(layout, self.boundary_hashes[g]) else {
                break;
            };
            debug_assert_eq!(slots.len(), self.layers.len());
            for (l, slot) in slots.into_iter().enumerate() {
                self.layers[l].xfer_mut().pool.install_adopted(g, slot);
            }
            pages += 1;
        }
        pages * self.page_size
    }

    /// Prefix key of logical page `page`, if sharing is on and the
    /// covering tokens were fed.
    pub fn page_key(&self, page: usize) -> Option<u128> {
        if self.sharing {
            self.boundary_hashes.get(page).copied()
        } else {
            None
        }
    }

    /// Tokens appended so far (absolute sequence length).
    pub fn len(&self) -> usize {
        // the compute half (which owns `len`) never leaves the engine, so
        // this is safe even while transfer halves are in flight.
        self.layers.first().map_or(0, |l| l.gpu.len)
    }

    /// Whether no tokens have been appended yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append a token's K/V to a layer, offloading the page if completed
    /// (aliasing a resident prefix-matched page when sharing allows).
    pub fn append(
        &mut self,
        layer: usize,
        k_new: &[f32],
        v_new: &[f32],
        engine: &mut TransferEngine,
    ) {
        if let Some(cp) = self.layers[layer].gpu.append(k_new, v_new) {
            let key = self.page_key(cp.page);
            let st = &mut self.layers[layer];
            let x = st.xfer.as_mut().expect("append while transfer half is on the recall worker");
            // A page completes exactly once, so a committed pool entry
            // here can only mean the page was LCP-adopted at prefill
            // entry — the offload (write + quantize) is already done.
            if !x.pool.is_written(cp.page) {
                engine.offload_page_keyed(&cp, &mut x.pool, key);
            }
        }
    }

    /// Offload a batch of completed pages (the prefill path), keyed for
    /// prefix sharing when the covering tokens were fed.
    pub fn offload_completed(
        &mut self,
        layer: usize,
        completed: &[CompletedPage],
        engine: &mut TransferEngine,
    ) {
        let keys: Vec<Option<u128>> = completed.iter().map(|cp| self.page_key(cp.page)).collect();
        let st = &mut self.layers[layer];
        let x = st.xfer.as_mut().expect("offload while transfer half is on the recall worker");
        for (cp, key) in completed.iter().zip(keys) {
            // skip pages whose offload was satisfied by LCP adoption
            // (see `append`)
            if !x.pool.is_written(cp.page) {
                engine.offload_page_keyed(cp, &mut x.pool, key);
            }
        }
    }

    /// Install a selection for one (layer, head): diffs against resident
    /// pages and recalls only the missing ones. Returns pages transferred.
    pub fn apply_selection(
        &mut self,
        layer: usize,
        head: usize,
        pages: &[usize],
        engine: &mut TransferEngine,
    ) -> usize {
        let st = &mut self.layers[layer];
        let x = st.xfer.as_mut().expect("selection while transfer half is on the recall worker");
        apply_selection_parts(&mut x.select, &x.pool, head, pages, engine)
    }

    /// Host bytes of CPU pool pages this request references — actual
    /// allocated pages, not the old dense `max_context` reservation.
    /// Shared pages count fully for each referencing request here; the
    /// process-wide figure (shared counted once) is
    /// `PageAllocator::stats().cpu_bytes_used`. Stays answerable while
    /// transfer halves are in flight (last-known snapshot per layer).
    pub fn cpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.pool_bytes()).sum()
    }

    /// Total bytes of GPU-resident state (budget cache + summaries).
    pub fn gpu_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.gpu.gpu_bytes()).sum::<usize>()
            + self.layers.len() * self.select_bytes_per_layer
    }
}

impl Drop for RequestKv {
    fn drop(&mut self) {
        self.alloc.release_gpu(self.gpu_charged);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 16,
            n_qo: 4,
            n_kv: 2,
            d_head: 4,
            d_ffn: 32,
            vocab: 16,
            rope_theta: 1e4,
            rms_eps: 1e-5,
            page_size: 4,
            max_context: 64,
            sink_pages: 1,
            window_pages: 2,
            select_pages: 2,
            kv_elem_bytes: 4,
        }
    }

    #[test]
    fn request_kv_lifecycle() {
        let cfg = tiny_cfg();
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            for l in 0..cfg.n_layers {
                let k: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(l, &k, &v, &mut eng);
            }
        }
        assert_eq!(kv.len(), 20);
        assert_eq!(eng.counters.offloaded_pages, 2 * 5);
        // select two offloaded pages on layer 0, head 1
        let n = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n, 2);
        // re-apply same selection: zero transfers (page cache hit)
        let n2 = kv.apply_selection(0, 1, &[1, 2], &mut eng);
        assert_eq!(n2, 0);
        assert!(kv.cpu_bytes() > 0 && kv.gpu_bytes() > 0);
        // byte accounting reflects offloaded pages, not max_context
        let page_bytes = kv.allocator().page_bytes();
        assert_eq!(kv.cpu_bytes(), 2 * 5 * page_bytes);
    }

    #[test]
    fn transfer_half_checkout_roundtrip() {
        let cfg = tiny_cfg();
        let mut kv = RequestKv::new(&cfg, Layout::Hnd);
        let mut eng = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let mut rng = Rng::new(9);
        // offload one page so byte accounting has something to report
        for _ in 0..cfg.page_size {
            for l in 0..cfg.n_layers {
                let k: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(l, &k.clone(), &k, &mut eng);
            }
        }
        assert!(!kv.layers[0].in_flight());
        let cpu_bytes = kv.cpu_bytes();
        assert!(cpu_bytes > 0);
        let x = kv.layers[0].take_xfer();
        assert!(kv.layers[0].in_flight());
        // length and byte accounting stay answerable while checked out
        assert_eq!(kv.len(), cfg.page_size);
        assert_eq!(kv.cpu_bytes(), cpu_bytes);
        kv.layers[0].put_xfer(x);
        assert!(!kv.layers[0].in_flight());
        assert_eq!(kv.layers[0].select().selected(0).len(), cfg.select_pages);
    }

    #[test]
    fn gpu_ledger_charges_and_releases_with_request_lifetime() {
        let cfg = tiny_cfg();
        let alloc = PageAllocator::for_model(&cfg, 0, false);
        assert_eq!(alloc.stats().gpu_bytes_used, 0);
        let kv = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        let charged = kv.gpu_bytes() as u64;
        assert!(charged > 0);
        assert_eq!(alloc.stats().gpu_bytes_used, charged);
        let kv2 = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        assert_eq!(alloc.stats().gpu_bytes_used, charged + kv2.gpu_bytes() as u64);
        drop(kv);
        drop(kv2);
        assert_eq!(alloc.stats().gpu_bytes_used, 0);
    }

    #[test]
    fn shared_prefix_appends_alias_pool_pages() {
        let cfg = tiny_cfg();
        let alloc = PageAllocator::for_model(&cfg, 0, true);
        let tokens: Vec<i32> = (0..12).map(|t| t % 7).collect();
        let kv_row = vec![0.25f32; cfg.n_kv * cfg.d_head];
        let fill = |kv: &mut RequestKv, eng: &mut TransferEngine| {
            for t in 0..tokens.len() {
                kv.feed_tokens(&tokens[..t + 1]);
                for l in 0..cfg.n_layers {
                    kv.append(l, &kv_row, &kv_row, eng);
                }
            }
        };
        let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        fill(&mut a, &mut ea);
        // 12 tokens = 3 pages x 2 layers
        assert_eq!(alloc.stats().pages_used, 6);
        assert_eq!(ea.counters.prefix_hits, 0);
        let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        fill(&mut b, &mut eb);
        // identical token stream: every page of b aliases a's
        assert_eq!(alloc.stats().pages_used, 6, "no new physical pages");
        assert_eq!(alloc.stats().pages_shared, 6);
        assert_eq!(eb.counters.prefix_hits, 6);
        assert_eq!(b.cpu_bytes(), a.cpu_bytes());
        drop(a);
        assert_eq!(alloc.stats().pages_used, 6, "b keeps the pages alive");
        drop(b);
        assert_eq!(alloc.stats().pages_used, 0);
    }

    #[test]
    fn lcp_adoption_survives_request_death_and_matches_cold_prefill() {
        let cfg = tiny_cfg();
        let alloc =
            PageAllocator::for_model_mode(&cfg, 0, PrefixCacheMode::Retained, 0, KvDtype::F32);
        let tokens: Vec<i32> = (0..12).map(|t| t % 7).collect();
        // distinguishable per-token rows so page content is checkable
        let rows: Vec<Vec<f32>> = (0..tokens.len())
            .map(|t| (0..cfg.n_kv * cfg.d_head).map(|i| (t * 13 + i) as f32 * 0.25).collect())
            .collect();
        let fill = |kv: &mut RequestKv, eng: &mut TransferEngine, adopt: bool| -> usize {
            kv.feed_tokens(&tokens);
            let adopted = if adopt { kv.adopt_prefix() } else { 0 };
            for row in &rows {
                for l in 0..cfg.n_layers {
                    kv.append(l, row, row, eng);
                }
            }
            adopted
        };
        // request A prefills cold and fully retires
        let mut a = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        let mut ea = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        fill(&mut a, &mut ea, false);
        drop(a);
        let st = alloc.stats();
        assert_eq!(st.pages_used, 6, "retained pages still count as used");
        assert_eq!(st.pages_retained, 6, "3 pages x 2 layers retained past death");
        // request B adopts the whole prefix out of the retained tier
        let mut b = RequestKv::with_alloc(&cfg, Layout::Hnd, alloc.clone());
        let mut eb = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        let adopted = fill(&mut b, &mut eb, true);
        assert_eq!(adopted, 12, "every whole page of the prompt adopted");
        assert_eq!(eb.counters.offloaded_pages, 0, "no adopted page was re-written");
        let st = alloc.stats();
        assert_eq!(st.retained_hits, 6);
        assert_eq!(st.pages_retained, 0, "revived pages left the tier");
        // the adopted pool is bit-identical to a cold prefill's pool
        let mut c = RequestKv::new(&cfg, Layout::Hnd);
        let mut ec = TransferEngine::new(cfg.page_size, cfg.d_head, true);
        fill(&mut c, &mut ec, false);
        for l in 0..cfg.n_layers {
            for g in 0..3 {
                for h in 0..cfg.n_kv {
                    assert_eq!(
                        b.layers[l].pool().read_page_head(g, h),
                        c.layers[l].pool().read_page_head(g, h),
                        "layer {} page {} head {} diverged from cold prefill",
                        l,
                        g,
                        h
                    );
                }
            }
        }
        drop(b);
        assert_eq!(alloc.stats().pages_retained, 6, "pages retire back into the tier");
    }
}
