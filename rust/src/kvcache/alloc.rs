//! Shared paged KV memory subsystem: the process-wide page allocator
//! behind every [`LayerPool`](crate::kvcache::pool::LayerPool) view.
//!
//! # Why
//!
//! The seed allocated KV memory the naive way: each `RequestKv` built a
//! private, dense, full-context slab per layer, so host memory scaled
//! with `running_set x max_context` regardless of how many pages a
//! request actually offloaded, admission was blind to memory, and N
//! requests with the same prompt stored the same pages N times. This
//! module replaces that with one allocator shared by every sequence of
//! an engine:
//!
//! * **One CPU slab per layer**, grown on demand one page at a time —
//!   a request's pool footprint is the pages it has *offloaded*, not
//!   `max_context`. Both HND and NHD page layouts live in the same
//!   slab (the layout governs the element order *within* a page, so
//!   the hybrid-layout ablation is preserved; see `pool.rs`).
//! * **Codec-parameterized pages.** The slab is byte-addressed: each
//!   page occupies the [`PageCodec`]-defined stride (f32, INT8, or
//!   packed INT4 payload) plus a sidecar of per-(head, plane) bf16
//!   scale entries (`kvcache::quant`). The allocator only moves and
//!   refcounts encoded bytes; encode/decode happens in the pool view
//!   (`write_page*` / `copy_chunks` / `read_page_head`).
//! * **Refcounted page handles** ([`Slot`]). A `LayerPool` is a view: a
//!   logical-page -> slot table plus an `Arc` of this allocator. Slots
//!   free when the last view referencing them drops (retire, cancel,
//!   disconnect), with double-free and use-after-free turned into loud
//!   assertions instead of corruption.
//! * **Copy-on-write prefix sharing.** When a request offloads a page
//!   whose token prefix hash matches a page a *resident* request
//!   already committed (same layer, same layout, same dtype, same model
//!   namespace), the new view aliases the existing slot instead of
//!   writing a duplicate ([`PageAllocator::adopt`]); a later write to
//!   an aliased page materializes a private copy first
//!   ([`PageAllocator::make_unique`]), so a shared page is never
//!   mutated in place. In [`PrefixCacheMode::Resident`] mode
//!   registrations die with the slot: sharing is only ever against
//!   pages that are still alive. Keys are 128-bit double-chain hashes
//!   (FNV-1a + a splitmix-style mixer over the same token stream):
//!   not cryptographic, but aliasing the wrong page requires
//!   colliding two structurally different chains at once; debug
//!   builds additionally keep an exact token-block oracle
//!   ([`PageAllocator::verify_token_block`]) that fails loudly on the
//!   first real collision.
//! * **A persistent prefix-cache tier** ([`PrefixCacheMode::Retained`]).
//!   When a retiring request drops the last reference to a committed,
//!   prefix-registered page, the page moves to a *retained* set —
//!   refcount 0 but pinned by the cache, still registered, still
//!   counted in `pages_used` — instead of freeing. A later request
//!   whose token chain reaches the same boundary hash revives it
//!   ([`PageAllocator::adopt_stack`] walks the longest common prefix
//!   page by page), turning prefill into recall across request
//!   lifetimes. Retained pages are reclaimable capacity: allocation
//!   under pool pressure evicts them in ascending
//!   (popularity, recency) order — live pages are never evicted — and
//!   an optional retention cap bounds the tier independently of the
//!   pool.
//! * **A capacity ledger** for admission control. The scheduler charges
//!   a request's worst-case page footprint ([`worst_case_pages`])
//!   before admitting it ([`PageAllocator::try_reserve`]); when the
//!   pool cannot cover the footprint the request *queues* instead of
//!   OOMing mid-decode, and resumes when a finish/cancel releases its
//!   reservation. A **GPU-budget ledger** tracks the device-side bytes
//!   (budget cache + summaries + select slabs) charged by live
//!   `RequestKv`s the same way.
//!
//! # Concurrency
//!
//! The transfer half of a layer (select slots + `LayerPool` view) is
//! checked out to the background recall worker while the engine
//! computes other layers, so slot reads happen off the engine thread.
//! Slab state is *sharded*: each layer's page payloads, scale sidecar,
//! refcounts, and free list sit behind their own shard lock (one shard
//! per layer by default; [`KvLockMode::Global`] collapses every layer
//! into one shard as the contention ablation), while the cross-layer
//! state — prefix registry, retained tier, admission and GPU ledgers,
//! the eviction clock — lives behind a single small metadata lock.
//!
//! The lock-ordering invariant is: **metadata before shard, and at
//! most one shard lock held at a time** (enforced per-thread in debug
//! builds). Cross-layer operations that must stay atomic
//! ([`PageAllocator::adopt_stack`], retained eviction, `try_reserve`)
//! hold the metadata lock and visit shards one at a time in ascending
//! layer order; holding the metadata lock freezes every refcount and
//! both maps (all lifecycle transitions take it), which is what makes
//! the one-shard-at-a-time walk atomic.
//!
//! Bulk byte movement stays *outside* the critical sections: writers
//! encode into scratch buffers and memcpy under the shard lock
//! (`write_slot_encoded`), and readers snapshot the encoded bytes
//! under the shard lock, decode after release, and re-check a per-slot
//! generation counter (seqlock-style) that every mutation bumps — a
//! concurrent CoW `make_unique` or rewrite is detected and the
//! snapshot retried. Every lock site counts acquisitions and contended
//! waits into [`KvPoolStats`] (`*_lock_waits` / `*_lock_wait_secs`),
//! surfaced through `EngineStats` on `/metrics`.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};
use std::time::Instant;

use crate::config::ModelConfig;
use crate::kvcache::pool::Layout;
use crate::kvcache::quant::{KvDtype, PageCodec};

/// Handle to one allocated page within a layer slab.
pub type Slot = u32;

/// Locking layout of the shared allocator (the `--kv-lock` ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvLockMode {
    /// One lock for every layer slab — the pre-sharding behaviour,
    /// kept as the contention baseline.
    Global,
    /// One lock per layer slab (plus the shared metadata lock), so the
    /// recall worker gathering layer *l* never blocks the engine
    /// appending to layer *l+1*.
    #[default]
    Sharded,
}

impl KvLockMode {
    /// Stable CLI / report name of the mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            KvLockMode::Global => "global",
            KvLockMode::Sharded => "sharded",
        }
    }

    /// Parse a CLI value.
    pub fn parse(s: &str) -> Option<KvLockMode> {
        match s {
            "global" | "single" => Some(KvLockMode::Global),
            "sharded" | "per-layer" => Some(KvLockMode::Sharded),
            _ => None,
        }
    }

    /// Both modes, for sweeps and equivalence tests.
    pub fn all() -> [KvLockMode; 2] {
        [KvLockMode::Global, KvLockMode::Sharded]
    }
}

impl std::fmt::Display for KvLockMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Operating mode of the cross-request prefix cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefixCacheMode {
    /// No sharing: every request writes private pages.
    #[default]
    Off,
    /// Copy-on-write sharing against *resident* requests only (the
    /// PR-5 semantics): prefix registrations die with the last live
    /// reference to a page.
    Resident,
    /// Resident sharing plus the persistent tier: a retiring request's
    /// committed pages stay adoptable at refcount 0, pinned by the
    /// cache until evicted by pool pressure or the retention cap.
    Retained,
}

impl PrefixCacheMode {
    /// Stable CLI / report name of the mode.
    pub fn as_str(&self) -> &'static str {
        match self {
            PrefixCacheMode::Off => "off",
            PrefixCacheMode::Resident => "resident",
            PrefixCacheMode::Retained => "retained",
        }
    }

    /// Parse a CLI value; accepts `on` as a back-compat alias for the
    /// historical boolean `--prefix-cache` flag.
    pub fn parse(s: &str) -> Option<PrefixCacheMode> {
        match s {
            "off" | "none" => Some(PrefixCacheMode::Off),
            "resident" | "on" => Some(PrefixCacheMode::Resident),
            "retained" | "lru" => Some(PrefixCacheMode::Retained),
            _ => None,
        }
    }

    /// Every mode, for sweeps.
    pub fn all() -> [PrefixCacheMode; 3] {
        [PrefixCacheMode::Off, PrefixCacheMode::Resident, PrefixCacheMode::Retained]
    }

    /// Is any form of prefix sharing (resident or retained) enabled?
    pub fn sharing(&self) -> bool {
        !matches!(self, PrefixCacheMode::Off)
    }

    /// Does the cache retain pages past the last live reference?
    pub fn retention(&self) -> bool {
        matches!(self, PrefixCacheMode::Retained)
    }
}

impl std::fmt::Display for PrefixCacheMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Outcome of charging a request's footprint against the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Footprint reserved; the request may start.
    Admit,
    /// The pool cannot cover the footprint right now; keep the request
    /// queued and retry once running requests free pages.
    Wait,
    /// The footprint exceeds the whole pool; the request can never run.
    Never,
}

/// Live gauges of the shared pool (surfaced on `/metrics` and in
/// `EngineStats`).
#[derive(Debug, Clone, Default)]
pub struct KvPoolStats {
    /// Configured capacity in pages across all layers (0 = unbounded).
    pub pages_capacity: u64,
    /// Distinct allocated slots across all layers (shared pages counted
    /// once, process-wide).
    pub pages_used: u64,
    /// High-water mark of `pages_used`.
    pub pages_peak: u64,
    /// Slots currently referenced by two or more views.
    pub pages_shared: u64,
    /// Pages reserved by admitted requests (worst-case footprints).
    pub pages_reserved: u64,
    /// Offloads satisfied by aliasing an already-resident page.
    pub prefix_hits: u64,
    /// Bytes of allocated CPU slab pages (distinct slots only), at the
    /// pool's *encoded* page stride (payload + scale sidecar).
    pub cpu_bytes_used: u64,
    /// High-water mark of `cpu_bytes_used` — scales with the codec.
    pub cpu_bytes_peak: u64,
    /// GPU-side bytes charged by live `RequestKv`s.
    pub gpu_bytes_used: u64,
    /// Pages in the retained tier: refcount 0, pinned by the prefix
    /// cache, counted inside `pages_used`.
    pub pages_retained: u64,
    /// Adoptions that revived a retained (refcount-0) page — the
    /// cross-request-lifetime subset of `prefix_hits`.
    pub retained_hits: u64,
    /// Retained pages reclaimed under pool pressure or the retention
    /// cap (cumulative).
    pub retained_evictions: u64,
    /// Encoded CPU bytes whose offload was satisfied by adoption
    /// instead of a fresh page write (`prefix_hits x page_bytes`).
    pub bytes_saved: u64,
    /// Shard-lock acquisitions across every per-layer slab lock
    /// (cumulative; in [`KvLockMode::Global`] the one slab lock).
    pub shard_lock_acqs: u64,
    /// Shard-lock acquisitions that found the lock held and had to
    /// block (cumulative).
    pub shard_lock_waits: u64,
    /// Total seconds spent blocked on shard locks (cumulative).
    pub shard_lock_wait_secs: f64,
    /// Metadata-lock acquisitions (prefix registry, retained tier,
    /// admission/GPU ledgers; cumulative).
    pub meta_lock_acqs: u64,
    /// Metadata-lock acquisitions that had to block (cumulative).
    pub meta_lock_waits: u64,
    /// Total seconds spent blocked on the metadata lock (cumulative).
    pub meta_lock_wait_secs: f64,
}

/// FNV-1a over one i32 token — half of the incremental prefix hash
/// chained by `RequestKv::feed_tokens`.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Seed of the second, independent chain (splitmix-style mixer). Prefix
/// keys are the 128-bit concatenation of both chains: neither is
/// cryptographic, but a page-aliasing collision must now defeat two
/// structurally different mixers simultaneously over the same token
/// stream, and accidental collisions are out at ~2^64 birthday bound.
pub const MIX2_SEED: u64 = 0x6a09_e667_f3bc_c909;

/// The first chain: FNV-1a folded over the token's little-endian bytes.
#[inline]
pub fn fnv1a_i32(state: u64, tok: i32) -> u64 {
    let mut h = state;
    for b in tok.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The second chain: splitmix64 finalizer over state xor token.
#[inline]
pub fn mix2_i32(state: u64, tok: i32) -> u64 {
    let mut z = state ^ (tok as u32 as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Fold both chain states into the 128-bit prefix key.
#[inline]
pub fn fold_key(fnv: u64, mix: u64) -> u128 {
    ((fnv as u128) << 64) | (mix as u128)
}

/// Worst-case distinct pool pages a request can offload across all
/// layers: every completed page of `prompt + max_new` tokens (clamped
/// to the model context), per layer. The admission charge.
pub fn worst_case_pages(cfg: &ModelConfig, total_tokens: usize) -> u64 {
    let toks = total_tokens.min(cfg.max_context).max(1);
    (cfg.n_layers as u64) * (toks.div_ceil(cfg.page_size) as u64)
}

/// Prefix-cache key: 128-bit token-stream hash qualified by layer, page
/// layout, *and element dtype* (an HND page and an NHD page are
/// different byte patterns, and an f32 page must never alias into an
/// int8 pool even if two allocators ever shared a prefix map). The
/// allocator namespace (model identity) is mixed into `hash`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PrefixKey {
    layer: u32,
    hnd: bool,
    dtype: KvDtype,
    hash: u128,
}

struct LayerSlab {
    /// Encoded page payloads, `slots * payload_stride` bytes, grown on
    /// demand.
    data: Vec<u8>,
    /// Scale sidecar, `slots * scales_per_page` bf16 bit patterns
    /// (empty for F32 pools).
    scales: Vec<u16>,
    refcnt: Vec<u32>,
    written: Vec<bool>,
    /// Prefix key registered for a slot (reverse index for cleanup).
    key: Vec<Option<PrefixKey>>,
    /// Adoption count per slot — the popularity half of the retained
    /// tier's eviction score. Survives retention/revival; resets when
    /// the slot is actually freed.
    hits: Vec<u32>,
    /// Per-slot generation counter, bumped under the shard lock by
    /// every content mutation (fresh alloc, write, free). Snapshot
    /// readers re-check it after decoding outside the lock — the
    /// seqlock half of the copy-outside-critical-section protocol.
    gen: Vec<u64>,
    free: Vec<Slot>,
}

impl LayerSlab {
    fn new() -> LayerSlab {
        LayerSlab {
            data: Vec::new(),
            scales: Vec::new(),
            refcnt: Vec::new(),
            written: Vec::new(),
            key: Vec::new(),
            hits: Vec::new(),
            gen: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Per-shard poison audit: everything checkable without the
    /// metadata lock. Free-list membership and refcounts live under the
    /// same lock, so a poisoning panic must not have torn them.
    fn poison_audit(&self) -> bool {
        let n = self.refcnt.len();
        self.written.len() == n
            && self.key.len() == n
            && self.hits.len() == n
            && self.gen.len() == n
            && self.free.iter().all(|&s| self.refcnt[s as usize] == 0)
    }
}

/// One lockable slice of the slab state. In [`KvLockMode::Sharded`]
/// each shard holds exactly one layer's slab; in [`KvLockMode::Global`]
/// a single shard holds every layer (the pre-sharding layout).
struct Shard {
    slabs: Vec<LayerSlab>,
}

/// Cross-layer state behind the single metadata lock. Every slot
/// *lifecycle* transition (alloc, retain, release, adopt, free) takes
/// this lock, which is what freezes refcounts during multi-shard walks;
/// pure content accesses (read/write/snapshot of an already-held slot)
/// are shard-only.
struct Meta {
    prefix: HashMap<PrefixKey, Slot>,
    used: u64,
    peak_used: u64,
    shared: u64,
    prefix_hits: u64,
    reservations: HashMap<u64, u64>,
    reserved: u64,
    gpu_used: u64,
    /// The retained tier: `(layer, slot) -> (popularity, last-touched
    /// tick)`. Every member has refcount 0, `written`, and a live
    /// prefix registration; it stays counted in `used`. The popularity
    /// is snapshotted from the slab at retention time (it cannot change
    /// while the page sits in the tier — adoption removes it first), so
    /// victim selection never has to visit the shards.
    retained: HashMap<(u32, Slot), (u32, u64)>,
    /// Logical clock advanced on every retention, giving the recency
    /// half of the eviction score a deterministic total order.
    clock: u64,
    retained_hits: u64,
    retained_evictions: u64,
}

impl Meta {
    /// Per-lock poison audit: the reservation ledger must still
    /// balance.
    fn poison_audit(&self) -> bool {
        self.reservations.values().sum::<u64>() == self.reserved
    }
}

/// Debug-build enforcement of the lock-ordering invariant: the
/// metadata lock is acquired before any shard lock, never after one,
/// and at most one shard lock is held per thread at a time. Together
/// these make allocator deadlock impossible (shard locks never nest,
/// and meta -> shard is the only nesting that exists); multi-shard
/// walks additionally visit shards in ascending layer order for
/// deterministic behaviour, but that is structural (loops over
/// `0..n_layers`), not something a runtime check can add to.
#[cfg(debug_assertions)]
mod lock_order {
    use std::cell::Cell;

    thread_local! {
        static META_HELD: Cell<bool> = const { Cell::new(false) };
        static SHARD_HELD: Cell<bool> = const { Cell::new(false) };
    }

    pub(super) struct MetaToken(());

    impl MetaToken {
        pub(super) fn acquire() -> MetaToken {
            SHARD_HELD.with(|c| {
                assert!(
                    !c.get(),
                    "kv lock-order violation: metadata lock requested while a shard lock is held"
                )
            });
            META_HELD.with(|c| {
                assert!(!c.replace(true), "kv lock-order violation: metadata lock re-entered")
            });
            MetaToken(())
        }
    }

    impl Drop for MetaToken {
        fn drop(&mut self) {
            META_HELD.with(|c| c.set(false));
        }
    }

    pub(super) struct ShardToken(());

    impl ShardToken {
        pub(super) fn acquire() -> ShardToken {
            SHARD_HELD.with(|c| {
                assert!(
                    !c.replace(true),
                    "kv lock-order violation: two shard locks held by one thread"
                )
            });
            ShardToken(())
        }
    }

    impl Drop for ShardToken {
        fn drop(&mut self) {
            SHARD_HELD.with(|c| c.set(false));
        }
    }
}

/// Contention counters for one lock class (all shard locks pooled, or
/// the metadata lock). Updated lock-free; read by `stats()`.
#[derive(Debug, Default)]
struct LockCounters {
    acquisitions: AtomicU64,
    waits: AtomicU64,
    wait_nanos: AtomicU64,
}

/// Lock with contention accounting and deliberate poison recovery: a
/// fast `try_lock` counts the uncontended path, a contended
/// acquisition is timed into `wait_nanos`, and a poisoned lock (a
/// panic while it was held — a crashed worker job, an injected
/// `AllocPanic`) is recovered after a per-lock audit instead of
/// cascading `PoisonError` panics through every thread sharing the
/// allocator.
#[allow(clippy::disallowed_methods)] // the allocator's deliberate poison-recovery point
fn lock_timed<'a, T>(
    m: &'a Mutex<T>,
    counters: &LockCounters,
    audit: impl FnOnce(&T) -> bool,
    what: &str,
) -> MutexGuard<'a, T> {
    counters.acquisitions.fetch_add(1, Ordering::Relaxed);
    let result = match m.try_lock() {
        Ok(g) => return g,
        Err(TryLockError::WouldBlock) => {
            counters.waits.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            let r = m.lock();
            counters.wait_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            r
        }
        Err(TryLockError::Poisoned(p)) => Err(p),
    };
    match result {
        Ok(g) => g,
        Err(poisoned) => {
            let g = poisoned.into_inner();
            debug_assert!(
                audit(&g),
                "kv allocator {} lock poisoned with broken invariants",
                what
            );
            g
        }
    }
}

/// RAII guard over the metadata lock (plus the debug-build lock-order
/// token).
struct MetaGuard<'a> {
    g: MutexGuard<'a, Meta>,
    #[cfg(debug_assertions)]
    _order: lock_order::MetaToken,
}

impl std::ops::Deref for MetaGuard<'_> {
    type Target = Meta;
    fn deref(&self) -> &Meta {
        &self.g
    }
}

impl std::ops::DerefMut for MetaGuard<'_> {
    fn deref_mut(&mut self) -> &mut Meta {
        &mut self.g
    }
}

/// RAII guard over one shard lock (plus the debug-build lock-order
/// token).
struct ShardGuard<'a> {
    g: MutexGuard<'a, Shard>,
    #[cfg(debug_assertions)]
    _order: lock_order::ShardToken,
}

impl std::ops::Deref for ShardGuard<'_> {
    type Target = Shard;
    fn deref(&self) -> &Shard {
        &self.g
    }
}

impl std::ops::DerefMut for ShardGuard<'_> {
    fn deref_mut(&mut self) -> &mut Shard {
        &mut self.g
    }
}

/// The shared allocator. Cheap to clone via `Arc`; `Send + Sync` so
/// `LayerPool` views travel to the recall worker inside `LayerXfer`.
pub struct PageAllocator {
    /// Number of model layers (one logical pool per layer).
    pub n_layers: usize,
    /// KV heads per layer.
    pub n_kv: usize,
    /// Tokens per page.
    pub page_size: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Logical f32 elements of one page across kv heads, K+V planes
    /// together (the pre-encode element count; the slab stride is
    /// `codec.payload_bytes()`).
    pub page_elems: usize,
    /// Aggregate capacity in pages across all layers (0 = unbounded).
    pub capacity_pages: u64,
    codec: PageCodec,
    mode: PrefixCacheMode,
    lock_mode: KvLockMode,
    /// Max pages the retained tier may pin (0 = bounded only by pool
    /// pressure). Only meaningful in [`PrefixCacheMode::Retained`].
    retain_cap_pages: u64,
    namespace: u64,
    shards: Vec<Mutex<Shard>>,
    meta: Mutex<Meta>,
    shard_locks: LockCounters,
    meta_locks: LockCounters,
    /// Debug-only collision oracle: boundary hash -> the exact token
    /// block that produced it (see
    /// [`PageAllocator::verify_token_block`]).
    #[cfg(debug_assertions)]
    token_blocks: Mutex<HashMap<u128, Vec<i32>>>,
}

impl std::fmt::Debug for PageAllocator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PageAllocator")
            .field("n_layers", &self.n_layers)
            .field("page_elems", &self.page_elems)
            .field("dtype", &self.codec.dtype)
            .field("capacity_pages", &self.capacity_pages)
            .field("mode", &self.mode)
            .field("lock_mode", &self.lock_mode)
            .field("pages_used", &s.pages_used)
            .field("pages_retained", &s.pages_retained)
            .finish()
    }
}

impl PageAllocator {
    /// Full-precision (f32) allocator — the historical constructor.
    pub fn new(
        n_layers: usize,
        n_kv: usize,
        page_size: usize,
        d_head: usize,
        capacity_pages: u64,
        sharing: bool,
        namespace: u64,
    ) -> Arc<PageAllocator> {
        PageAllocator::with_dtype(
            n_layers,
            n_kv,
            page_size,
            d_head,
            capacity_pages,
            sharing,
            namespace,
            KvDtype::F32,
        )
    }

    /// Allocator whose pages are stored through the `dtype` codec,
    /// with the prefix cache either off or resident-only (the
    /// historical boolean). Use [`PageAllocator::with_mode`] for the
    /// retained tier.
    #[allow(clippy::too_many_arguments)]
    pub fn with_dtype(
        n_layers: usize,
        n_kv: usize,
        page_size: usize,
        d_head: usize,
        capacity_pages: u64,
        sharing: bool,
        namespace: u64,
        dtype: KvDtype,
    ) -> Arc<PageAllocator> {
        let mode = if sharing { PrefixCacheMode::Resident } else { PrefixCacheMode::Off };
        PageAllocator::with_mode(
            n_layers,
            n_kv,
            page_size,
            d_head,
            capacity_pages,
            mode,
            0,
            namespace,
            dtype,
        )
    }

    /// Explicit prefix-cache mode and retention cap, with the default
    /// (sharded) lock layout. Use [`PageAllocator::with_mode_lock`]
    /// for the `--kv-lock` ablation.
    #[allow(clippy::too_many_arguments)]
    pub fn with_mode(
        n_layers: usize,
        n_kv: usize,
        page_size: usize,
        d_head: usize,
        capacity_pages: u64,
        mode: PrefixCacheMode,
        retain_cap_pages: u64,
        namespace: u64,
        dtype: KvDtype,
    ) -> Arc<PageAllocator> {
        PageAllocator::with_mode_lock(
            n_layers,
            n_kv,
            page_size,
            d_head,
            capacity_pages,
            mode,
            retain_cap_pages,
            namespace,
            dtype,
            KvLockMode::default(),
        )
    }

    /// The fully general constructor: explicit prefix-cache mode,
    /// retention cap (pages the retained tier may pin; 0 = bounded
    /// only by pool pressure), and lock layout.
    #[allow(clippy::too_many_arguments)]
    pub fn with_mode_lock(
        n_layers: usize,
        n_kv: usize,
        page_size: usize,
        d_head: usize,
        capacity_pages: u64,
        mode: PrefixCacheMode,
        retain_cap_pages: u64,
        namespace: u64,
        dtype: KvDtype,
        lock_mode: KvLockMode,
    ) -> Arc<PageAllocator> {
        let codec = PageCodec::new(dtype, n_kv, page_size, d_head);
        let shards = match lock_mode {
            KvLockMode::Sharded => (0..n_layers)
                .map(|_| Mutex::new(Shard { slabs: vec![LayerSlab::new()] }))
                .collect(),
            KvLockMode::Global => {
                vec![Mutex::new(Shard { slabs: (0..n_layers).map(|_| LayerSlab::new()).collect() })]
            }
        };
        Arc::new(PageAllocator {
            n_layers,
            n_kv,
            page_size,
            d_head,
            page_elems: codec.page_elems(),
            capacity_pages,
            codec,
            mode,
            lock_mode,
            retain_cap_pages,
            namespace,
            shards,
            meta: Mutex::new(Meta {
                prefix: HashMap::new(),
                used: 0,
                peak_used: 0,
                shared: 0,
                prefix_hits: 0,
                reservations: HashMap::new(),
                reserved: 0,
                gpu_used: 0,
                retained: HashMap::new(),
                clock: 0,
                retained_hits: 0,
                retained_evictions: 0,
            }),
            shard_locks: LockCounters::default(),
            meta_locks: LockCounters::default(),
            #[cfg(debug_assertions)]
            token_blocks: Mutex::new(HashMap::new()),
        })
    }

    /// f32 allocator for one model config, with the namespace derived
    /// from its identity so prefix keys never collide across models.
    pub fn for_model(
        cfg: &ModelConfig,
        capacity_pages: u64,
        sharing: bool,
    ) -> Arc<PageAllocator> {
        PageAllocator::for_model_dtype(cfg, capacity_pages, sharing, KvDtype::F32)
    }

    /// [`PageAllocator::for_model`] with an explicit page codec dtype.
    pub fn for_model_dtype(
        cfg: &ModelConfig,
        capacity_pages: u64,
        sharing: bool,
        dtype: KvDtype,
    ) -> Arc<PageAllocator> {
        let mode = if sharing { PrefixCacheMode::Resident } else { PrefixCacheMode::Off };
        PageAllocator::for_model_mode(cfg, capacity_pages, mode, 0, dtype)
    }

    /// [`PageAllocator::for_model_dtype`] with an explicit prefix-cache
    /// mode and retention cap, using the default (sharded) lock layout.
    pub fn for_model_mode(
        cfg: &ModelConfig,
        capacity_pages: u64,
        mode: PrefixCacheMode,
        retain_cap_pages: u64,
        dtype: KvDtype,
    ) -> Arc<PageAllocator> {
        PageAllocator::for_model_lock(
            cfg,
            capacity_pages,
            mode,
            retain_cap_pages,
            dtype,
            KvLockMode::default(),
        )
    }

    /// [`PageAllocator::for_model_mode`] with an explicit lock layout
    /// (the `--kv-lock` ablation); the namespace is derived from the
    /// model identity so prefix keys never collide across models.
    pub fn for_model_lock(
        cfg: &ModelConfig,
        capacity_pages: u64,
        mode: PrefixCacheMode,
        retain_cap_pages: u64,
        dtype: KvDtype,
        lock_mode: KvLockMode,
    ) -> Arc<PageAllocator> {
        let mut ns = FNV_OFFSET;
        for b in cfg.name.bytes() {
            ns = fnv1a_i32(ns, b as i32);
        }
        for v in [cfg.n_layers, cfg.n_kv, cfg.d_head, cfg.page_size, cfg.max_context] {
            ns = fnv1a_i32(ns, v as i32);
        }
        PageAllocator::with_mode_lock(
            cfg.n_layers,
            cfg.n_kv,
            cfg.page_size,
            cfg.d_head,
            capacity_pages,
            mode,
            retain_cap_pages,
            ns,
            dtype,
            lock_mode,
        )
    }

    /// Is copy-on-write prefix sharing enabled on this allocator?
    pub fn sharing(&self) -> bool {
        self.mode.sharing()
    }

    /// The prefix-cache operating mode.
    pub fn prefix_mode(&self) -> PrefixCacheMode {
        self.mode
    }

    /// The lock layout (`--kv-lock`).
    pub fn lock_mode(&self) -> KvLockMode {
        self.lock_mode
    }

    /// Number of slab shards (one per layer when sharded, one total
    /// when global).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Element dtype of every page in this pool.
    pub fn dtype(&self) -> KvDtype {
        self.codec.dtype
    }

    /// The page codec (dtype + geometry) governing the slab stride.
    pub fn codec(&self) -> PageCodec {
        self.codec
    }

    /// Encoded bytes of one page (all kv heads, K+V): codec payload
    /// stride plus the 2-byte-per-region scale sidecar.
    pub fn page_bytes(&self) -> usize {
        self.codec.page_bytes()
    }

    /// Payload bytes of one page, excluding the scale sidecar.
    fn payload_stride(&self) -> usize {
        self.codec.payload_bytes()
    }

    /// Scale entries of one page.
    fn scale_stride(&self) -> usize {
        self.codec.scales_per_page()
    }

    /// Which shard a layer's slab lives in, and the slab index within
    /// that shard.
    fn shard_of(&self, layer: usize) -> (usize, usize) {
        match self.lock_mode {
            KvLockMode::Sharded => (layer, 0),
            KvLockMode::Global => (0, layer),
        }
    }

    fn lock_meta(&self) -> MetaGuard<'_> {
        #[cfg(debug_assertions)]
        let order = lock_order::MetaToken::acquire();
        let g = lock_timed(&self.meta, &self.meta_locks, Meta::poison_audit, "metadata");
        MetaGuard {
            g,
            #[cfg(debug_assertions)]
            _order: order,
        }
    }

    fn lock_shard(&self, shard: usize) -> ShardGuard<'_> {
        #[cfg(debug_assertions)]
        let order = lock_order::ShardToken::acquire();
        let g = lock_timed(
            &self.shards[shard],
            &self.shard_locks,
            |s: &Shard| s.slabs.iter().all(LayerSlab::poison_audit),
            "shard",
        );
        ShardGuard {
            g,
            #[cfg(debug_assertions)]
            _order: order,
        }
    }

    /// Fault-injection hook: panic *while holding* the metadata lock,
    /// poisoning the mutex exactly the way a crashed critical section
    /// would. Exists so chaos tests (`FaultSite::AllocPanic`) exercise
    /// the poison-recovery path end to end.
    pub fn panic_while_locked(&self, msg: &str) -> ! {
        let _guard = self.lock_meta();
        panic!("injected allocator fault: {}", msg);
    }

    /// Fault-injection hook targeting one *shard* lock (index taken
    /// modulo the shard count, so chaos schedules written for sharded
    /// mode also run under `--kv-lock=global`).
    pub fn panic_while_locked_shard(&self, shard: usize, msg: &str) -> ! {
        let idx = shard % self.shards.len();
        let _guard = self.lock_shard(idx);
        panic!("injected allocator fault: {} (shard {})", msg, idx);
    }

    fn prefix_key(&self, layer: usize, layout: Layout, hash: u128) -> PrefixKey {
        let ns = fold_key(self.namespace, self.namespace.rotate_left(17));
        PrefixKey {
            layer: layer as u32,
            hnd: matches!(layout, Layout::Hnd),
            dtype: self.codec.dtype,
            hash: hash ^ ns,
        }
    }

    // ------------------------------------------------------------------
    // Slot lifecycle (used by LayerPool views)
    // ------------------------------------------------------------------

    /// Pop or grow a slot inside one slab: the shard-local half of an
    /// allocation. Asserts *before* mutating refcounts so a violated
    /// invariant poisons nothing it has touched.
    fn alloc_in_slab(slab: &mut LayerSlab, layer: usize, ps: usize, ss: usize) -> Slot {
        let slot = match slab.free.pop() {
            Some(s) => s,
            None => {
                let s = slab.refcnt.len() as Slot;
                slab.data.resize((s as usize + 1) * ps, 0);
                slab.scales.resize((s as usize + 1) * ss, 0);
                slab.refcnt.push(0);
                slab.written.push(false);
                slab.key.push(None);
                slab.hits.push(0);
                slab.gen.push(0);
                s
            }
        };
        let i = slot as usize;
        assert_eq!(slab.refcnt[i], 0, "allocating a live slot {} (layer {})", slot, layer);
        slab.refcnt[i] = 1;
        slab.written[i] = false;
        slab.key[i] = None;
        slab.hits[i] = 0;
        slab.gen[i] = slab.gen[i].wrapping_add(1);
        slot
    }

    /// Physically free a refcount-0 slot: clear its commit bit and
    /// popularity, drop its prefix registration, and recycle it.
    fn free_slot_locked(meta: &mut Meta, slab: &mut LayerSlab, layer: usize, slot: Slot) {
        let i = slot as usize;
        debug_assert_eq!(slab.refcnt[i], 0, "freeing a live slot {} (layer {})", slot, layer);
        slab.written[i] = false;
        slab.hits[i] = 0;
        slab.gen[i] = slab.gen[i].wrapping_add(1);
        if let Some(k) = slab.key[i].take() {
            if meta.prefix.get(&k) == Some(&slot) {
                meta.prefix.remove(&k);
            }
        }
        slab.free.push(slot);
        meta.used -= 1;
    }

    /// Evict up to `n` retained pages in ascending
    /// (popularity, recency) order — least-adopted first, ties broken
    /// by least-recently-retained (the retention clock is unique per
    /// entry, so the victim order is deterministic). Returns how many
    /// pages were actually evicted. Caller holds the metadata lock and
    /// **no shard lock**: each victim's shard is taken briefly in turn.
    fn evict_retained_locked(&self, meta: &mut Meta, n: usize) -> usize {
        let mut evicted = 0;
        while evicted < n {
            let victim =
                meta.retained.iter().min_by_key(|(_, &score)| score).map(|(&key, _)| key);
            let Some((layer, slot)) = victim else { break };
            meta.retained.remove(&(layer, slot));
            let (si, li) = self.shard_of(layer as usize);
            {
                let mut shard = self.lock_shard(si);
                Self::free_slot_locked(meta, &mut shard.slabs[li], layer as usize, slot);
            }
            meta.retained_evictions += 1;
            evicted += 1;
        }
        evicted
    }

    /// Bump an adoptable slot's refcount, reviving it from the
    /// retained tier when its last live reference is already gone, and
    /// record the popularity hit either way.
    fn adopt_slot_locked(&self, meta: &mut Meta, slab: &mut LayerSlab, layer: usize, slot: Slot) {
        let i = slot as usize;
        if meta.retained.remove(&(layer as u32, slot)).is_some() {
            debug_assert_eq!(
                slab.refcnt[i],
                0,
                "retained slot {} (layer {}) with a live refcount",
                slot,
                layer
            );
            slab.refcnt[i] = 1;
            meta.retained_hits += 1;
        } else {
            assert!(slab.refcnt[i] > 0, "retain of a free slot {} (layer {})", slot, layer);
            slab.refcnt[i] += 1;
            if slab.refcnt[i] == 2 {
                meta.shared += 1;
            }
        }
        slab.hits[i] = slab.hits[i].saturating_add(1);
        meta.prefix_hits += 1;
    }

    pub(crate) fn alloc_slot(&self, layer: usize) -> Slot {
        let mut meta = self.lock_meta();
        // Pool pressure: the retained tier is reclaimable capacity.
        // Before growing past the configured page budget, evict the
        // coldest retained (refcount-0) page — live pages are never
        // evicted, so an admitted request's footprint always fits
        // (live pages <= reservations <= capacity). Eviction happens
        // before taking the target shard: the victim may live in any
        // shard, and shard locks never nest.
        if self.capacity_pages > 0 && meta.used >= self.capacity_pages {
            self.evict_retained_locked(&mut meta, 1);
        }
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        let (si, li) = self.shard_of(layer);
        let mut shard = self.lock_shard(si);
        let slot = Self::alloc_in_slab(&mut shard.slabs[li], layer, ps, ss);
        meta.used += 1;
        meta.peak_used = meta.peak_used.max(meta.used);
        slot
    }

    pub(crate) fn release_slot(&self, layer: usize, slot: Slot) {
        let mut meta = self.lock_meta();
        let i = slot as usize;
        let (si, li) = self.shard_of(layer);
        let hits;
        {
            let mut shard = self.lock_shard(si);
            let slab = &mut shard.slabs[li];
            assert!(slab.refcnt[i] > 0, "double free of slot {} (layer {})", slot, layer);
            slab.refcnt[i] -= 1;
            if slab.refcnt[i] == 1 {
                meta.shared -= 1;
            }
            if slab.refcnt[i] != 0 {
                return;
            }
            // Last reference dropped. In retained mode a committed,
            // prefix-registered page enters the retained tier (still
            // registered, still counted in `used`) instead of freeing;
            // anything unwritten or never registered frees as before.
            let retainable = self.mode.retention() && slab.written[i] && slab.key[i].is_some();
            if !retainable {
                Self::free_slot_locked(&mut meta, slab, layer, slot);
                return;
            }
            hits = slab.hits[i];
        }
        // Retain. A cap-displacement eviction may target any shard, so
        // it runs with no shard lock held.
        if self.retain_cap_pages > 0 && meta.retained.len() as u64 >= self.retain_cap_pages {
            self.evict_retained_locked(&mut meta, 1);
        }
        meta.clock += 1;
        let clock = meta.clock;
        meta.retained.insert((layer as u32, slot), (hits, clock));
    }

    /// CoW: return a slot holding the same encoded bytes (payload and
    /// scales) that is safe to write (refcount 1). Aliased slots get a
    /// private copy; a page that is already private only sheds its
    /// stale prefix registration (its content is about to change).
    pub(crate) fn make_unique(&self, layer: usize, slot: Slot) -> Slot {
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        let i = slot as usize;
        let (si, li) = self.shard_of(layer);
        let mut meta = self.lock_meta();
        {
            let mut shard = self.lock_shard(si);
            let slab = &mut shard.slabs[li];
            if slab.refcnt[i] == 1 {
                if let Some(k) = slab.key[i].take() {
                    if meta.prefix.get(&k) == Some(&slot) {
                        meta.prefix.remove(&k);
                    }
                }
                return slot;
            }
        }
        // Aliased: allocate a private copy. Holding the metadata lock
        // freezes refcounts, so dropping and re-taking the shard lock
        // around the capacity eviction cannot race the alias away.
        if self.capacity_pages > 0 && meta.used >= self.capacity_pages {
            self.evict_retained_locked(&mut meta, 1);
        }
        let mut shard = self.lock_shard(si);
        let slab = &mut shard.slabs[li];
        let fresh = Self::alloc_in_slab(slab, layer, ps, ss);
        meta.used += 1;
        meta.peak_used = meta.peak_used.max(meta.used);
        let src = i * ps;
        slab.data.copy_within(src..src + ps, fresh as usize * ps);
        if ss > 0 {
            let ssrc = i * ss;
            slab.scales.copy_within(ssrc..ssrc + ss, fresh as usize * ss);
        }
        slab.written[fresh as usize] = slab.written[i];
        // Release the alias we cloned from: its refcount is >= 2 here,
        // so this never frees or retains — just the decrement.
        slab.refcnt[i] -= 1;
        if slab.refcnt[i] == 1 {
            meta.shared -= 1;
        }
        fresh
    }

    pub(crate) fn slot_written(&self, layer: usize, slot: Slot) -> bool {
        let (si, li) = self.shard_of(layer);
        self.lock_shard(si).slabs[li].written[slot as usize]
    }

    pub(crate) fn set_written(&self, layer: usize, slot: Slot) {
        let (si, li) = self.shard_of(layer);
        self.lock_shard(si).slabs[li].written[slot as usize] = true;
    }

    /// Read a slot's encoded payload and scale sidecar under the shard
    /// lock. Cold-path reads only — the hot gather path snapshots via
    /// [`PageAllocator::snapshot_slot_ranges`] and decodes outside the
    /// lock.
    pub(crate) fn read_slot<R>(
        &self,
        layer: usize,
        slot: Slot,
        f: impl FnOnce(&[u8], &[u16]) -> R,
    ) -> R {
        let (si, li) = self.shard_of(layer);
        let shard = self.lock_shard(si);
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        let base = slot as usize * ps;
        let sbase = slot as usize * ss;
        let slab = &shard.slabs[li];
        f(&slab.data[base..base + ps], &slab.scales[sbase..sbase + ss])
    }

    /// Write a slot's encoded payload and scale sidecar under the shard
    /// lock. The slot must be private (`make_unique` first): writing a
    /// shared slot would leak through every alias. Bumps the slot
    /// generation. Cold-path writes only — the hot offload path encodes
    /// outside the lock and installs via
    /// [`PageAllocator::write_slot_encoded`].
    pub(crate) fn write_slot<R>(
        &self,
        layer: usize,
        slot: Slot,
        f: impl FnOnce(&mut [u8], &mut [u16]) -> R,
    ) -> R {
        let (si, li) = self.shard_of(layer);
        let mut shard = self.lock_shard(si);
        let slab = &mut shard.slabs[li];
        let i = slot as usize;
        assert_eq!(
            slab.refcnt[i],
            1,
            "writing a shared slot {} (layer {}) — make_unique first",
            slot,
            layer
        );
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        let base = i * ps;
        let sbase = i * ss;
        slab.gen[i] = slab.gen[i].wrapping_add(1);
        let (data, scales) = (&mut slab.data, &mut slab.scales);
        f(&mut data[base..base + ps], &mut scales[sbase..sbase + ss])
    }

    /// Install pre-encoded page bytes into a private slot: the
    /// copy-outside-critical-section write path. The caller encodes
    /// (quantize + transpose) into scratch with no lock held; the
    /// critical section is two memcpys and a generation bump.
    pub(crate) fn write_slot_encoded(
        &self,
        layer: usize,
        slot: Slot,
        payload: &[u8],
        scales: &[u16],
    ) {
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        debug_assert_eq!(payload.len(), ps);
        debug_assert_eq!(scales.len(), ss);
        let (si, li) = self.shard_of(layer);
        let mut shard = self.lock_shard(si);
        let slab = &mut shard.slabs[li];
        let i = slot as usize;
        assert_eq!(
            slab.refcnt[i],
            1,
            "writing a shared slot {} (layer {}) — make_unique first",
            slot,
            layer
        );
        slab.data[i * ps..i * ps + ps].copy_from_slice(payload);
        slab.scales[i * ss..i * ss + ss].copy_from_slice(scales);
        slab.gen[i] = slab.gen[i].wrapping_add(1);
    }

    /// Snapshot selected byte ranges of a slot's encoded payload (plus
    /// the full scale sidecar) into caller scratch under the shard
    /// lock, returning the slot generation observed. The caller
    /// decodes outside the lock and re-checks the generation with
    /// [`PageAllocator::slot_generation`]; a mismatch means the slot
    /// was mutated concurrently and the snapshot must be retried.
    /// `ranges` are `(byte offset within the page payload, byte len)`.
    pub(crate) fn snapshot_slot_ranges(
        &self,
        layer: usize,
        slot: Slot,
        ranges: &[(usize, usize)],
        payload_out: &mut Vec<u8>,
        scales_out: &mut Vec<u16>,
    ) -> u64 {
        let (ps, ss) = (self.payload_stride(), self.scale_stride());
        let (si, li) = self.shard_of(layer);
        let shard = self.lock_shard(si);
        let slab = &shard.slabs[li];
        let base = slot as usize * ps;
        let sbase = slot as usize * ss;
        payload_out.clear();
        for &(off, len) in ranges {
            debug_assert!(off + len <= ps, "snapshot range beyond the page payload");
            payload_out.extend_from_slice(&slab.data[base + off..base + off + len]);
        }
        scales_out.clear();
        scales_out.extend_from_slice(&slab.scales[sbase..sbase + ss]);
        slab.gen[slot as usize]
    }

    /// Current generation of a slot (see
    /// [`PageAllocator::snapshot_slot_ranges`]).
    pub(crate) fn slot_generation(&self, layer: usize, slot: Slot) -> u64 {
        let (si, li) = self.shard_of(layer);
        self.lock_shard(si).slabs[li].gen[slot as usize]
    }

    // ------------------------------------------------------------------
    // Prefix sharing
    // ------------------------------------------------------------------

    /// Alias a committed page whose prefix key matches, bumping its
    /// refcount (reviving it from the retained tier if its last live
    /// reference is gone). `None` when sharing is off or no match.
    pub(crate) fn adopt(&self, layer: usize, layout: Layout, hash: u128) -> Option<Slot> {
        if !self.sharing() {
            return None;
        }
        let key = self.prefix_key(layer, layout, hash);
        let mut meta = self.lock_meta();
        let slot = *meta.prefix.get(&key)?;
        let (si, li) = self.shard_of(layer);
        let mut shard = self.lock_shard(si);
        if !shard.slabs[li].written[slot as usize] {
            return None;
        }
        self.adopt_slot_locked(&mut meta, &mut shard.slabs[li], layer, slot);
        Some(slot)
    }

    /// Atomically adopt the page behind `hash` across *all* layers —
    /// the longest-common-prefix path adopts whole cross-layer pages
    /// or nothing (a page resident in only some layers would leave a
    /// request half-prefilled). Returns one slot per layer on a full
    /// hit; on any miss the allocator is left untouched.
    ///
    /// Atomicity without holding every shard at once: the metadata
    /// lock freezes refcounts and both maps for the whole walk, and
    /// `written` can only flip false -> true (commit) while it is
    /// held, so a slot validated in the first ascending pass is still
    /// valid when the second pass adopts it.
    pub(crate) fn adopt_stack(&self, layout: Layout, hash: u128) -> Option<Vec<Slot>> {
        if !self.sharing() {
            return None;
        }
        let mut meta = self.lock_meta();
        let mut slots = Vec::with_capacity(self.n_layers);
        for layer in 0..self.n_layers {
            let key = self.prefix_key(layer, layout, hash);
            let slot = *meta.prefix.get(&key)?;
            let (si, li) = self.shard_of(layer);
            let shard = self.lock_shard(si);
            if !shard.slabs[li].written[slot as usize] {
                return None;
            }
            slots.push(slot);
        }
        for (layer, &slot) in slots.iter().enumerate() {
            let (si, li) = self.shard_of(layer);
            let mut shard = self.lock_shard(si);
            self.adopt_slot_locked(&mut meta, &mut shard.slabs[li], layer, slot);
        }
        Some(slots)
    }

    /// Drop every retained (refcount-0) page, returning the pool to a
    /// live-pages-only baseline. Counts into `retained_evictions`.
    /// Exposed for tests and cache-flush tooling; live pages are
    /// untouched.
    pub fn drop_retained(&self) -> u64 {
        let mut meta = self.lock_meta();
        let n = meta.retained.len();
        self.evict_retained_locked(&mut meta, n) as u64
    }

    /// Record and cross-check the exact token block behind a boundary
    /// hash (debug builds only; release builds compile this away).
    ///
    /// Chain hashes are FNV-1a + splitmix — fast, not cryptographic —
    /// and the retained tier widens the collision window from "pages
    /// of currently resident requests" to the whole cache lifetime.
    /// Debug and test builds therefore keep a `hash -> token block`
    /// oracle: the first time two *different* token blocks produce the
    /// same chain hash, this assertion fires at hash-record time
    /// (before any adoption can alias the wrong page). The trust model
    /// is documented in `ARCHITECTURE.md`.
    pub fn verify_token_block(&self, hash: u128, tokens: &[i32]) {
        #[cfg(debug_assertions)]
        {
            if !self.sharing() {
                return;
            }
            let mut map = crate::util::sync::lock_unpoisoned(&self.token_blocks);
            // bound debug-build memory; the oracle is best-effort
            if map.len() >= (1 << 16) && !map.contains_key(&hash) {
                map.clear();
            }
            match map.entry(hash) {
                Entry::Vacant(e) => {
                    e.insert(tokens.to_vec());
                }
                Entry::Occupied(e) => {
                    assert_eq!(
                        e.get().as_slice(),
                        tokens,
                        "prefix-hash collision: two distinct token blocks share chain hash {:#034x}",
                        hash
                    );
                }
            }
        }
        #[cfg(not(debug_assertions))]
        let _ = (hash, tokens);
    }

    /// Register a freshly written page under its prefix key (first
    /// writer wins; in resident mode the registration dies with the
    /// slot, in retained mode it survives into the retained tier).
    pub(crate) fn register_prefix(&self, layer: usize, layout: Layout, hash: u128, slot: Slot) {
        if !self.sharing() {
            return;
        }
        let key = self.prefix_key(layer, layout, hash);
        let mut meta = self.lock_meta();
        if let Entry::Vacant(e) = meta.prefix.entry(key) {
            e.insert(slot);
            let (si, li) = self.shard_of(layer);
            let mut shard = self.lock_shard(si);
            shard.slabs[li].key[slot as usize] = Some(key);
        }
    }

    // ------------------------------------------------------------------
    // Admission ledger
    // ------------------------------------------------------------------

    /// Charge `pages` (a worst-case footprint) against the pool for
    /// request `id`. `Wait` leaves no reservation behind; `Admit` must
    /// be paired with [`PageAllocator::release_reservation`].
    ///
    /// Retained pages are deliberately *not* counted against the
    /// ledger: they are reclaimable capacity. Admission only weighs
    /// live reservations, and when an admitted request later allocates
    /// into a full pool the allocator evicts the coldest retained page
    /// to make room — reservations may evict retained pages but never
    /// live ones, so `Wait => progress` is preserved exactly as
    /// without the retained tier.
    pub fn try_reserve(&self, id: u64, pages: u64) -> AdmitDecision {
        let mut meta = self.lock_meta();
        if self.capacity_pages > 0 {
            if pages > self.capacity_pages {
                return AdmitDecision::Never;
            }
            if meta.reserved + pages > self.capacity_pages {
                return AdmitDecision::Wait;
            }
        }
        if let Some(old) = meta.reservations.insert(id, pages) {
            meta.reserved -= old;
        }
        meta.reserved += pages;
        AdmitDecision::Admit
    }

    /// Release request `id`'s reservation (idempotent).
    pub fn release_reservation(&self, id: u64) {
        let mut meta = self.lock_meta();
        if let Some(pages) = meta.reservations.remove(&id) {
            meta.reserved -= pages;
        }
    }

    // ------------------------------------------------------------------
    // GPU-budget ledger
    // ------------------------------------------------------------------

    /// Add `bytes` to the GPU-resident KV usage gauge.
    pub fn charge_gpu(&self, bytes: usize) {
        self.lock_meta().gpu_used += bytes as u64;
    }

    /// Subtract `bytes` from the GPU-resident KV usage gauge (saturating).
    pub fn release_gpu(&self, bytes: usize) {
        let mut meta = self.lock_meta();
        meta.gpu_used = meta.gpu_used.saturating_sub(bytes as u64);
    }

    /// Full cross-lock invariant audit, for tests and chaos recovery
    /// checks: refcount/`used`/`shared` accounting, free-list health,
    /// retained-tier consistency, and ledger balance. Panics with a
    /// description on the first violation. Only meaningful while no
    /// other thread is mid-operation (the audit takes the metadata
    /// lock, which freezes lifecycle state, then walks shards in
    /// ascending order).
    pub fn audit_invariants(&self) {
        let meta = self.lock_meta();
        let mut live = 0u64;
        let mut shared = 0u64;
        for si in 0..self.shards.len() {
            let shard = self.lock_shard(si);
            for slab in &shard.slabs {
                for &r in &slab.refcnt {
                    if r > 0 {
                        live += 1;
                    }
                    if r >= 2 {
                        shared += 1;
                    }
                }
                assert!(
                    slab.free.iter().all(|&s| slab.refcnt[s as usize] == 0),
                    "free-list slot with a live refcount"
                );
            }
        }
        for &(layer, slot) in meta.retained.keys() {
            let (si, li) = self.shard_of(layer as usize);
            let shard = self.lock_shard(si);
            let slab = &shard.slabs[li];
            let i = slot as usize;
            assert!(
                slab.refcnt[i] == 0 && slab.written[i] && slab.key[i].is_some(),
                "retained page {} (layer {}) is not a committed, registered, refcount-0 page",
                slot,
                layer
            );
        }
        assert_eq!(
            live + meta.retained.len() as u64,
            meta.used,
            "live + retained pages disagree with `used`"
        );
        assert_eq!(shared, meta.shared, "aliased-slot count disagrees with `shared`");
        assert_eq!(
            meta.reservations.values().sum::<u64>(),
            meta.reserved,
            "reservation ledger out of balance"
        );
    }

    /// Snapshot of the pool gauges.
    pub fn stats(&self) -> KvPoolStats {
        let meta = self.lock_meta();
        KvPoolStats {
            pages_capacity: self.capacity_pages,
            pages_used: meta.used,
            pages_peak: meta.peak_used,
            pages_shared: meta.shared,
            pages_reserved: meta.reserved,
            prefix_hits: meta.prefix_hits,
            cpu_bytes_used: meta.used * self.page_bytes() as u64,
            cpu_bytes_peak: meta.peak_used * self.page_bytes() as u64,
            gpu_bytes_used: meta.gpu_used,
            pages_retained: meta.retained.len() as u64,
            retained_hits: meta.retained_hits,
            retained_evictions: meta.retained_evictions,
            bytes_saved: meta.prefix_hits * self.page_bytes() as u64,
            shard_lock_acqs: self.shard_locks.acquisitions.load(Ordering::Relaxed),
            shard_lock_waits: self.shard_locks.waits.load(Ordering::Relaxed),
            shard_lock_wait_secs: self.shard_locks.wait_nanos.load(Ordering::Relaxed) as f64
                * 1e-9,
            meta_lock_acqs: self.meta_locks.acquisitions.load(Ordering::Relaxed),
            meta_lock_waits: self.meta_locks.waits.load(Ordering::Relaxed),
            meta_lock_wait_secs: self.meta_locks.wait_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}
#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_alloc(capacity: u64, sharing: bool) -> Arc<PageAllocator> {
        PageAllocator::new(2, 2, 4, 8, capacity, sharing, 0xABCD)
    }

    fn tiny_retained(capacity: u64, retain_cap: u64) -> Arc<PageAllocator> {
        PageAllocator::with_mode(
            2,
            2,
            4,
            8,
            capacity,
            PrefixCacheMode::Retained,
            retain_cap,
            0xABCD,
            KvDtype::F32,
        )
    }

    /// Allocate, commit, and register one page under `hash`.
    fn committed_page(a: &PageAllocator, layer: usize, hash: u128, fill: u8) -> Slot {
        let s = a.alloc_slot(layer);
        a.write_slot(layer, s, |buf, _| buf.iter_mut().for_each(|x| *x = fill));
        a.set_written(layer, s);
        a.register_prefix(layer, Layout::Hnd, hash, s);
        s
    }

    #[test]
    fn retained_pages_survive_release_and_revive_on_adopt() {
        let a = tiny_retained(0, 0);
        let s = committed_page(&a, 0, 42, 7);
        a.release_slot(0, s);
        let st = a.stats();
        assert_eq!(st.pages_used, 1, "retained page still counts as used");
        assert_eq!(st.pages_retained, 1);
        assert_eq!(st.retained_hits, 0);
        // content is still adoptable after the last view died
        let got = a.adopt(0, Layout::Hnd, 42).expect("retained page revives");
        assert_eq!(got, s);
        a.read_slot(0, got, |buf, _| assert!(buf.iter().all(|&x| x == 7)));
        let st = a.stats();
        assert_eq!(st.pages_retained, 0, "revived page left the retained tier");
        assert_eq!(st.retained_hits, 1);
        assert_eq!(st.prefix_hits, 1);
        a.release_slot(0, got);
        assert_eq!(a.stats().pages_retained, 1, "retires back into the tier");
        assert_eq!(a.drop_retained(), 1);
        let st = a.stats();
        assert_eq!(st.pages_used, 0, "cache drop returns the pool to baseline");
        assert_eq!(st.pages_retained, 0);
        assert!(a.adopt(0, Layout::Hnd, 42).is_none(), "registration died with eviction");
    }

    #[test]
    fn resident_mode_never_retains() {
        let a = tiny_alloc(0, true);
        let s = committed_page(&a, 0, 42, 7);
        a.release_slot(0, s);
        let st = a.stats();
        assert_eq!(st.pages_used, 0);
        assert_eq!(st.pages_retained, 0);
        assert!(a.adopt(0, Layout::Hnd, 42).is_none());
    }

    #[test]
    fn uncommitted_or_unregistered_pages_free_instead_of_retaining() {
        let a = tiny_retained(0, 0);
        let plain = a.alloc_slot(0); // never written, never registered
        let written = a.alloc_slot(0);
        a.set_written(0, written); // written but never registered
        a.release_slot(0, plain);
        a.release_slot(0, written);
        let st = a.stats();
        assert_eq!(st.pages_used, 0);
        assert_eq!(st.pages_retained, 0);
    }

    #[test]
    fn pool_pressure_evicts_least_popular_then_least_recent() {
        // capacity 3: three retained pages fill the pool; page B is the
        // most popular (adopted once), A and C never were; A was
        // retained before C. Under allocation pressure the victims go
        // A (cold, oldest) then C (cold, newer) then B.
        let a = tiny_retained(3, 0);
        let sa = committed_page(&a, 0, 1, 1);
        let sb = committed_page(&a, 0, 2, 2);
        let sc = committed_page(&a, 0, 3, 3);
        a.release_slot(0, sa); // A: cold, retained first
        a.release_slot(0, sb);
        let rb = a.adopt(0, Layout::Hnd, 2).expect("b revives"); // B: 1 hit
        a.release_slot(0, rb); // B: popular
        a.release_slot(0, sc); // C: cold, retained last
        assert_eq!(a.stats().pages_retained, 3);
        // each allocation at capacity reclaims exactly one page; a
        // failed adopt probe (`None`) is side-effect free, so the
        // eviction order is observable page by page
        let n1 = a.alloc_slot(1);
        assert!(a.adopt(0, Layout::Hnd, 1).is_none(), "cold oldest A evicted first");
        assert_eq!(a.stats().pages_retained, 2);
        let n2 = a.alloc_slot(1);
        assert!(a.adopt(0, Layout::Hnd, 3).is_none(), "cold newer C evicted second");
        let n3 = a.alloc_slot(1);
        assert!(a.adopt(0, Layout::Hnd, 2).is_none(), "popular B evicted last");
        let st = a.stats();
        assert_eq!(st.retained_evictions, 3);
        assert_eq!(st.pages_used, 3, "pool never exceeded capacity");
        for s in [n1, n2, n3] {
            a.release_slot(1, s);
        }
        assert_eq!(a.stats().pages_used, 0);
    }

    #[test]
    fn retention_cap_bounds_the_tier() {
        let a = tiny_retained(0, 2);
        for hash in [10u128, 11, 12] {
            let s = committed_page(&a, 0, hash, hash as u8);
            a.release_slot(0, s);
        }
        let st = a.stats();
        assert_eq!(st.pages_retained, 2, "cap holds the tier at 2");
        assert_eq!(st.retained_evictions, 1);
        assert!(a.adopt(0, Layout::Hnd, 10).is_none(), "oldest page evicted at cap");
        a.drop_retained();
        assert_eq!(a.stats().pages_used, 0);
    }

    #[test]
    fn adopt_stack_is_all_or_nothing_across_layers() {
        let a = tiny_retained(0, 0);
        // hash 5 committed in both layers; hash 6 only in layer 0
        let s0 = committed_page(&a, 0, 5, 1);
        let s1 = committed_page(&a, 1, 5, 2);
        let s2 = committed_page(&a, 0, 6, 3);
        for (l, s) in [(0, s0), (1, s1), (0, s2)] {
            a.release_slot(l, s);
        }
        let before = a.stats();
        assert!(a.adopt_stack(Layout::Hnd, 6).is_none(), "layer-1 miss adopts nothing");
        let after = a.stats();
        assert_eq!(before.prefix_hits, after.prefix_hits, "failed stack adopt left no trace");
        assert_eq!(after.pages_retained, 3);
        let slots = a.adopt_stack(Layout::Hnd, 5).expect("full-stack hit");
        assert_eq!(slots, vec![s0, s1]);
        assert_eq!(a.stats().retained_hits, 2);
        for (l, s) in slots.into_iter().enumerate() {
            a.release_slot(l, s);
        }
        a.drop_retained();
        assert_eq!(a.stats().pages_used, 0);
    }

    #[test]
    fn reservations_may_evict_retained_but_never_live_pages() {
        // capacity 4; a retired request left 4 retained pages. A new
        // reservation for the whole pool still admits (retained pages
        // are reclaimable), and its allocations evict them one by one.
        let a = tiny_retained(4, 0);
        let mut retained = Vec::new();
        for h in 0..4u128 {
            retained.push(committed_page(&a, 0, 100 + h, h as u8));
        }
        for s in retained {
            a.release_slot(0, s);
        }
        assert_eq!(a.stats().pages_retained, 4);
        assert_eq!(a.try_reserve(1, 4), AdmitDecision::Admit, "retained pages don't block");
        let mut live = Vec::new();
        for _ in 0..4 {
            live.push(a.alloc_slot(1));
        }
        let st = a.stats();
        assert_eq!(st.pages_used, 4, "pool stayed at capacity");
        assert_eq!(st.pages_retained, 0, "all retained pages were reclaimed");
        assert_eq!(st.retained_evictions, 4);
        for s in live {
            a.release_slot(1, s);
        }
        a.release_reservation(1);
        assert_eq!(a.stats().pages_used, 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "prefix-hash collision")]
    fn token_block_oracle_catches_collisions() {
        let a = tiny_retained(0, 0);
        a.verify_token_block(99, &[1, 2, 3, 4]);
        a.verify_token_block(99, &[1, 2, 3, 5]);
    }

    #[test]
    fn token_block_oracle_accepts_consistent_rehashes() {
        let a = tiny_retained(0, 0);
        a.verify_token_block(99, &[1, 2, 3, 4]);
        a.verify_token_block(99, &[1, 2, 3, 4]);
    }

    #[test]
    fn slots_recycle_and_stats_track_usage() {
        let a = tiny_alloc(0, false);
        let s0 = a.alloc_slot(0);
        let s1 = a.alloc_slot(0);
        let s2 = a.alloc_slot(1);
        assert_eq!(a.stats().pages_used, 3);
        a.release_slot(0, s0);
        assert_eq!(a.stats().pages_used, 2);
        let s3 = a.alloc_slot(0);
        assert_eq!(s3, s0, "freed slot is recycled");
        a.release_slot(0, s1);
        a.release_slot(0, s3);
        a.release_slot(1, s2);
        let st = a.stats();
        assert_eq!(st.pages_used, 0);
        assert_eq!(st.pages_peak, 3);
        assert_eq!(st.cpu_bytes_used, 0);
        assert_eq!(st.cpu_bytes_peak, 3 * a.page_bytes() as u64);
    }

    #[test]
    fn page_bytes_scale_with_the_codec() {
        let elems = 2 * 2 * 4 * 8; // n_kv * 2 * p * d
        let f = PageAllocator::with_dtype(1, 2, 4, 8, 0, false, 0, KvDtype::F32);
        let i8a = PageAllocator::with_dtype(1, 2, 4, 8, 0, false, 0, KvDtype::Int8);
        let i4a = PageAllocator::with_dtype(1, 2, 4, 8, 0, false, 0, KvDtype::Int4);
        assert_eq!(f.page_bytes(), elems * 4);
        assert_eq!(i8a.page_bytes(), elems + 4 * 2); // payload + 4 bf16 scales
        assert_eq!(i4a.page_bytes(), elems / 2 + 4 * 2);
        // the acceptance ratio: int8 pool bytes <= ~30% of f32 at equal pages
        assert!(i8a.page_bytes() * 100 <= f.page_bytes() * 30);
        for a in [&f, &i8a, &i4a] {
            let s = a.alloc_slot(0);
            assert_eq!(a.stats().cpu_bytes_used, a.page_bytes() as u64);
            a.release_slot(0, s);
        }
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_is_loud() {
        let a = tiny_alloc(0, false);
        let s = a.alloc_slot(0);
        a.release_slot(0, s);
        a.release_slot(0, s);
    }

    #[test]
    fn cow_gives_a_private_copy() {
        let a = tiny_alloc(0, true);
        let s = a.alloc_slot(0);
        a.write_slot(0, s, |buf, _| buf.iter_mut().for_each(|x| *x = 7));
        a.set_written(0, s);
        a.register_prefix(0, Layout::Hnd, 42, s);
        let adopted = a.adopt(0, Layout::Hnd, 42).expect("registered page adopts");
        assert_eq!(adopted, s);
        assert_eq!(a.stats().pages_shared, 1);
        // write through the adopting view: must materialize privately
        let fresh = a.make_unique(0, adopted);
        assert_ne!(fresh, s, "shared slot must not be written in place");
        a.write_slot(0, fresh, |buf, _| buf.iter_mut().for_each(|x| *x = 255));
        a.read_slot(0, s, |buf, _| assert!(buf.iter().all(|&x| x == 7), "original mutated"));
        a.read_slot(0, fresh, |buf, _| assert!(buf.iter().all(|&x| x == 255)));
        assert_eq!(a.stats().pages_shared, 0);
        a.release_slot(0, fresh);
        a.release_slot(0, s);
        assert_eq!(a.stats().pages_used, 0);
        // the registration died with the slot
        assert!(a.adopt(0, Layout::Hnd, 42).is_none());
    }

    #[test]
    fn cow_copies_the_scale_sidecar_too() {
        let a = PageAllocator::with_dtype(1, 2, 4, 8, 0, true, 0, KvDtype::Int8);
        let s = a.alloc_slot(0);
        a.write_slot(0, s, |buf, scales| {
            buf.iter_mut().for_each(|x| *x = 11);
            scales.iter_mut().enumerate().for_each(|(i, v)| *v = 100 + i as u16);
        });
        a.set_written(0, s);
        a.register_prefix(0, Layout::Hnd, 7, s);
        let adopted = a.adopt(0, Layout::Hnd, 7).unwrap();
        let fresh = a.make_unique(0, adopted);
        assert_ne!(fresh, s);
        a.read_slot(0, fresh, |buf, scales| {
            assert!(buf.iter().all(|&x| x == 11), "payload not copied");
            for (i, &v) in scales.iter().enumerate() {
                assert_eq!(v, 100 + i as u16, "scale sidecar not copied");
            }
        });
        a.release_slot(0, fresh);
        a.release_slot(0, s);
    }

    #[test]
    fn adopt_respects_layer_layout_and_namespace() {
        let a = tiny_alloc(0, true);
        let s = a.alloc_slot(0);
        a.set_written(0, s);
        a.register_prefix(0, Layout::Hnd, 9, s);
        assert!(a.adopt(1, Layout::Hnd, 9).is_none(), "different layer");
        assert!(a.adopt(0, Layout::Nhd, 9).is_none(), "different layout");
        assert!(a.adopt(0, Layout::Hnd, 10).is_none(), "different hash");
        let got = a.adopt(0, Layout::Hnd, 9).unwrap();
        a.release_slot(0, got);
        a.release_slot(0, s);
    }

    #[test]
    fn quantized_pools_still_adopt_under_dtype_qualified_keys() {
        for dtype in KvDtype::all() {
            let a = PageAllocator::with_dtype(1, 2, 4, 8, 0, true, 0xE, dtype);
            let s = a.alloc_slot(0);
            a.set_written(0, s);
            a.register_prefix(0, Layout::Hnd, 77, s);
            let got = a.adopt(0, Layout::Hnd, 77);
            assert!(got.is_some(), "{:?}: same-dtype adopt must hit", dtype);
            a.release_slot(0, got.unwrap());
            a.release_slot(0, s);
        }
    }

    #[test]
    fn reservation_ledger_admits_waits_and_fails() {
        let a = tiny_alloc(10, false);
        assert_eq!(a.try_reserve(1, 6), AdmitDecision::Admit);
        assert_eq!(a.try_reserve(2, 6), AdmitDecision::Wait, "6+6 exceeds 10");
        assert_eq!(a.try_reserve(3, 11), AdmitDecision::Never, "bigger than the pool");
        a.release_reservation(1);
        assert_eq!(a.try_reserve(2, 6), AdmitDecision::Admit, "resumes after a release");
        a.release_reservation(2);
        a.release_reservation(2); // idempotent
        assert_eq!(a.stats().pages_reserved, 0);
    }

    #[test]
    fn gpu_ledger_balances() {
        let a = tiny_alloc(0, false);
        a.charge_gpu(1000);
        a.charge_gpu(500);
        assert_eq!(a.stats().gpu_bytes_used, 1500);
        a.release_gpu(1000);
        a.release_gpu(500);
        assert_eq!(a.stats().gpu_bytes_used, 0);
    }

    #[test]
    fn poisoned_allocator_stays_usable() {
        let a = tiny_alloc(8, true);
        let s0 = a.alloc_slot(0);
        assert_eq!(a.try_reserve(1, 4), AdmitDecision::Admit);
        // poison the lock the way a crashed critical section would
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.panic_while_locked("chaos");
        }));
        assert!(r.is_err(), "the injected panic propagates to the faulting thread");
        // every path still works: alloc, data access, ledger, stats
        let s1 = a.alloc_slot(0);
        a.write_slot(0, s1, |buf, _| buf.iter_mut().for_each(|x| *x = 2));
        a.read_slot(0, s1, |buf, _| assert!(buf.iter().all(|&x| x == 2)));
        assert_eq!(a.try_reserve(2, 4), AdmitDecision::Admit);
        a.release_reservation(1);
        a.release_reservation(2);
        a.release_slot(0, s1);
        a.release_slot(0, s0);
        let st = a.stats();
        assert_eq!(st.pages_used, 0, "pool drains to baseline after poisoning");
        assert_eq!(st.pages_reserved, 0);
    }

    #[test]
    fn worst_case_footprint_is_per_layer_page_count() {
        let cfg = ModelConfig::llama31_8b();
        // 100 tokens on page 32 -> 4 pages x 32 layers
        assert_eq!(worst_case_pages(&cfg, 100), 4 * 32);
        // clamped at the model context
        assert_eq!(
            worst_case_pages(&cfg, usize::MAX),
            (cfg.max_context / cfg.page_size * cfg.n_layers) as u64
        );
    }

    fn tiny_lock(lock: KvLockMode) -> Arc<PageAllocator> {
        PageAllocator::with_mode_lock(
            2,
            2,
            4,
            8,
            0,
            PrefixCacheMode::Retained,
            0,
            0xABCD,
            KvDtype::F32,
            lock,
        )
    }

    #[test]
    fn global_and_sharded_lock_modes_agree() {
        for lock in KvLockMode::all() {
            let a = tiny_lock(lock);
            assert_eq!(a.lock_mode(), lock);
            assert_eq!(
                a.n_shards(),
                if lock == KvLockMode::Global { 1 } else { 2 },
                "shard count follows the lock layout"
            );
            let s0 = committed_page(&a, 0, 42, 7);
            let s1 = committed_page(&a, 1, 42, 9);
            a.release_slot(0, s0);
            a.release_slot(1, s1);
            let got = a.adopt_stack(Layout::Hnd, 42).expect("full cross-layer hit");
            assert_eq!(got, vec![s0, s1]);
            a.read_slot(0, s0, |buf, _| assert!(buf.iter().all(|&x| x == 7)));
            a.read_slot(1, s1, |buf, _| assert!(buf.iter().all(|&x| x == 9)));
            let st = a.stats();
            assert_eq!(st.retained_hits, 2, "both layers revived ({})", lock);
            assert_eq!(st.pages_used, 2);
            a.audit_invariants();
            a.release_slot(0, s0);
            a.release_slot(1, s1);
            a.drop_retained();
            assert_eq!(a.stats().pages_used, 0, "drained clean ({})", lock);
            a.audit_invariants();
        }
    }

    #[test]
    fn contention_counters_track_acquisitions_without_contention() {
        let a = tiny_lock(KvLockMode::Sharded);
        let s = committed_page(&a, 0, 1, 3);
        a.read_slot(0, s, |_, _| ());
        a.release_slot(0, s);
        let st = a.stats();
        assert!(st.shard_lock_acqs > 0, "shard lock sites counted");
        assert!(st.meta_lock_acqs > 0, "metadata lock sites counted");
        assert_eq!(st.shard_lock_waits, 0, "no contention single-threaded");
        assert_eq!(st.meta_lock_waits, 0);
        assert_eq!(st.shard_lock_wait_secs, 0.0);
        assert_eq!(st.meta_lock_wait_secs, 0.0);
    }

    #[test]
    fn every_shard_recovers_from_poisoning() {
        for lock in KvLockMode::all() {
            let a = tiny_lock(lock);
            for shard in 0..a.n_shards() {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    a.panic_while_locked_shard(shard, "chaos")
                }));
                assert!(r.is_err(), "the injected panic propagates");
            }
            // every poisoned shard lock recovers: normal lifecycle works
            for layer in 0..a.n_layers {
                let s = a.alloc_slot(layer);
                a.write_slot(layer, s, |buf, _| buf.fill(5));
                a.read_slot(layer, s, |buf, _| assert!(buf.iter().all(|&x| x == 5)));
                a.release_slot(layer, s);
            }
            a.audit_invariants();
            assert_eq!(a.stats().pages_used, 0, "pool drained after recovery ({})", lock);
        }
    }

    #[test]
    fn snapshot_generation_detects_a_rewrite() {
        let a = tiny_alloc(0, false);
        let s = a.alloc_slot(0);
        a.write_slot(0, s, |buf, _| buf.fill(1));
        let mut payload = Vec::new();
        let mut scales = Vec::new();
        let gen = a.snapshot_slot_ranges(0, s, &[(0, 8)], &mut payload, &mut scales);
        assert_eq!(&payload[..], &[1u8; 8]);
        assert_eq!(a.slot_generation(0, s), gen, "no write, generation stable");
        a.write_slot(0, s, |buf, _| buf.fill(2));
        assert_ne!(a.slot_generation(0, s), gen, "a rewrite bumps the generation");
        a.release_slot(0, s);
    }
}
