//! Discrete-event timeline: named streams + dependency edges -> makespan.
//!
//! This is the substrate that reproduces the paper's overlap diagrams
//! (Fig. 2a / Fig. 4): each decode step schedules compute ops on the
//! Compute stream and recall/offload work on copy streams; an op starts
//! when its stream is free AND all its dependencies have finished.

use std::collections::HashMap;

/// A serialized execution resource in the modeled device (ops on the
/// same stream run back-to-back; ops on different streams overlap).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    /// GPU compute (attention, FFN, selection kernels).
    Compute,
    /// Host-to-device copy engine (recall).
    H2D,
    /// Device-to-host copy engine (offload).
    D2H,
    /// On-device layout conversion (second half of streamed recall).
    Convert,
    /// CPU-side control work (scheduling, index math).
    Cpu,
    /// Executor-pool worker: artifact execution dispatched off the
    /// engine thread (`runtime::executor`), e.g. pooled selection
    /// scoring. Serialized per worker like every stream, but concurrent
    /// with `Compute`.
    Exec,
    /// One decode microbatch lane's artifact stream (N-lane dispatch):
    /// lane `i` maps to `Lane(i % exec_streams)`, so lanes beyond the
    /// modeled worker count serialize exactly like jobs sharing a pool
    /// worker do.
    Lane(u8),
}

/// Index of a scheduled event within its timeline.
pub type EventId = usize;

/// One scheduled op on a stream.
#[derive(Debug, Clone)]
pub struct Event {
    /// Position in the timeline's event list.
    pub id: EventId,
    /// Stream the op executed on.
    pub stream: Stream,
    /// Human-readable op label (diagrams / debugging).
    pub label: String,
    /// Start time, seconds since timeline start.
    pub start: f64,
    /// End time, seconds since timeline start.
    pub end: f64,
}

/// An append-only schedule. Times are seconds since timeline start.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    events: Vec<Event>,
    stream_free: HashMap<Stream, f64>,
}

impl Timeline {
    /// Empty timeline with all streams free at t=0.
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Schedule `duration` seconds of work on `stream` after `deps`.
    pub fn schedule(
        &mut self,
        stream: Stream,
        deps: &[EventId],
        duration: f64,
        label: impl Into<String>,
    ) -> EventId {
        let dep_end = deps
            .iter()
            .map(|&d| self.events[d].end)
            .fold(0.0f64, f64::max);
        let free = *self.stream_free.get(&stream).unwrap_or(&0.0);
        let start = dep_end.max(free);
        let end = start + duration.max(0.0);
        self.stream_free.insert(stream, end);
        let id = self.events.len();
        self.events.push(Event { id, stream, label: label.into(), start, end });
        id
    }

    /// Latest end time over all events (total makespan).
    pub fn makespan(&self) -> f64 {
        self.events.iter().map(|e| e.end).fold(0.0, f64::max)
    }

    /// End time of one event.
    pub fn end_of(&self, id: EventId) -> f64 {
        self.events[id].end
    }

    /// All events, in scheduling order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Total busy time per stream (for breakdown figures).
    pub fn busy(&self, stream: Stream) -> f64 {
        self.events
            .iter()
            .filter(|e| e.stream == stream)
            .map(|e| e.end - e.start)
            .sum()
    }

    /// Sum of durations of events whose label starts with `prefix`.
    pub fn busy_labeled(&self, prefix: &str) -> f64 {
        self.events
            .iter()
            .filter(|e| e.label.starts_with(prefix))
            .map(|e| e.end - e.start)
            .sum()
    }

    /// Portion of `prefix`-labeled work that does NOT overlap any Compute
    /// stream event — the "exposed" latency a user actually waits for.
    /// Compute events are serialized on their stream, so their intervals
    /// are disjoint and sorted by start; a binary search per labeled event
    /// keeps this O(E log E) (timelines reach millions of events).
    pub fn exposed(&self, prefix: &str) -> f64 {
        let compute: Vec<(f64, f64)> = self
            .events
            .iter()
            .filter(|e| e.stream == Stream::Compute)
            .map(|e| (e.start, e.end))
            .collect();
        debug_assert!(compute.windows(2).all(|w| w[0].0 <= w[1].0));
        let mut total = 0.0;
        for e in self.events.iter().filter(|e| e.label.starts_with(prefix)) {
            let mut uncovered = e.end - e.start;
            // first compute interval that could overlap: last with start <= e.end
            let hi_idx = compute.partition_point(|&(cs, _)| cs < e.end);
            let mut i = hi_idx;
            while i > 0 {
                i -= 1;
                let (cs, ce) = compute[i];
                if ce <= e.start {
                    // intervals are disjoint and ordered; nothing earlier overlaps
                    break;
                }
                let lo = cs.max(e.start);
                let hi = ce.min(e.end);
                if hi > lo {
                    uncovered -= hi - lo;
                }
            }
            total += uncovered.max(0.0);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_on_same_stream() {
        let mut t = Timeline::new();
        let a = t.schedule(Stream::Compute, &[], 1.0, "a");
        let b = t.schedule(Stream::Compute, &[], 2.0, "b");
        assert_eq!(t.end_of(a), 1.0);
        assert_eq!(t.end_of(b), 3.0);
        assert_eq!(t.makespan(), 3.0);
    }

    #[test]
    fn parallel_streams_overlap() {
        let mut t = Timeline::new();
        let _c = t.schedule(Stream::Compute, &[], 5.0, "compute");
        let _x = t.schedule(Stream::H2D, &[], 3.0, "recall");
        assert_eq!(t.makespan(), 5.0); // fully hidden
        assert_eq!(t.busy(Stream::H2D), 3.0);
        assert_eq!(t.exposed("recall"), 0.0);
    }

    #[test]
    fn dependencies_serialize_across_streams() {
        let mut t = Timeline::new();
        let x = t.schedule(Stream::H2D, &[], 3.0, "recall");
        let c = t.schedule(Stream::Compute, &[x], 2.0, "attn");
        assert_eq!(t.events()[c].start, 3.0);
        assert_eq!(t.makespan(), 5.0);
        // recall happens before any compute -> fully exposed
        assert_eq!(t.exposed("recall"), 3.0);
    }

    #[test]
    fn exposed_counts_partial_overlap() {
        let mut t = Timeline::new();
        let _c = t.schedule(Stream::Compute, &[], 2.0, "attn");
        let _x = t.schedule(Stream::H2D, &[], 5.0, "recall");
        // 2s of the 5s recall overlaps compute -> 3s exposed.
        assert!((t.exposed("recall") - 3.0).abs() < 1e-12);
    }

    #[test]
    fn double_buffer_pipeline_shape() {
        // transfer(i) on H2D overlaps convert(i-1) on Convert: the classic
        // double-buffered pipeline; makespan ~ n*xfer + conv instead of
        // n*(xfer+conv).
        let (n, xfer, conv) = (8, 1.0, 0.8);
        let mut t = Timeline::new();
        let mut prev_conv: Option<EventId> = None;
        for i in 0..n {
            let x = t.schedule(Stream::H2D, &[], xfer, format!("xfer{}", i));
            let deps = match prev_conv {
                Some(pc) => vec![x, pc],
                None => vec![x],
            };
            prev_conv = Some(t.schedule(Stream::Convert, &deps, conv, format!("conv{}", i)));
        }
        let pipelined = t.makespan();
        assert!((pipelined - (n as f64 * xfer + conv)).abs() < 1e-9, "{}", pipelined);

        let mut seq = Timeline::new();
        for i in 0..n {
            let x = seq.schedule(Stream::H2D, &[], xfer, format!("xfer{}", i));
            seq.schedule(Stream::H2D, &[x], conv, format!("conv{}", i));
        }
        assert!(seq.makespan() > pipelined + conv);
    }
}
