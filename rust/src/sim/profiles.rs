//! Device profiles for the latency model.
//!
//! The paper's testbed is an A100-40G on PCIe Gen4 (plus an Ascend 910B in
//! Appendix D). This environment has neither, so latency *figures* are
//! produced by an analytical model parameterized by these profiles; the
//! real CPU pipeline exercises the same code paths and validates ordering.
//! See DESIGN.md §Hardware adaptation.

/// One direction of a host<->device link.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkProfile {
    /// sustained bandwidth for large contiguous copies, bytes/s.
    pub bw: f64,
    /// fixed cost per DMA transaction (descriptor setup / doorbell).
    /// This is what makes fragmented NHD recall slow: a 256 B chunk pays
    /// the same per-transaction cost as an 8 KB one.
    pub per_txn: f64,
    /// base latency per engine invocation (driver + completion signal).
    pub base: f64,
}

impl LinkProfile {
    /// Modeled time to move `chunks` transactions of `chunk_bytes` each.
    pub fn time(&self, chunks: u64, chunk_bytes: u64) -> f64 {
        if chunks == 0 {
            return 0.0;
        }
        self.base + chunks as f64 * (self.per_txn + chunk_bytes as f64 / self.bw)
    }
}

/// Full device profile.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    /// Profile name (see [`DeviceProfile::by_name`]).
    pub name: String,
    /// peak dense matmul throughput, flop/s (fp16/bf16 tensor units).
    pub peak_flops: f64,
    /// device memory bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// fixed kernel-launch overhead per device op.
    pub launch: f64,
    /// Host-to-device link (recall direction).
    pub h2d: LinkProfile,
    /// Device-to-host link (offload direction).
    pub d2h: LinkProfile,
    /// on-device layout-conversion throughput (HND->NHD transpose),
    /// bytes/s — bounded by HBM bandwidth, with some inefficiency.
    pub convert_bw: f64,
    /// fraction of transfer time that can overlap compute on this
    /// platform (1.0 = perfect async copy engines; Appendix D notes the
    /// Ascend path overlaps poorly).
    pub overlap_efficiency: f64,
}

impl DeviceProfile {
    /// Roofline time of a device op touching `bytes` and doing `flops`.
    pub fn op_time(&self, flops: f64, bytes: f64) -> f64 {
        self.launch + (flops / self.peak_flops).max(bytes / self.hbm_bw)
    }

    /// NVIDIA A100-40GB + PCIe Gen4 x16 (paper §5.3 testbed).
    pub fn a100_pcie4() -> DeviceProfile {
        DeviceProfile {
            name: "a100-pcie4".into(),
            peak_flops: 312e12,       // fp16 tensor core
            hbm_bw: 1.555e12,         // HBM2e
            launch: 5e-6,
            h2d: LinkProfile { bw: 24e9, per_txn: 1.5e-6, base: 8e-6 },
            d2h: LinkProfile { bw: 22e9, per_txn: 1.5e-6, base: 8e-6 },
            convert_bw: 0.05e12, // strided per-page transpose, not bulk copy
            overlap_efficiency: 1.0,
        }
    }

    /// Ascend 910B (Appendix D): lower effective PCIe bandwidth, higher
    /// per-op overhead, and poorer copy/compute overlap through the
    /// current AscendC path.
    pub fn ascend_910b() -> DeviceProfile {
        DeviceProfile {
            name: "ascend-910b".into(),
            peak_flops: 280e12,
            hbm_bw: 1.2e12,
            launch: 20e-6,            // torch-level op dispatch (App. D (i))
            h2d: LinkProfile { bw: 12e9, per_txn: 1.8e-6, base: 20e-6 },
            d2h: LinkProfile { bw: 11e9, per_txn: 1.8e-6, base: 20e-6 },
            convert_bw: 0.3e12,
            overlap_efficiency: 0.5,  // App. D (ii): insufficient overlap
        }
    }

    /// The local CPU testbed (used when cross-checking modeled vs real
    /// wall-clock on the tiny model; "transfers" are memcpys).
    pub fn cpu_local() -> DeviceProfile {
        DeviceProfile {
            name: "cpu-local".into(),
            peak_flops: 5e9,
            hbm_bw: 10e9,
            launch: 50e-6,
            h2d: LinkProfile { bw: 8e9, per_txn: 0.2e-6, base: 0.5e-6 },
            d2h: LinkProfile { bw: 8e9, per_txn: 0.2e-6, base: 0.5e-6 },
            convert_bw: 4e9,
            overlap_efficiency: 0.0, // single core: nothing overlaps
        }
    }

    /// Look up a built-in profile by name (accepts short aliases).
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name {
            "a100-pcie4" | "a100" => Some(Self::a100_pcie4()),
            "ascend-910b" | "ascend" => Some(Self::ascend_910b()),
            "cpu-local" | "cpu" => Some(Self::cpu_local()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragmentation_dominates_small_chunks() {
        let p = DeviceProfile::a100_pcie4();
        // One KV page for one head: 8 KB contiguous (HND) vs 32 x 256 B (NHD).
        let hnd = p.h2d.time(1, 8192);
        let nhd = p.h2d.time(32, 256);
        assert!(nhd > 5.0 * hnd, "nhd {} hnd {}", nhd, hnd);
    }

    #[test]
    fn op_time_is_rooflined() {
        let p = DeviceProfile::a100_pcie4();
        // Memory-bound op: 1 GB at 1.555 TB/s ~ 0.64 ms.
        let t = p.op_time(1e9, 1e9);
        assert!((t - (1e9 / 1.555e12 + 5e-6)).abs() < 1e-6);
        // Compute-bound op.
        let t2 = p.op_time(1e15, 1e6);
        assert!(t2 > 3e-3);
    }

    #[test]
    fn profiles_resolvable() {
        for n in ["a100", "ascend", "cpu"] {
            assert!(DeviceProfile::by_name(n).is_some());
        }
        assert!(DeviceProfile::by_name("tpu-v9").is_none());
    }

    #[test]
    fn zero_chunks_is_free() {
        assert_eq!(DeviceProfile::a100_pcie4().h2d.time(0, 4096), 0.0);
    }
}
