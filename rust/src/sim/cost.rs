//! Analytical cost model: roofline per decode-step op, parameterized by
//! model geometry (config.rs) and device profile (profiles.rs).
//!
//! LLM decode is memory-bound, so op times are dominated by bytes moved
//! (weights + KV); the matmul flops term matters for prefill and for
//! large batch. All sizes derive from the *paper's* model geometries so
//! the latency figures (Fig. 1 right, 7, 8, 9, 10) reproduce the paper's
//! shapes without the paper's hardware.

use crate::config::ModelConfig;
use crate::kvcache::quant::KvDtype;

use super::profiles::DeviceProfile;

/// Roofline cost model: op durations from bytes moved and flops, for one
/// device profile and model geometry.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Device bandwidth/compute profile.
    pub dev: DeviceProfile,
    /// Model geometry the costs are derived from.
    pub model: ModelConfig,
    /// bytes per weight element on device (2 = fp16 paper setting).
    pub weight_elem_bytes: usize,
    /// CPU-side page codec (kvcache::quant). Scales host<->device wire
    /// bytes only: the GPU working set (attention / gather / selection /
    /// layout conversion) stays at `kv_elem_bytes` because pages are
    /// dequantized at the transfer boundary.
    pub kv_dtype: KvDtype,
}

impl CostModel {
    /// Cost model with the paper's fp16 weights and an f32 KV pool.
    pub fn new(dev: DeviceProfile, model: ModelConfig) -> CostModel {
        CostModel { dev, model, weight_elem_bytes: 2, kv_dtype: KvDtype::F32 }
    }

    /// Cost model with a quantized CPU-side KV pool codec.
    pub fn with_kv_dtype(dev: DeviceProfile, model: ModelConfig, dtype: KvDtype) -> CostModel {
        let mut c = CostModel::new(dev, model);
        c.kv_dtype = dtype;
        c
    }

    fn eb(&self) -> f64 {
        self.model.kv_elem_bytes as f64
    }

    /// Bytes per KV element on the PCIe wire (encoded form). F32 pools
    /// move `kv_elem_bytes` untouched; quantized pools move the codec's
    /// payload width (scale sidecars are amortized into the noise).
    fn wire_eb(&self) -> f64 {
        match self.kv_dtype {
            KvDtype::F32 => self.eb(),
            d => d.bytes_per_elem(),
        }
    }

    fn web(&self) -> f64 {
        self.weight_elem_bytes as f64
    }

    /// Per-layer weight bytes (qkv + o + swiglu ffn).
    pub fn layer_weight_bytes(&self) -> f64 {
        let m = &self.model;
        let qkv = m.d_model * (m.n_qo + 2 * m.n_kv) * m.d_head;
        let o = m.n_qo * m.d_head * m.d_model;
        let ffn = 3 * m.d_model * m.d_ffn;
        (qkv + o + ffn) as f64 * self.web()
    }

    /// QKV + output + FFN projections for one layer, batch b.
    pub fn layer_linear(&self, b: usize) -> f64 {
        let m = &self.model;
        let qkv = 2.0 * (m.d_model * (m.n_qo + 2 * m.n_kv) * m.d_head) as f64;
        let o = 2.0 * (m.n_qo * m.d_head * m.d_model) as f64;
        let ffn = 2.0 * (3 * m.d_model * m.d_ffn) as f64;
        let flops = b as f64 * (qkv + o + ffn);
        self.dev.op_time(flops, self.layer_weight_bytes())
    }

    /// Decode attention over `slots` gathered KV slots, batch b.
    pub fn attention(&self, b: usize, slots: usize) -> f64 {
        let m = &self.model;
        let flops = 4.0 * (b * m.n_qo * slots * m.d_head) as f64; // qk + pv
        let bytes = (2 * b * m.n_kv * slots * m.d_head) as f64 * self.eb();
        self.dev.op_time(flops, bytes)
    }

    /// Page-selection scoring over `pages` summaries + top-k, batch b.
    pub fn selection(&self, b: usize, pages: usize) -> f64 {
        let m = &self.model;
        let flops = 4.0 * (b * m.n_qo * pages * m.d_head) as f64;
        let bytes = (2 * b * m.n_kv * pages * m.d_head) as f64 * self.eb();
        self.dev.op_time(flops, bytes)
    }

    /// On-GPU gather of selected pages into the contiguous attention
    /// input (HBM-bound).
    pub fn gather(&self, b: usize, slots: usize) -> f64 {
        let m = &self.model;
        let bytes = (2 * 2 * b * m.n_kv * slots * m.d_head) as f64 * self.eb(); // rd+wr
        self.dev.op_time(0.0, bytes)
    }

    /// LM head.
    pub fn logits(&self, b: usize) -> f64 {
        let m = &self.model;
        let flops = 2.0 * (b * m.d_model * m.vocab) as f64;
        let bytes = (m.d_model * m.vocab) as f64 * self.web();
        self.dev.op_time(flops, bytes)
    }

    /// One full decode step's compute (all layers + head) with a given
    /// number of attended slots — the building block every policy shares.
    pub fn decode_compute(&self, b: usize, slots: usize) -> f64 {
        self.model.n_layers as f64 * (self.layer_linear(b) + self.attention(b, slots))
            + self.logits(b)
    }

    /// Prefill compute for `t` prompt tokens (full causal attention).
    pub fn prefill_compute(&self, t: usize) -> f64 {
        let m = &self.model;
        let lin = self.layer_linear(t); // flops scale with t via b argument
        let attn_flops = 2.0 * (m.n_qo * m.d_head) as f64 * (t as f64 * t as f64);
        let attn_bytes = (2 * m.n_kv * t * m.d_head) as f64 * self.eb();
        let attn = self.dev.op_time(attn_flops, attn_bytes);
        m.n_layers as f64 * (lin + attn) + self.logits(1)
    }

    /// ShadowKV-style key reconstruction from rank-r factors for
    /// `tokens` selected tokens, batch b.
    pub fn svd_reconstruct(&self, b: usize, tokens: usize, rank: usize) -> f64 {
        let m = &self.model;
        let flops = 2.0 * (b * m.n_kv * tokens * rank * m.d_head) as f64;
        let bytes = (b * m.n_kv * tokens * rank) as f64 * self.eb();
        self.dev.op_time(flops, bytes)
    }

    /// InfiniGen-style query re-projection (skewed partial weights,
    /// rank fraction `r_frac` of the head dim), batch b.
    pub fn reprojection(&self, b: usize, r_frac: f64) -> f64 {
        let m = &self.model;
        let cols = (m.n_qo as f64 * m.d_head as f64 * r_frac).ceil();
        let flops = 2.0 * b as f64 * m.d_model as f64 * cols;
        let bytes = m.d_model as f64 * cols * self.web();
        self.dev.op_time(flops, bytes)
    }

    /// Token-level scoring over the whole context (InfiniGen's selection
    /// is token-wise, not page-wise).
    pub fn token_selection(&self, b: usize, context: usize, r_frac: f64) -> f64 {
        let m = &self.model;
        let dh = (m.d_head as f64 * r_frac).ceil();
        let flops = 2.0 * (b * m.n_qo * context) as f64 * dh;
        let bytes = (b * m.n_kv * context) as f64 * dh * self.eb();
        self.dev.op_time(flops, bytes)
    }

    // ----- transfer building blocks ------------------------------------

    /// Recall `pages` KV pages for ALL kv heads, contiguity per layout:
    /// HND -> one transaction of 2*p*d per (page, head); NHD -> p
    /// transactions of d elems per (page, head, k/v plane).
    pub fn recall_pages(&self, pages: usize, hnd: bool) -> f64 {
        let m = &self.model;
        let per_head_bytes = (2 * m.page_size * m.d_head) as f64 * self.wire_eb();
        if hnd {
            let chunks = (pages * m.n_kv) as u64;
            self.dev.h2d.time(chunks, per_head_bytes as u64)
        } else {
            let chunks = (pages * m.n_kv * 2 * m.page_size) as u64;
            let chunk_bytes = m.d_head as f64 * self.wire_eb();
            self.dev.h2d.time(chunks, chunk_bytes as u64)
        }
    }

    /// Recall `tokens` individual tokens (InfiniGen's token-wise recall).
    pub fn recall_tokens(&self, tokens: usize) -> f64 {
        let m = &self.model;
        let chunks = (tokens * m.n_kv * 2) as u64;
        let chunk_bytes = (m.d_head as f64 * self.wire_eb()) as u64;
        self.dev.h2d.time(chunks, chunk_bytes)
    }

    /// Offload one completed page (D2H), HND-converted on the fly.
    pub fn offload_page(&self) -> f64 {
        let m = &self.model;
        let per_head_bytes = (2 * m.page_size * m.d_head) as f64 * self.wire_eb();
        self.dev.d2h.time(m.n_kv as u64, per_head_bytes as u64)
    }

    /// On-GPU HND->NHD conversion of `pages` recalled pages.
    pub fn convert_pages(&self, pages: usize) -> f64 {
        let bytes = pages as f64 * self.model.page_bytes() as f64;
        self.dev.launch + bytes / self.dev.convert_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profiles::DeviceProfile;

    fn cm() -> CostModel {
        CostModel::new(DeviceProfile::a100_pcie4(), ModelConfig::llama31_8b())
    }

    #[test]
    fn decode_step_is_memory_bound_and_plausible() {
        let c = cm();
        // Llama-8B fp16 weights ~15 GB -> ~10 ms/token on A100 roofline.
        let t = c.decode_compute(1, 2048);
        assert!(t > 5e-3 && t < 30e-3, "decode {}", t);
        // Bigger batch amortizes weights: same order of magnitude.
        let t4 = c.decode_compute(4, 2048);
        assert!(t4 < 2.0 * t, "t4 {} t {}", t4, t);
    }

    #[test]
    fn full_context_attention_much_slower_than_budget() {
        let c = cm();
        let budget = c.attention(1, 2048);
        let full = c.attention(1, 32768);
        assert!(full > 8.0 * budget);
    }

    #[test]
    fn hnd_recall_beats_nhd_by_order_of_magnitude() {
        let c = cm();
        let hnd = c.recall_pages(32, true);
        let nhd = c.recall_pages(32, false);
        // The paper's hybrid-layout ablation (Fig. 9) reports up to ~10x.
        assert!(nhd / hnd > 5.0, "nhd {} hnd {} ratio {}", nhd, hnd, nhd / hnd);
        assert!(nhd / hnd < 80.0);
    }

    #[test]
    fn token_recall_worse_than_page_recall() {
        let c = cm();
        // Same token count: 32 pages vs 1024 scattered tokens.
        let page = c.recall_pages(32, true);
        let tok = c.recall_tokens(32 * 32);
        assert!(tok > page * 3.0, "tok {} page {}", tok, page);
    }

    #[test]
    fn prefill_scales_superlinearly() {
        let c = cm();
        let t1 = c.prefill_compute(8192);
        let t2 = c.prefill_compute(32768);
        assert!(t2 > 3.9 * t1);
    }

    #[test]
    fn quantized_pools_shrink_wire_time_but_not_gpu_time() {
        let c = cm();
        let c8 = CostModel::with_kv_dtype(
            DeviceProfile::a100_pcie4(),
            ModelConfig::llama31_8b(),
            KvDtype::Int8,
        );
        let c4 = CostModel::with_kv_dtype(
            DeviceProfile::a100_pcie4(),
            ModelConfig::llama31_8b(),
            KvDtype::Int4,
        );
        // PCIe blocks scale with the codec's payload width (latency floor
        // keeps the ratio below the raw byte ratio).
        let (f, i8t, i4t) =
            (c.recall_pages(64, true), c8.recall_pages(64, true), c4.recall_pages(64, true));
        assert!(i8t < f && i4t < i8t, "f32 {} int8 {} int4 {}", f, i8t, i4t);
        assert!(i8t < 0.75 * f, "int8 recall {} vs f32 {}", i8t, f);
        assert!(c8.offload_page() < c.offload_page());
        assert!(c8.recall_tokens(1024) < c.recall_tokens(1024));
        // GPU-side ops see dequantized pages: identical across dtypes.
        assert_eq!(c.attention(1, 2048), c8.attention(1, 2048));
        assert_eq!(c.gather(1, 2048), c4.gather(1, 2048));
        assert_eq!(c.selection(1, 512), c8.selection(1, 512));
        assert_eq!(c.convert_pages(32), c4.convert_pages(32));
    }

    #[test]
    fn ascend_recall_slower_than_a100() {
        let a = cm();
        let n = CostModel::new(DeviceProfile::ascend_910b(), ModelConfig::llama31_8b());
        assert!(n.recall_pages(32, true) > a.recall_pages(32, true) * 1.2);
    }
}
