//! Latency-model substrate: device profiles, analytical op costs, and a
//! discrete-event timeline with named streams. The policy simulators
//! (`policies::latency`) build per-step event graphs on top of these to
//! regenerate the paper's latency tables and figures.

pub mod cost;
pub mod profiles;
pub mod timeline;

pub use cost::CostModel;
pub use profiles::{DeviceProfile, LinkProfile};
pub use timeline::{Event, EventId, Stream, Timeline};
