//! Workload generation: arrival processes and request-shape distributions
//! for load-testing the serving stack (used by `freekv loadtest` and the
//! scheduler tests). Mirrors the paper's two evaluation scenarios:
//! long-input (big prompt, short output) and long-generation (short
//! prompt, long output). [`run_router_loadtest`] replays the same
//! workloads across N replica schedulers through a
//! [`DispatchPolicy`] — the exact routing core the live serving tier
//! runs — for the multi-replica throughput/affinity sweeps.

use crate::coordinator::engine::{Backend, SampleParams};
use crate::coordinator::router::{DispatchPolicy, ReplicaLoad, RouterCounters};
use crate::coordinator::scheduler::{Request, StepEvent};
use crate::util::rng::Rng;

/// Request-shape scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// 32K-in/512-out style: prompt-heavy (scaled to the model's context).
    LongInput,
    /// 600-in/16K-out style: decode-heavy.
    LongGeneration,
    /// chat-like mixture of both.
    Mixed,
    /// every request opens with the same long system prompt plus a
    /// short unique suffix — the shape the persistent prefix cache is
    /// built for (`--prefix-cache=retained` turns re-prefills of the
    /// shared head into retained-tier hits).
    RepeatedPrompt,
}

impl Scenario {
    /// Parse a `--scenario` CLI name (`long-input`, `long-gen`,
    /// `mixed`, `repeated-prompt`/`repeated`/`shared-prefix`).
    pub fn parse(s: &str) -> Option<Scenario> {
        Some(match s {
            "long-input" | "longinput" => Scenario::LongInput,
            "long-gen" | "longgen" => Scenario::LongGeneration,
            "mixed" => Scenario::Mixed,
            "repeated-prompt" | "repeated" | "shared-prefix" => Scenario::RepeatedPrompt,
            _ => return None,
        })
    }
}

/// Workload generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Request-shape distribution to draw from.
    pub scenario: Scenario,
    /// mean arrival rate (requests/second) of the Poisson process.
    pub rate: f64,
    /// Total requests to generate.
    pub n_requests: usize,
    /// bounds imposed by the compiled model (prefill buckets / context).
    pub max_prompt: usize,
    /// Cap on any request's `max_new_tokens`.
    pub max_output: usize,
    /// Seed for arrivals, shapes, and prompt bytes (fully deterministic).
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            scenario: Scenario::Mixed,
            rate: 4.0,
            n_requests: 16,
            max_prompt: 1000,
            max_output: 64,
            seed: 0xF00D,
        }
    }
}

/// A generated request with its arrival offset (seconds from start).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Arrival offset in seconds from the start of the replay.
    pub at: f64,
    /// The request to submit at that instant.
    pub request: Request,
}

/// Draw a prompt/output shape for the scenario.
fn shape(rng: &mut Rng, scenario: Scenario, max_prompt: usize, max_output: usize) -> (usize, usize) {
    let (p_lo, p_hi, o_lo, o_hi) = match scenario {
        Scenario::LongInput => (max_prompt / 2, max_prompt, 8, max_output / 4),
        Scenario::LongGeneration => (32, 128.min(max_prompt), max_output / 2, max_output),
        Scenario::Mixed => {
            if rng.below(2) == 0 {
                (max_prompt / 2, max_prompt, 8, max_output / 4)
            } else {
                (32, 128.min(max_prompt), max_output / 2, max_output)
            }
        }
        // shared head (3/4 of max_prompt) + a short unique tail
        Scenario::RepeatedPrompt => {
            let h = repeated_head_len(max_prompt);
            ((h + 1).min(max_prompt), (h + 33).min(max_prompt), 8, max_output / 4)
        }
    };
    let p = p_lo + rng.below((p_hi - p_lo).max(1));
    let o = (o_lo + rng.below((o_hi - o_lo).max(1))).max(1);
    (p.max(2), o)
}

/// Synthetic byte prompt of a given token length (BOS + bytes).
fn synth_prompt(rng: &mut Rng, tokens: usize) -> Vec<i32> {
    let mut p = Vec::with_capacity(tokens);
    p.push(crate::coordinator::tokenizer::BOS);
    // word-ish structure so prompts aren't pure noise
    while p.len() < tokens {
        let wlen = 2 + rng.below(8);
        for _ in 0..wlen.min(tokens - p.len()) {
            p.push((b'a' + rng.below(26) as u8) as i32);
        }
        if p.len() < tokens {
            p.push(b' ' as i32);
        }
    }
    p
}

/// Tokens of the shared head every [`Scenario::RepeatedPrompt`] request
/// opens with (the rest of the prompt is a per-request unique tail).
fn repeated_head_len(max_prompt: usize) -> usize {
    (max_prompt * 3 / 4).max(2)
}

/// Generate the full timed workload (Poisson arrivals).
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = Rng::new(spec.seed);
    // RepeatedPrompt: draw the shared head once from the spec seed, so
    // every request (and every rerun of the same spec) opens with the
    // exact same token block and keys the same prefix pages.
    let shared_head = if spec.scenario == Scenario::RepeatedPrompt {
        synth_prompt(&mut rng.fork(u64::MAX), repeated_head_len(spec.max_prompt))
    } else {
        Vec::new()
    };
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        t += rng.exp(spec.rate.max(1e-9));
        let (p_len, o_len) = shape(&mut rng, spec.scenario, spec.max_prompt, spec.max_output);
        let prompt = if spec.scenario == Scenario::RepeatedPrompt {
            let mut p = shared_head.clone();
            let mut tail = rng.fork(i as u64);
            while p.len() < p_len {
                p.push((b'a' + tail.below(26) as u8) as i32);
            }
            p
        } else {
            synth_prompt(&mut rng.fork(i as u64), p_len)
        };
        out.push(TimedRequest {
            at: t,
            request: Request {
                id: i as u64 + 1,
                prompt,
                max_new_tokens: o_len,
                sample: SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 },
                stop: Vec::new(),
            },
        });
    }
    out
}

/// Closed-loop load test: replay the workload against a scheduler,
/// respecting arrival times in *scheduler ticks* (the single-core testbed
/// has no wall-clock arrival fidelity; arrivals are mapped to ticks by
/// the requested rate so queueing behaviour is still exercised).
pub fn run_loadtest<B: Backend>(
    sched: &mut crate::coordinator::scheduler::Scheduler<B>,
    workload: Vec<TimedRequest>,
    ticks_per_second: f64,
) -> anyhow::Result<LoadtestReport> {
    let mut pending: std::collections::VecDeque<TimedRequest> = workload.into();
    let mut tick = 0u64;
    let t0 = std::time::Instant::now();
    let mut max_inflight = 0usize;
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut tick_faults = 0usize;
    while !pending.is_empty() || sched.pending() > 0 {
        let now = tick as f64 / ticks_per_second.max(1e-9);
        while pending.front().map_or(false, |r| r.at <= now) {
            sched.submit(pending.pop_front().unwrap().request);
        }
        // Chaos tolerance (`--chaos-seed`): a tick panic or
        // engine-global error fails the in-flight requests — mirroring
        // the engine-loop supervisor's teardown — and the replay
        // continues; every request still reaches exactly one terminal
        // outcome (completed or failed).
        let events = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.tick()))
            .map_err(|p| anyhow::anyhow!("{}", crate::util::fault::panic_message(p.as_ref())));
        match events.and_then(|r| r) {
            Ok(events) => {
                for ev in events {
                    match ev {
                        StepEvent::Finished { id } => {
                            completed += 1;
                            // claim each completion so nothing accumulates
                            let _ = sched.take_completion(id);
                        }
                        StepEvent::Failed { .. } => failed += 1,
                        StepEvent::Token { .. } => {}
                    }
                }
            }
            Err(e) => {
                tick_faults += 1;
                let ids =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.active_ids()))
                        .unwrap_or_default();
                for id in ids {
                    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.abort(id)))
                        .is_err()
                    {
                        sched.engine.kv_release(id);
                        sched.metrics.on_failed();
                    }
                    failed += 1;
                }
                eprintln!("[loadtest] engine fault on tick {}: {:#}", tick, e);
            }
        }
        max_inflight = max_inflight.max(sched.pending());
        tick += 1;
    }
    Ok(LoadtestReport {
        wall_secs: t0.elapsed().as_secs_f64(),
        ticks: tick,
        completed,
        failed,
        max_inflight,
        tokens_out: sched.metrics.tokens_out,
        tick_faults,
    })
}

/// Terminal accounting of one [`run_loadtest`] replay.
#[derive(Debug, Clone)]
pub struct LoadtestReport {
    /// Real elapsed wall time of the replay.
    pub wall_secs: f64,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Requests that finished normally.
    pub completed: usize,
    /// Requests that reached a failure outcome.
    pub failed: usize,
    /// Peak requests simultaneously queued or running.
    pub max_inflight: usize,
    /// Total generated tokens across all requests.
    pub tokens_out: u64,
    /// Ticks that ended in an engine panic or engine-global error
    /// (non-zero only under `--chaos-seed` fault injection).
    pub tick_faults: usize,
}

/// Replay a workload across N replica schedulers through a routing
/// policy — the multi-replica analogue of [`run_loadtest`]. Each tick
/// dispatches the due arrivals via [`DispatchPolicy::route`] over live
/// per-replica load snapshots (queue depth + KV pool pages, exactly
/// what the serving-tier router reads), records the dispatch for
/// prefix affinity, then ticks every busy replica — so N replicas
/// genuinely decode the same tick and modeled throughput scales with
/// the set. Per-replica engine faults mirror [`run_loadtest`]'s chaos
/// tolerance: the faulting replica's in-flight requests are failed
/// loudly and the replay continues.
pub fn run_router_loadtest<B: Backend>(
    scheds: &mut [crate::coordinator::scheduler::Scheduler<B>],
    policy: &mut DispatchPolicy,
    workload: Vec<TimedRequest>,
    ticks_per_second: f64,
) -> anyhow::Result<RouterLoadtestReport> {
    anyhow::ensure!(!scheds.is_empty(), "router loadtest needs at least one replica");
    let n = scheds.len();
    let mut pending: std::collections::VecDeque<TimedRequest> = workload.into();
    let mut tick = 0u64;
    let t0 = std::time::Instant::now();
    let mut max_inflight = 0usize;
    let mut tick_faults = 0usize;
    let mut completed = vec![0usize; n];
    let mut failed = vec![0usize; n];
    // request id -> arrival tick, removed at the first sampled token to
    // model TTFT in ticks (converted to seconds by the tick rate)
    let mut awaiting_first = std::collections::HashMap::new();
    let mut ttfts = Vec::new();
    loop {
        let busy: usize = scheds.iter().map(|s| s.pending()).sum();
        if pending.is_empty() && busy == 0 {
            break;
        }
        let now = tick as f64 / ticks_per_second.max(1e-9);
        while pending.front().map_or(false, |r| r.at <= now) {
            let req = pending.pop_front().unwrap().request;
            let loads: Vec<ReplicaLoad> = scheds
                .iter()
                .map(|s| ReplicaLoad {
                    alive: true,
                    in_flight: s.pending(),
                    kv_pages_used: s.kv_pool_stats().pages_used,
                })
                .collect();
            let r = policy.route(&req.prompt, &loads).expect("all replicas alive");
            policy.record(&req.prompt, r);
            awaiting_first.insert(req.id, tick);
            scheds[r].submit(req);
        }
        for (r, sched) in scheds.iter_mut().enumerate() {
            if sched.pending() == 0 {
                continue;
            }
            let events = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.tick()))
                .map_err(|p| anyhow::anyhow!("{}", crate::util::fault::panic_message(p.as_ref())));
            match events.and_then(|x| x) {
                Ok(events) => {
                    for ev in events {
                        match ev {
                            StepEvent::Token { id, index: 0, .. } => {
                                if let Some(at) = awaiting_first.remove(&id) {
                                    ttfts.push(
                                        (tick - at) as f64 / ticks_per_second.max(1e-9),
                                    );
                                }
                            }
                            StepEvent::Token { .. } => {}
                            StepEvent::Finished { id } => {
                                completed[r] += 1;
                                awaiting_first.remove(&id);
                                let _ = sched.take_completion(id);
                            }
                            StepEvent::Failed { id, .. } => {
                                failed[r] += 1;
                                awaiting_first.remove(&id);
                            }
                        }
                    }
                }
                Err(e) => {
                    tick_faults += 1;
                    let ids = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        sched.active_ids()
                    }))
                    .unwrap_or_default();
                    for id in ids {
                        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            sched.abort(id)
                        }))
                        .is_err()
                        {
                            sched.engine.kv_release(id);
                            sched.metrics.on_failed();
                        }
                        failed[r] += 1;
                        awaiting_first.remove(&id);
                    }
                    eprintln!("[loadtest] replica {} fault on tick {}: {:#}", r, tick, e);
                }
            }
        }
        max_inflight = max_inflight.max(scheds.iter().map(|s| s.pending()).sum());
        tick += 1;
    }
    let per_replica: Vec<ReplicaLoadtestReport> = scheds
        .iter()
        .enumerate()
        .map(|(r, s)| {
            let kv = s.kv_pool_stats();
            let stats = s.engine.stats();
            ReplicaLoadtestReport {
                completed: completed[r],
                failed: failed[r],
                tokens_out: s.metrics.tokens_out,
                retained_hits: kv.retained_hits,
                prefill_tokens_saved: stats.prefill_tokens_saved,
                kv_pages_retained: kv.pages_retained,
            }
        })
        .collect();
    Ok(RouterLoadtestReport {
        wall_secs: t0.elapsed().as_secs_f64(),
        ticks: tick,
        completed: completed.iter().sum(),
        failed: failed.iter().sum(),
        max_inflight,
        tokens_out: per_replica.iter().map(|p| p.tokens_out).sum(),
        tick_faults,
        ttft_p95_secs: crate::util::stats::percentile(&ttfts, 95.0),
        per_replica,
        counters: policy.counters(),
    })
}

/// One replica's slice of a [`run_router_loadtest`] replay.
#[derive(Debug, Clone)]
pub struct ReplicaLoadtestReport {
    /// Requests that finished normally on this replica.
    pub completed: usize,
    /// Requests that reached a failure outcome on this replica.
    pub failed: usize,
    /// Tokens generated by this replica.
    pub tokens_out: u64,
    /// Retained-tier prefix hits on this replica's allocator.
    pub retained_hits: u64,
    /// Prefill tokens this replica skipped via prefix reuse.
    pub prefill_tokens_saved: u64,
    /// Pages parked in this replica's retained tier at the end.
    pub kv_pages_retained: u64,
}

/// Terminal accounting of one [`run_router_loadtest`] replay.
#[derive(Debug, Clone)]
pub struct RouterLoadtestReport {
    /// Real elapsed wall time of the replay.
    pub wall_secs: f64,
    /// Scheduler ticks executed (shared clock across replicas).
    pub ticks: u64,
    /// Requests that finished normally, summed over replicas.
    pub completed: usize,
    /// Requests that reached a failure outcome, summed over replicas.
    pub failed: usize,
    /// Peak requests simultaneously queued or running across the set.
    pub max_inflight: usize,
    /// Total generated tokens across all replicas.
    pub tokens_out: u64,
    /// Per-replica ticks that ended in an engine panic or error.
    pub tick_faults: usize,
    /// p95 time-to-first-token in modeled seconds (arrival tick to
    /// first sampled token, divided by the tick rate).
    pub ttft_p95_secs: f64,
    /// Per-replica breakdown, in replica index order.
    pub per_replica: Vec<ReplicaLoadtestReport>,
    /// The dispatch policy's routing counters (zeros for round-robin).
    pub counters: RouterCounters,
}

impl RouterLoadtestReport {
    /// Modeled decode throughput in tokens per modeled second: total
    /// tokens over the tick span, scaled by the tick rate. Comparable
    /// across replica counts because the tick is the shared clock.
    pub fn modeled_throughput(&self, ticks_per_second: f64) -> f64 {
        if self.ticks == 0 {
            return 0.0;
        }
        self.tokens_out as f64 * ticks_per_second / self.ticks as f64
    }

    /// Fraction of all retained-tier hits that landed on the single
    /// hottest replica (1.0 = perfectly concentrated, the prefix-
    /// affinity goal; ~1/N under affinity-blind routing).
    pub fn retained_hit_concentration(&self) -> f64 {
        let total: u64 = self.per_replica.iter().map(|p| p.retained_hits).sum();
        let max = self.per_replica.iter().map(|p| p.retained_hits).max().unwrap_or(0);
        max as f64 / total.max(1) as f64
    }

    /// Total retained-tier hits across the set.
    pub fn retained_hits(&self) -> u64 {
        self.per_replica.iter().map(|p| p.retained_hits).sum()
    }

    /// Total prefill tokens saved across the set.
    pub fn prefill_tokens_saved(&self) -> u64 {
        self.per_replica.iter().map(|p| p.prefill_tokens_saved).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing_and_rate_plausible() {
        let spec = WorkloadSpec { rate: 10.0, n_requests: 400, ..Default::default() };
        let w = generate(&spec);
        assert_eq!(w.len(), 400);
        assert!(w.windows(2).all(|p| p[0].at <= p[1].at));
        let span = w.last().unwrap().at;
        let rate = 400.0 / span;
        assert!((rate - 10.0).abs() < 2.5, "empirical rate {}", rate);
    }

    #[test]
    fn shapes_respect_scenario_bounds() {
        for scenario in [
            Scenario::LongInput,
            Scenario::LongGeneration,
            Scenario::Mixed,
            Scenario::RepeatedPrompt,
        ] {
            let spec = WorkloadSpec { scenario, n_requests: 60, ..Default::default() };
            for tr in generate(&spec) {
                assert!(tr.request.prompt.len() <= spec.max_prompt);
                assert!(tr.request.max_new_tokens <= spec.max_output);
                assert!(tr.request.max_new_tokens >= 1);
            }
        }
        // long-input prompts longer than long-gen prompts on average
        let li = generate(&WorkloadSpec { scenario: Scenario::LongInput, n_requests: 50, ..Default::default() });
        let lg = generate(&WorkloadSpec { scenario: Scenario::LongGeneration, n_requests: 50, ..Default::default() });
        let avg = |w: &[TimedRequest]| {
            w.iter().map(|r| r.request.prompt.len()).sum::<usize>() as f64 / w.len() as f64
        };
        assert!(avg(&li) > 3.0 * avg(&lg));
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].request.prompt, b[3].request.prompt);
        let c = generate(&WorkloadSpec { seed: 1, ..spec });
        assert_ne!(a[3].request.prompt, c[3].request.prompt);
    }

    #[test]
    fn loadtest_over_sim_backend_completes_everything() {
        use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use crate::coordinator::sim_backend::SimBackend;
        let spec =
            WorkloadSpec { n_requests: 12, max_prompt: 64, max_output: 8, ..Default::default() };
        let w = generate(&spec);
        let mut sched = Scheduler::new(SimBackend::tiny(), SchedulerConfig::default());
        let report = run_loadtest(&mut sched, w, 1000.0).unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.failed, 0);
        assert!(report.max_inflight >= 1);
        assert!(sched.metrics.tokens_out > 0);
        assert_eq!(sched.pending(), 0);
    }

    #[test]
    fn prompts_are_tokenizer_valid() {
        let w = generate(&WorkloadSpec { n_requests: 5, ..Default::default() });
        for tr in w {
            assert!(tr.request.prompt.iter().all(|&t| (0..260).contains(&t)));
        }
    }

    #[test]
    fn repeated_prompt_requests_share_a_head_with_unique_tails() {
        let spec = WorkloadSpec {
            scenario: Scenario::RepeatedPrompt,
            n_requests: 8,
            max_prompt: 128,
            ..Default::default()
        };
        let w = generate(&spec);
        let head = repeated_head_len(spec.max_prompt);
        let first = &w[0].request.prompt;
        for tr in &w {
            assert!(tr.request.prompt.len() >= head);
            assert!(tr.request.prompt.len() <= spec.max_prompt);
            assert_eq!(&tr.request.prompt[..head], &first[..head], "shared head diverged");
            assert!(tr.request.prompt.iter().all(|&t| (0..260).contains(&t)));
        }
        // tails are per-request unique (full prompts differ pairwise)
        for (i, a) in w.iter().enumerate() {
            for b in w.iter().skip(i + 1) {
                assert_ne!(a.request.prompt, b.request.prompt, "two identical prompts");
            }
        }
    }

    #[test]
    fn repeated_prompt_loadtest_hits_the_retained_tier() {
        use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use crate::coordinator::sim_backend::SimBackend;
        use crate::kvcache::PrefixCacheMode;
        // Arrivals far apart relative to generation length, so earlier
        // requests fully retire (dropping their pages to the retained
        // tier) before later ones prefill — the hits below can only be
        // retained-tier revivals, not live-page sharing.
        let spec = WorkloadSpec {
            scenario: Scenario::RepeatedPrompt,
            rate: 0.002,
            n_requests: 4,
            max_prompt: 64,
            max_output: 4,
            ..Default::default()
        };
        let w = generate(&spec);
        let backend = SimBackend::tiny_with_pool_mode(0, PrefixCacheMode::Retained, 0);
        let alloc = backend.allocator();
        let mut sched = Scheduler::new(backend, SchedulerConfig::default());
        let report = run_loadtest(&mut sched, w, 1.0).unwrap();
        assert_eq!(report.completed, 4);
        assert_eq!(report.failed, 0);
        let kv = alloc.stats();
        assert!(kv.retained_hits > 0, "no retained-tier prefix hits: {:?}", kv);
        assert!(kv.prefix_hits >= kv.retained_hits);
        assert!(kv.bytes_saved > 0);
        let stats = sched.engine.stats();
        assert!(stats.prefill_tokens_saved > 0, "no prefill tokens saved");
        assert_eq!(stats.kv_retained_hits, kv.retained_hits);
    }

    #[test]
    fn router_loadtest_kv_affinity_concentrates_retained_hits_vs_round_robin() {
        use crate::coordinator::router::KvRouterConfig;
        use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use crate::coordinator::sim_backend::SimBackend;
        use crate::kvcache::PrefixCacheMode;
        // Same far-apart arrivals as the single-scheduler retained-tier
        // test: every hit below is a retained-tier revival.
        let spec = WorkloadSpec {
            scenario: Scenario::RepeatedPrompt,
            rate: 0.002,
            n_requests: 6,
            max_prompt: 64,
            max_output: 4,
            ..Default::default()
        };
        let make = || {
            (0..2)
                .map(|_| {
                    Scheduler::new(
                        SimBackend::tiny_with_pool_mode(0, PrefixCacheMode::Retained, 0),
                        SchedulerConfig::default(),
                    )
                })
                .collect::<Vec<_>>()
        };
        let mut kv_scheds = make();
        let mut kv_policy =
            DispatchPolicy::kv_aware(KvRouterConfig { page_size: 4, ..Default::default() });
        let kv = run_router_loadtest(&mut kv_scheds, &mut kv_policy, generate(&spec), 1.0).unwrap();
        assert_eq!(kv.completed, 6);
        assert_eq!(kv.failed, 0);
        assert!(kv.retained_hits() > 0, "no retained hits: {:?}", kv.per_replica);
        assert!(
            kv.retained_hit_concentration() > 0.99,
            "affinity must concentrate hits on one replica: {:?}",
            kv.per_replica
        );
        assert!(kv.counters.affinity_hits > 0, "{:?}", kv.counters);

        let mut rr_scheds = make();
        let mut rr_policy = DispatchPolicy::round_robin();
        let rr = run_router_loadtest(&mut rr_scheds, &mut rr_policy, generate(&spec), 1.0).unwrap();
        assert_eq!(rr.completed, 6);
        assert!(
            kv.prefill_tokens_saved() > rr.prefill_tokens_saved(),
            "kv-aware ({}) must beat round-robin ({}) on prefill tokens saved",
            kv.prefill_tokens_saved(),
            rr.prefill_tokens_saved()
        );
    }

    #[test]
    fn router_loadtest_four_replicas_beat_one_on_modeled_throughput() {
        use crate::coordinator::router::KvRouterConfig;
        use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use crate::coordinator::sim_backend::SimBackend;
        // Near-simultaneous arrivals so the set is decode-bound: four
        // replicas run 4x the lanes of one at equal per-replica config.
        let spec = WorkloadSpec {
            scenario: Scenario::LongGeneration,
            rate: 1e6,
            n_requests: 32,
            max_prompt: 32,
            max_output: 16,
            ..Default::default()
        };
        let tps = 1000.0;
        let run = |n: usize| {
            let mut scheds: Vec<_> = (0..n)
                .map(|_| Scheduler::new(SimBackend::tiny(), SchedulerConfig::default()))
                .collect();
            let mut policy =
                DispatchPolicy::kv_aware(KvRouterConfig { page_size: 4, ..Default::default() });
            run_router_loadtest(&mut scheds, &mut policy, generate(&spec), tps).unwrap()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.completed, 32);
        assert_eq!(four.completed, 32);
        assert_eq!(one.tokens_out, four.tokens_out, "same workload, same tokens");
        let speedup = four.modeled_throughput(tps) / one.modeled_throughput(tps).max(1e-9);
        assert!(speedup > 2.5, "4-replica speedup {:.2}x <= 2.5x", speedup);
        assert!(four.ttft_p95_secs <= one.ttft_p95_secs, "more lanes must not slow TTFT");
    }
}
