//! Workload generation: arrival processes and request-shape distributions
//! for load-testing the serving stack (used by `freekv loadtest` and the
//! scheduler tests). Mirrors the paper's two evaluation scenarios:
//! long-input (big prompt, short output) and long-generation (short
//! prompt, long output).

use crate::coordinator::engine::{Backend, SampleParams};
use crate::coordinator::scheduler::{Request, StepEvent};
use crate::util::rng::Rng;

/// Request-shape scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// 32K-in/512-out style: prompt-heavy (scaled to the model's context).
    LongInput,
    /// 600-in/16K-out style: decode-heavy.
    LongGeneration,
    /// chat-like mixture of both.
    Mixed,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        Some(match s {
            "long-input" | "longinput" => Scenario::LongInput,
            "long-gen" | "longgen" => Scenario::LongGeneration,
            "mixed" => Scenario::Mixed,
            _ => return None,
        })
    }
}

/// Workload generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub scenario: Scenario,
    /// mean arrival rate (requests/second) of the Poisson process.
    pub rate: f64,
    pub n_requests: usize,
    /// bounds imposed by the compiled model (prefill buckets / context).
    pub max_prompt: usize,
    pub max_output: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            scenario: Scenario::Mixed,
            rate: 4.0,
            n_requests: 16,
            max_prompt: 1000,
            max_output: 64,
            seed: 0xF00D,
        }
    }
}

/// A generated request with its arrival offset (seconds from start).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at: f64,
    pub request: Request,
}

/// Draw a prompt/output shape for the scenario.
fn shape(rng: &mut Rng, scenario: Scenario, max_prompt: usize, max_output: usize) -> (usize, usize) {
    let (p_lo, p_hi, o_lo, o_hi) = match scenario {
        Scenario::LongInput => (max_prompt / 2, max_prompt, 8, max_output / 4),
        Scenario::LongGeneration => (32, 128.min(max_prompt), max_output / 2, max_output),
        Scenario::Mixed => {
            if rng.below(2) == 0 {
                (max_prompt / 2, max_prompt, 8, max_output / 4)
            } else {
                (32, 128.min(max_prompt), max_output / 2, max_output)
            }
        }
    };
    let p = p_lo + rng.below((p_hi - p_lo).max(1));
    let o = (o_lo + rng.below((o_hi - o_lo).max(1))).max(1);
    (p.max(2), o)
}

/// Synthetic byte prompt of a given token length (BOS + bytes).
fn synth_prompt(rng: &mut Rng, tokens: usize) -> Vec<i32> {
    let mut p = Vec::with_capacity(tokens);
    p.push(crate::coordinator::tokenizer::BOS);
    // word-ish structure so prompts aren't pure noise
    while p.len() < tokens {
        let wlen = 2 + rng.below(8);
        for _ in 0..wlen.min(tokens - p.len()) {
            p.push((b'a' + rng.below(26) as u8) as i32);
        }
        if p.len() < tokens {
            p.push(b' ' as i32);
        }
    }
    p
}

/// Generate the full timed workload (Poisson arrivals).
pub fn generate(spec: &WorkloadSpec) -> Vec<TimedRequest> {
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        t += rng.exp(spec.rate.max(1e-9));
        let (p_len, o_len) = shape(&mut rng, spec.scenario, spec.max_prompt, spec.max_output);
        let prompt = synth_prompt(&mut rng.fork(i as u64), p_len);
        out.push(TimedRequest {
            at: t,
            request: Request {
                id: i as u64 + 1,
                prompt,
                max_new_tokens: o_len,
                sample: SampleParams { temperature: 0.8, top_p: 0.95, seed: i as u64 },
                stop: Vec::new(),
            },
        });
    }
    out
}

/// Closed-loop load test: replay the workload against a scheduler,
/// respecting arrival times in *scheduler ticks* (the single-core testbed
/// has no wall-clock arrival fidelity; arrivals are mapped to ticks by
/// the requested rate so queueing behaviour is still exercised).
pub fn run_loadtest<B: Backend>(
    sched: &mut crate::coordinator::scheduler::Scheduler<B>,
    workload: Vec<TimedRequest>,
    ticks_per_second: f64,
) -> anyhow::Result<LoadtestReport> {
    let mut pending: std::collections::VecDeque<TimedRequest> = workload.into();
    let mut tick = 0u64;
    let t0 = std::time::Instant::now();
    let mut max_inflight = 0usize;
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut tick_faults = 0usize;
    while !pending.is_empty() || sched.pending() > 0 {
        let now = tick as f64 / ticks_per_second.max(1e-9);
        while pending.front().map_or(false, |r| r.at <= now) {
            sched.submit(pending.pop_front().unwrap().request);
        }
        // Chaos tolerance (`--chaos-seed`): a tick panic or
        // engine-global error fails the in-flight requests — mirroring
        // the engine-loop supervisor's teardown — and the replay
        // continues; every request still reaches exactly one terminal
        // outcome (completed or failed).
        let events = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.tick()))
            .map_err(|p| anyhow::anyhow!("{}", crate::util::fault::panic_message(p.as_ref())));
        match events.and_then(|r| r) {
            Ok(events) => {
                for ev in events {
                    match ev {
                        StepEvent::Finished { id } => {
                            completed += 1;
                            // claim each completion so nothing accumulates
                            let _ = sched.take_completion(id);
                        }
                        StepEvent::Failed { .. } => failed += 1,
                        StepEvent::Token { .. } => {}
                    }
                }
            }
            Err(e) => {
                tick_faults += 1;
                let ids =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.active_ids()))
                        .unwrap_or_default();
                for id in ids {
                    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sched.abort(id)))
                        .is_err()
                    {
                        sched.engine.kv_release(id);
                        sched.metrics.on_failed();
                    }
                    failed += 1;
                }
                eprintln!("[loadtest] engine fault on tick {}: {:#}", tick, e);
            }
        }
        max_inflight = max_inflight.max(sched.pending());
        tick += 1;
    }
    Ok(LoadtestReport {
        wall_secs: t0.elapsed().as_secs_f64(),
        ticks: tick,
        completed,
        failed,
        max_inflight,
        tokens_out: sched.metrics.tokens_out,
        tick_faults,
    })
}

#[derive(Debug, Clone)]
pub struct LoadtestReport {
    pub wall_secs: f64,
    pub ticks: u64,
    pub completed: usize,
    pub failed: usize,
    pub max_inflight: usize,
    pub tokens_out: u64,
    /// Ticks that ended in an engine panic or engine-global error
    /// (non-zero only under `--chaos-seed` fault injection).
    pub tick_faults: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing_and_rate_plausible() {
        let spec = WorkloadSpec { rate: 10.0, n_requests: 400, ..Default::default() };
        let w = generate(&spec);
        assert_eq!(w.len(), 400);
        assert!(w.windows(2).all(|p| p[0].at <= p[1].at));
        let span = w.last().unwrap().at;
        let rate = 400.0 / span;
        assert!((rate - 10.0).abs() < 2.5, "empirical rate {}", rate);
    }

    #[test]
    fn shapes_respect_scenario_bounds() {
        for scenario in [Scenario::LongInput, Scenario::LongGeneration, Scenario::Mixed] {
            let spec = WorkloadSpec { scenario, n_requests: 60, ..Default::default() };
            for tr in generate(&spec) {
                assert!(tr.request.prompt.len() <= spec.max_prompt);
                assert!(tr.request.max_new_tokens <= spec.max_output);
                assert!(tr.request.max_new_tokens >= 1);
            }
        }
        // long-input prompts longer than long-gen prompts on average
        let li = generate(&WorkloadSpec { scenario: Scenario::LongInput, n_requests: 50, ..Default::default() });
        let lg = generate(&WorkloadSpec { scenario: Scenario::LongGeneration, n_requests: 50, ..Default::default() });
        let avg = |w: &[TimedRequest]| {
            w.iter().map(|r| r.request.prompt.len()).sum::<usize>() as f64 / w.len() as f64
        };
        assert!(avg(&li) > 3.0 * avg(&lg));
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].request.prompt, b[3].request.prompt);
        let c = generate(&WorkloadSpec { seed: 1, ..spec });
        assert_ne!(a[3].request.prompt, c[3].request.prompt);
    }

    #[test]
    fn loadtest_over_sim_backend_completes_everything() {
        use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
        use crate::coordinator::sim_backend::SimBackend;
        let spec =
            WorkloadSpec { n_requests: 12, max_prompt: 64, max_output: 8, ..Default::default() };
        let w = generate(&spec);
        let mut sched = Scheduler::new(SimBackend::tiny(), SchedulerConfig::default());
        let report = run_loadtest(&mut sched, w, 1000.0).unwrap();
        assert_eq!(report.completed, 12);
        assert_eq!(report.failed, 0);
        assert!(report.max_inflight >= 1);
        assert!(sched.metrics.tokens_out > 0);
        assert_eq!(sched.pending(), 0);
    }

    #[test]
    fn prompts_are_tokenizer_valid() {
        let w = generate(&WorkloadSpec { n_requests: 5, ..Default::default() });
        for tr in w {
            assert!(tr.request.prompt.iter().all(|&t| (0..260).contains(&t)));
        }
    }
}
