//! Minimal HTTP/1.1 edge over the event-driven serving API. Hand-rolled
//! on `std::net` (the offline registry has no hyper/tokio): one acceptor
//! plus a thread per connection, all of them talking to the serving
//! tier only through a [`Router`] — so concurrent `/generate` requests
//! genuinely share decode batches instead of serializing behind a
//! single request/response loop. A bare [`Submitter`] *is* the
//! single-replica router (today's path, unchanged); multi-replica
//! deployments pass a `KvAwareRouter`/`RoundRobinRouter` over N engine
//! loops instead and the edge neither knows nor cares — dispatch,
//! health aggregation, and drain fan-out all live behind the trait.
//!
//! API:
//!   POST /generate  {"prompt": "...", "max_tokens": 64,
//!                    "temperature": 0.8, "top_p": 0.95, "seed": 7,
//!                    "stop": "###" | ["###", "\n\n"], "stream": false}
//!     -> 200 {"id", "text", "prompt_tokens", "generated", "finish_reason"}
//!     -> 400 malformed JSON / missing prompt
//!     -> 429 admission queue full (backpressure — retry later)
//!     with "stream": true -> chunked `text/event-stream`; each sampled
//!     token arrives as `data: {"event":"token","index":..,"token":..,
//!     "text":".."}` the moment it is emitted, terminated by one
//!     `data: {"event":"done",...}` (or `{"event":"error",...}`) event.
//!   GET  /metrics   -> one-line serving metrics (per-token TTFT/ITL
//!                      percentiles included)
//!   GET  /healthz   -> ok
//!
//! HTTP keep-alive: a client sending `Connection: keep-alive` gets a
//! per-connection request loop (bounded by
//! [`HttpLimits::keep_alive_idle`] between requests), so repeated
//! generations — a loadtest, a chat turn loop — stop paying TCP setup
//! per request. Opt-in only: without the header the edge keeps its
//! one-request-per-connection contract (clients that read to EOF),
//! and error responses and SSE streams always close. Requests are
//! processed strictly in order (no concurrent execution per
//! connection), but one `BufReader` spans the connection, so a client
//! that pipelines its next request early loses nothing.
//!
//! Robustness at the edge: request lines that aren't `METHOD SP PATH SP
//! HTTP/x` are rejected with 400, bodies above
//! [`HttpLimits::max_body_bytes`] with 413, a read timeout bounds how
//! long a stalled client can hold a connection thread, and a write
//! timeout bounds a client that stops reading its response. Connection
//! threads are capped ([`ServeOptions::max_connections`], derived from
//! the submitter's admission queue depth by default): past the cap,
//! generation requests get `503` instead of spawning unboundedly, while
//! a small probe headroom keeps `/healthz` and `/metrics` answering so
//! saturation is not mistaken for a dead engine loop. A kept-alive
//! connection counts against the cap only while serving a request:
//! parked idle between requests it releases its slot and re-acquires
//! one when the next request line arrives (`503` + close if the edge
//! saturated meanwhile). Client
//! disconnects cancel the in-flight session mid-generation, returning
//! its GPU slots and CPU pool pages to the free pool: streaming
//! sessions treat a failed chunk write *or* an EOF `peek` as
//! disconnect; buffered sessions only hard socket errors (a half-close
//! while awaiting the response is legal HTTP/1.1).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::engine::SampleParams;
use crate::coordinator::engine_loop::{SessionEvent, SessionHandle, SubmitError, Submitter};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::Request;
use crate::util::json::{Json, JsonObj};

/// How often waiting handlers poll the socket for client disconnect.
const DISCONNECT_POLL: Duration = Duration::from_millis(100);

/// Parsing limits for the HTTP edge.
#[derive(Debug, Clone)]
pub struct HttpLimits {
    /// Reject request bodies larger than this (413).
    pub max_body_bytes: usize,
    /// Reject request/header lines longer than this (400).
    pub max_line_bytes: usize,
    /// Reject requests with more headers than this (400).
    pub max_headers: usize,
    /// A client that stalls mid-request is dropped after this long.
    pub header_timeout: Duration,
    /// A client that stops reading its response is dropped after a
    /// blocked write exceeds this (frees the connection thread and
    /// cancels the session).
    pub write_timeout: Duration,
    /// How long a kept-alive connection may sit idle between requests
    /// before the server closes it (frees the connection-thread slot).
    pub keep_alive_idle: Duration,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_body_bytes: 1 << 20, // 1 MiB
            max_line_bytes: 8 << 10,
            max_headers: 100,
            header_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(30),
            keep_alive_idle: Duration::from_secs(5),
        }
    }
}

/// Why a request could not be read off the wire.
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line / headers — answer 400.
    BadRequest(String),
    /// Declared body exceeds the cap — answer 413.
    TooLarge { len: usize, cap: usize },
    /// Stalled or vanished client — drop the connection.
    Io(std::io::Error),
}

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// HTTP method (`GET`, `POST`, ...).
    pub method: String,
    /// Request path including any query string.
    pub path: String,
    /// Request body (empty if none).
    pub body: String,
    /// Client asked to reuse the connection (`Connection: keep-alive`).
    /// Opt-in only — without the explicit header the edge keeps its
    /// historical one-request-per-connection contract, so clients that
    /// read to EOF keep working.
    pub keep_alive: bool,
}

/// Read one line, capped at `cap` bytes.
fn take_line<R: BufRead>(r: &mut R, out: &mut String, cap: usize) -> Result<usize, HttpError> {
    out.clear();
    let n = r.by_ref().take(cap as u64 + 1).read_line(out).map_err(HttpError::Io)?;
    if n > cap {
        return Err(HttpError::BadRequest(format!("line exceeds {} bytes", cap)));
    }
    Ok(n)
}

/// Read one HTTP/1.1 request from a stream, enforcing `limits`.
pub fn read_request(stream: &mut TcpStream, limits: &HttpLimits) -> Result<HttpRequest, HttpError> {
    let mut reader = BufReader::new(stream.try_clone().map_err(HttpError::Io)?);
    read_request_from(&mut reader, stream, limits, None)
}

/// [`read_request`] over a caller-owned reader — the keep-alive loop
/// keeps ONE `BufReader` per connection so readahead bytes (a client
/// writing its next request early) survive across requests instead of
/// dying with a per-request reader. `idle` is the distinct first-byte
/// timeout for the *next* request line; the rest of the request runs on
/// the header timeout (timeouts are socket-level, shared with the
/// cloned reader FD). A clean EOF while waiting between keep-alive
/// requests surfaces as `Io` (normal close), not `BadRequest`.
fn read_request_from(
    reader: &mut BufReader<TcpStream>,
    stream: &TcpStream,
    limits: &HttpLimits,
    idle: Option<Duration>,
) -> Result<HttpRequest, HttpError> {
    stream
        .set_read_timeout(Some(idle.unwrap_or(limits.header_timeout)))
        .map_err(HttpError::Io)?;
    let mut line = String::new();
    if take_line(reader, &mut line, limits.max_line_bytes)? == 0 {
        if idle.is_some() {
            // the client closed between keep-alive requests: a normal
            // end of session, not a protocol error
            return Err(HttpError::Io(std::io::ErrorKind::UnexpectedEof.into()));
        }
        return Err(HttpError::BadRequest("empty request".into()));
    }
    if idle.is_some() {
        stream.set_read_timeout(Some(limits.header_timeout)).map_err(HttpError::Io)?;
    }
    let parts: Vec<String> = line.trim_end().split(' ').map(str::to_string).collect();
    if parts.len() != 3 {
        return Err(HttpError::BadRequest("malformed request line".into()));
    }
    let (method, path, version) = (&parts[0], &parts[1], &parts[2]);
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::BadRequest(format!("bad method {:?}", method)));
    }
    if !path.starts_with('/') {
        return Err(HttpError::BadRequest("path must start with '/'".into()));
    }
    if !version.starts_with("HTTP/") {
        return Err(HttpError::BadRequest("bad protocol version".into()));
    }

    let mut content_len = 0usize;
    let mut n_headers = 0usize;
    let mut keep_alive = false;
    loop {
        if take_line(&mut reader, &mut line, limits.max_line_bytes)? == 0 {
            return Err(HttpError::BadRequest("truncated headers".into()));
        }
        let h = line.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > limits.max_headers {
            return Err(HttpError::BadRequest(format!("more than {} headers", limits.max_headers)));
        }
        let Some((k, v)) = h.split_once(':') else {
            return Err(HttpError::BadRequest(format!("malformed header {:?}", h)));
        };
        if k.eq_ignore_ascii_case("content-length") {
            content_len = v
                .trim()
                .parse()
                .map_err(|_| HttpError::BadRequest(format!("bad content-length {:?}", v.trim())))?;
        } else if k.eq_ignore_ascii_case("transfer-encoding") {
            // Chunked (or any) request framing is unsupported; accepting
            // it while only draining Content-Length bytes would leave
            // the body on the wire for the keep-alive loop to parse as
            // the next request — a smuggling primitive. Reject and close
            // (the 400 path closes the connection).
            return Err(HttpError::BadRequest(format!(
                "Transfer-Encoding {:?} not supported; use Content-Length",
                v.trim()
            )));
        } else if k.eq_ignore_ascii_case("connection") {
            let wants_keep =
                v.split(',').any(|t| t.trim().eq_ignore_ascii_case("keep-alive"));
            let wants_close = v.split(',').any(|t| t.trim().eq_ignore_ascii_case("close"));
            keep_alive = wants_keep && !wants_close;
        }
    }
    if content_len > limits.max_body_bytes {
        return Err(HttpError::TooLarge { len: content_len, cap: limits.max_body_bytes });
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body).map_err(HttpError::Io)?;
    }
    Ok(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        body: String::from_utf8_lossy(&body).into_owned(),
        keep_alive,
    })
}

/// Write a complete HTTP response that closes the connection.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &str,
) -> Result<()> {
    write_response_conn(w, status, content_type, body, false)
}

/// Write a complete HTTP response, advertising keep-alive when the
/// connection will serve another request.
pub fn write_response_conn<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        status,
        reason,
        content_type,
        body.len(),
        conn,
        body
    )?;
    Ok(())
}

fn write_chunk<W: Write>(w: &mut W, data: &str) -> std::io::Result<()> {
    write!(w, "{:x}\r\n{}\r\n", data.len(), data)
}

fn finish_chunks<W: Write>(w: &mut W) -> std::io::Result<()> {
    write!(w, "0\r\n\r\n")
}

fn sse_data(j: Json) -> String {
    format!("data: {}\n\n", j.to_string_compact())
}

fn error_json(msg: &str) -> String {
    let mut obj = JsonObj::new();
    obj.insert("error", msg);
    Json::from(obj).to_string_compact()
}

/// Parse a `/generate` body into a request plus the stream flag.
/// Per-request sampling (`temperature`/`top_p`/`seed`) and `stop`
/// strings come straight from the JSON.
pub fn parse_generate(body: &str) -> Result<(Request, bool), String> {
    let parsed = Json::parse(body).map_err(|e| format!("invalid json: {}", e))?;
    let prompt = parsed.get("prompt").as_str().unwrap_or("");
    if prompt.is_empty() {
        return Err("missing prompt".into());
    }
    let max_tokens = parsed.get("max_tokens").as_usize().unwrap_or(32);
    let mut req = Request::from_text(0, prompt, max_tokens);
    req.sample = SampleParams {
        temperature: parsed.get("temperature").as_f64().unwrap_or(0.0) as f32,
        top_p: parsed.get("top_p").as_f64().unwrap_or(1.0) as f32,
        seed: parsed.get("seed").as_f64().unwrap_or(0.0) as u64,
    };
    req.stop = match parsed.get("stop") {
        Json::Str(s) => vec![s.clone()],
        Json::Arr(a) => a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect(),
        _ => Vec::new(),
    };
    let stream = parsed.get("stream").as_bool().unwrap_or(false);
    Ok((req, stream))
}

/// Has the peer abandoned the connection? Non-blocking-ish: a 1 ms
/// `peek` that treats timeouts as "still there". `eof_means_gone`
/// controls whether a read-side FIN counts: streaming clients hold the
/// connection fully open, so EOF there means the client died; buffered
/// clients may legitimately half-close their write side while waiting
/// for the response, so only hard errors count.
fn client_gone(stream: &TcpStream, eof_means_gone: bool) -> bool {
    let mut buf = [0u8; 1];
    if stream.set_read_timeout(Some(Duration::from_millis(1))).is_err() {
        return true;
    }
    match stream.peek(&mut buf) {
        Ok(0) => eof_means_gone,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
    }
}

/// Server behaviour knobs.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Exit after this many completed generations (None = run forever).
    pub max_requests: Option<usize>,
    /// Wire-parsing limits (line/header/body caps, read timeouts).
    pub limits: HttpLimits,
    /// Cap on generation-serving connection threads. `0` derives the
    /// cap from the router's aggregate admission depth (`2 * queue_cap`,
    /// min 8): every admissible session can hold a connection plus room for
    /// 429 rejections, but a connection flood can no longer spawn
    /// unbounded threads. At the cap, `/generate` connections are
    /// answered `503` and closed; a further [`PROBE_HEADROOM`] threads
    /// still serve `/healthz` and `/metrics` so probes stay truthful.
    pub max_connections: usize,
    /// Graceful-drain budget applied when the server shuts down
    /// ([`Router::drain`], which fans one shared deadline out to every
    /// replica): running sessions get this long to finish before being
    /// cancelled. Zero (the default) preserves the old
    /// cancel-everything shutdown.
    pub drain: Duration,
    /// External shutdown request (the signal handler in `freekv serve`
    /// sets it on Ctrl-C / SIGTERM): when the flag flips, the acceptor
    /// stops taking connections and begins the graceful drain. Whoever
    /// sets the flag must also poke the listener with a throwaway
    /// connection so a blocked `accept` wakes up.
    pub shutdown: Option<Arc<AtomicBool>>,
}

/// Extra connection threads allowed past [`ServeOptions::max_connections`]
/// that serve only cheap read-only endpoints (`/healthz`, `/metrics`).
/// This keeps the health contract truthful under a connection flood: a
/// saturated-but-alive instance still answers probes 200 instead of the
/// 503 that means "engine dead — restart me". Generation requests on
/// these overflow slots get the saturation 503.
const PROBE_HEADROOM: usize = 4;

/// RAII slot in the connection-thread budget: decrements on drop so a
/// panicking handler can't leak its slot.
struct ConnSlot(Arc<AtomicUsize>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Bind `addr` and serve. See [`serve_listener`].
pub fn serve<R: Router + 'static>(router: R, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_listener(listener, router, opts)
}

/// Serve connections from an already-bound listener: one thread per
/// connection, sessions multiplexed onto the serving tier through
/// `router` (a bare [`Submitter`] for the single-replica path, or a
/// multi-replica router). Returns once `max_requests` generations have
/// completed.
pub fn serve_listener<R: Router + 'static>(
    listener: TcpListener,
    router: R,
    opts: ServeOptions,
) -> Result<()> {
    let router = Arc::new(router);
    let local = listener.local_addr()?;
    println!("[freekv] serving on http://{}", local);
    let served = Arc::new(AtomicUsize::new(0));
    let engine_down = Arc::new(AtomicBool::new(false));
    let limits = Arc::new(opts.limits.clone());
    // Connection-thread budget tied to the admission queue depth: see
    // `ServeOptions::max_connections`.
    let conn_cap = if opts.max_connections > 0 {
        opts.max_connections
    } else {
        router.queue_cap().saturating_mul(2).max(8)
    };
    let active_conns = Arc::new(AtomicUsize::new(0));
    for stream in listener.incoming() {
        if opts.shutdown.as_ref().map_or(false, |f| f.load(Ordering::SeqCst)) {
            println!("[freekv] shutdown requested; draining in-flight sessions");
            break;
        }
        if engine_down.load(Ordering::SeqCst) {
            return Err(anyhow!("engine loop terminated; shutting down server"));
        }
        if opts.max_requests.map_or(false, |m| served.load(Ordering::SeqCst) >= m) {
            println!("[freekv] served {} generations, exiting", served.load(Ordering::SeqCst));
            break;
        }
        let Ok(mut stream) = stream else { continue };
        // Past cap + headroom: answer 503 from the acceptor (bounded
        // work — one short write with a timeout), no thread spawned.
        // Deliberate tradeoff: the request is never read, so a client
        // mid-way through a large body may see the close as a TCP RST
        // instead of the 503. Draining would serialize the acceptor
        // behind the very flood this path defends against; acceptor
        // liveness wins, and the in-headroom path below still answers
        // well-behaved probes properly.
        let prev = active_conns.fetch_add(1, Ordering::SeqCst);
        if prev >= conn_cap + PROBE_HEADROOM {
            active_conns.fetch_sub(1, Ordering::SeqCst);
            let _ = stream.set_write_timeout(Some(opts.limits.write_timeout));
            let msg = error_json(&format!(
                "connection limit reached ({} active); retry later",
                prev
            ));
            let _ = write_response(&mut stream, 503, "application/json", &msg);
            continue;
        }
        // Past the cap but within headroom: serve only probes (health/
        // metrics); generation requests get the saturation 503.
        let restricted = prev >= conn_cap;
        let slot = ConnSlot(active_conns.clone());
        let conns = active_conns.clone();
        let sub = router.clone();
        let served = served.clone();
        let engine_down = engine_down.clone();
        let limits = limits.clone();
        let max = opts.max_requests;
        thread::spawn(move || {
            handle_connection(
                &mut stream,
                &*sub,
                &limits,
                &served,
                &engine_down,
                &conns,
                conn_cap,
                slot,
                restricted,
            );
            // Completing the last generation — or noticing the engine
            // loop died — must unblock the acceptor.
            if engine_down.load(Ordering::SeqCst)
                || max.map_or(false, |m| served.load(Ordering::SeqCst) >= m)
            {
                let _ = TcpStream::connect(local);
            }
        });
    }
    // The edge is exiting: begin the serving-tier drain now — the
    // router fans one shared deadline out to every replica — so running
    // sessions keep decoding (new submissions are refused) while the
    // caller tears the process down. `ReplicaSet::shutdown_graceful` /
    // `EngineLoop::shutdown_graceful` then join the already-draining
    // loops.
    if !opts.drain.is_zero() {
        router.drain(opts.drain);
    }
    Ok(())
}

/// Serve requests off one connection. HTTP keep-alive is honored when
/// the client opts in with `Connection: keep-alive`: the thread loops
/// reading further requests (bounded by `HttpLimits::keep_alive_idle`
/// between them) so loadtest clients stop paying per-request TCP
/// setup. Without the header, one request per connection as before.
/// Error responses and SSE streams always close.
///
/// The connection-thread slot is only held while a request is actually
/// being served: a kept-alive connection parked between requests gives
/// its slot back (an idle client must not pin the budget for its whole
/// `keep_alive_idle` window) and re-acquires one when the next request
/// arrives — answered `503` and closed if the edge saturated meanwhile.
fn handle_connection<R: Router + ?Sized>(
    stream: &mut TcpStream,
    sub: &R,
    limits: &HttpLimits,
    served: &AtomicUsize,
    engine_down: &AtomicBool,
    conns: &Arc<AtomicUsize>,
    conn_cap: usize,
    slot: ConnSlot,
    restricted: bool,
) {
    // A peer that stops reading must not wedge this thread on a write.
    let _ = stream.set_write_timeout(Some(limits.write_timeout));
    // One reader for the whole connection: keep-alive readahead (a
    // client sending its next request early) stays buffered here
    // instead of being lost with a per-request reader.
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut first = true;
    let mut slot = Some(slot);
    let mut restricted = restricted;
    loop {
        let idle = if first { None } else { Some(limits.keep_alive_idle) };
        if !first {
            // Parked between keep-alive requests: release the slot so
            // idle connections don't count against the budget.
            slot = None;
        }
        first = false;
        let req = match read_request_from(&mut reader, stream, limits, idle) {
            Ok(r) => r,
            Err(HttpError::BadRequest(msg)) => {
                let _ = write_response(stream, 400, "application/json", &error_json(&msg));
                return;
            }
            Err(HttpError::TooLarge { len, cap }) => {
                let msg = format!("body of {} bytes exceeds cap of {}", len, cap);
                let _ = write_response(stream, 413, "application/json", &error_json(&msg));
                return;
            }
            Err(HttpError::Io(_)) => return, // stalled, idle-timed-out, or vanished client
        };
        if slot.is_none() {
            // The next keep-alive request arrived: re-acquire a slot
            // before doing any work. Mirrors the acceptor's admission:
            // past cap + headroom the request is refused outright; past
            // the cap but within headroom only probes are served.
            let prev = conns.fetch_add(1, Ordering::SeqCst);
            slot = Some(ConnSlot(conns.clone()));
            if prev >= conn_cap + PROBE_HEADROOM {
                let msg = error_json(&format!(
                    "connection limit reached ({} active); retry later",
                    prev
                ));
                let _ = write_response(stream, 503, "application/json", &msg);
                return;
            }
            restricted = prev >= conn_cap;
        }
        let keep = req.keep_alive;
        let again = match (req.method.as_str(), req.path.as_str()) {
            // Health is honest: it round-trips the engine loop, so a dead
            // loop flips this instance to 503 for load balancers.
            ("GET", "/healthz") => match sub.metrics_report() {
                // Alive: report the supervisor's ladder rung — "ok" or
                // "degraded" (engine restarted, executor worker dead,
                // recall gone serial). Both are 200: a degraded
                // instance still serves and must not be killed by its
                // load balancer.
                Ok(_) => {
                    let body = sub.health().as_str();
                    write_response_conn(stream, 200, "text/plain", body, keep).is_ok() && keep
                }
                Err(_) => {
                    engine_down.store(true, Ordering::SeqCst);
                    let _ = write_response(stream, 503, "text/plain", "down");
                    false
                }
            },
            ("GET", "/metrics") => match sub.metrics_report() {
                Ok(r) => write_response_conn(stream, 200, "text/plain", &r, keep).is_ok() && keep,
                Err(_) => {
                    engine_down.store(true, Ordering::SeqCst);
                    let _ = write_response(stream, 503, "text/plain", "engine unavailable");
                    false
                }
            },
            ("POST", "/generate") if restricted => {
                // Overflow (probe-headroom) slot: generation would hold
                // this thread for a whole session, which the cap exists
                // to bound.
                let msg = error_json("connection limit reached; retry later");
                let _ = write_response(stream, 503, "application/json", &msg);
                false
            }
            ("POST", "/generate") => {
                handle_generate(stream, sub, served, engine_down, &req.body, keep)
            }
            _ => {
                let _ = write_response(stream, 404, "text/plain", "not found");
                false
            }
        };
        if !again {
            return;
        }
    }
}

/// Returns whether the connection may serve another request.
fn handle_generate<R: Router + ?Sized>(
    stream: &mut TcpStream,
    sub: &R,
    served: &AtomicUsize,
    engine_down: &AtomicBool,
    body: &str,
    keep: bool,
) -> bool {
    let (req, stream_mode) = match parse_generate(body) {
        Ok(x) => x,
        Err(msg) => {
            let _ = write_response(stream, 400, "application/json", &error_json(&msg));
            return false;
        }
    };
    let handle = match sub.submit(req) {
        Ok(h) => h,
        Err(e @ SubmitError::Busy { .. }) => {
            // Backpressure keeps the connection usable: a keep-alive
            // loadtest client retries on the same socket.
            let _ = write_response_conn(
                stream,
                429,
                "application/json",
                &error_json(&e.to_string()),
                keep,
            );
            return keep;
        }
        Err(e @ SubmitError::Draining) => {
            // Shutting down but alive: 503 without tripping the
            // engine-down latch — in-flight sessions are still served.
            let _ = write_response(stream, 503, "application/json", &error_json(&e.to_string()));
            return false;
        }
        Err(SubmitError::Closed) => {
            engine_down.store(true, Ordering::SeqCst);
            let msg = error_json("engine unavailable");
            let _ = write_response(stream, 503, "application/json", &msg);
            return false;
        }
    };
    if stream_mode {
        // SSE streams end with the chunked terminator + close.
        stream_session(stream, &handle, served, engine_down);
        false
    } else {
        wait_session(stream, &handle, served, engine_down, keep)
    }
}

/// Buffered mode: wait for the terminal event, polling for client
/// disconnect so an abandoned request is cancelled instead of decoded
/// to completion. Returns whether the connection may serve another
/// request (keep-alive + clean 200).
fn wait_session(
    stream: &mut TcpStream,
    h: &SessionHandle,
    served: &AtomicUsize,
    engine_down: &AtomicBool,
    keep: bool,
) -> bool {
    loop {
        match h.recv_timeout(DISCONNECT_POLL) {
            Ok(SessionEvent::Token { .. }) => {}
            Ok(SessionEvent::Done(c)) => {
                let mut obj = JsonObj::new();
                obj.insert("id", c.id as usize);
                obj.insert("text", c.text);
                obj.insert("prompt_tokens", c.prompt_tokens);
                obj.insert("generated", c.generated_tokens);
                obj.insert("finish_reason", c.finish_reason.as_str());
                let body = Json::from(obj).to_string_compact();
                let ok = write_response_conn(stream, 200, "application/json", &body, keep).is_ok();
                served.fetch_add(1, Ordering::SeqCst);
                return ok && keep;
            }
            Ok(SessionEvent::Error(e)) => {
                let _ = write_response(stream, 500, "application/json", &error_json(&e));
                return false;
            }
            Err(RecvTimeoutError::Timeout) => {
                // EOF alone is not abandonment here: buffered clients
                // may half-close and still await the response.
                if client_gone(stream, false) {
                    h.cancel();
                    return false;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                engine_down.store(true, Ordering::SeqCst);
                let msg = error_json("engine shut down");
                let _ = write_response(stream, 503, "application/json", &msg);
                return false;
            }
        }
    }
}

/// Streaming mode: chunked SSE, one event per sampled token. A failed
/// chunk write or an EOF peek means the client is gone — cancel.
fn stream_session(
    stream: &mut TcpStream,
    h: &SessionHandle,
    served: &AtomicUsize,
    engine_down: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
                Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        h.cancel();
        return;
    }
    loop {
        match h.recv_timeout(DISCONNECT_POLL) {
            Ok(SessionEvent::Token { index, token, text }) => {
                let mut obj = JsonObj::new();
                obj.insert("event", "token");
                obj.insert("index", index);
                obj.insert("token", token as i64);
                obj.insert("text", text);
                if write_chunk(stream, &sse_data(Json::from(obj))).is_err() {
                    h.cancel();
                    return;
                }
            }
            Ok(SessionEvent::Done(c)) => {
                let mut obj = JsonObj::new();
                obj.insert("event", "done");
                obj.insert("id", c.id as usize);
                obj.insert("finish_reason", c.finish_reason.as_str());
                obj.insert("prompt_tokens", c.prompt_tokens);
                obj.insert("generated", c.generated_tokens);
                obj.insert("text", c.text);
                let _ = write_chunk(stream, &sse_data(Json::from(obj)));
                let _ = finish_chunks(stream);
                served.fetch_add(1, Ordering::SeqCst);
                return;
            }
            Ok(SessionEvent::Error(e)) => {
                let mut obj = JsonObj::new();
                obj.insert("event", "error");
                obj.insert("error", e);
                let _ = write_chunk(stream, &sse_data(Json::from(obj)));
                let _ = finish_chunks(stream);
                return;
            }
            Err(RecvTimeoutError::Timeout) => {
                if client_gone(stream, true) {
                    h.cancel();
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                engine_down.store(true, Ordering::SeqCst);
                let _ = finish_chunks(stream);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_parse_roundtrip() {
        // exercise the parser through a real socket pair
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s, &HttpLimits::default()).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/generate");
            assert_eq!(req.body, r#"{"prompt":"hi","max_tokens":4}"#);
            write_response(&mut s, 200, "application/json", r#"{"ok":true}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt":"hi","max_tokens":4}"#;
        write!(
            c,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut resp = String::new();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.ends_with(r#"{"ok":true}"#));
        h.join().unwrap();
    }

    /// Run the parser against one raw client payload.
    fn parse_raw(payload: &[u8], limits: HttpLimits) -> Result<HttpRequest, HttpError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let payload = payload.to_vec();
        let client = thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(&payload).unwrap();
            // hold the connection open so EOF doesn't mask timeouts
            thread::sleep(Duration::from_millis(300));
        });
        let (mut s, _) = listener.accept().unwrap();
        let r = read_request(&mut s, &limits);
        client.join().unwrap();
        r
    }

    #[test]
    fn garbage_request_line_is_bad_request() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x\r\n\r\n"[..],
            &b"get /x HTTP/1.1\r\n\r\n"[..],
            &b"GET x HTTP/1.1\r\n\r\n"[..],
            &b"GET /x SMTP\r\n\r\n"[..],
        ] {
            match parse_raw(raw, HttpLimits::default()) {
                Err(HttpError::BadRequest(_)) => {}
                other => {
                    panic!("expected BadRequest for {:?}, got {:?}", raw, other.map(|r| r.method))
                }
            }
        }
    }

    #[test]
    fn oversize_body_is_rejected_without_reading_it() {
        let raw = b"POST /generate HTTP/1.1\r\nContent-Length: 2097152\r\n\r\n";
        match parse_raw(raw, HttpLimits::default()) {
            Err(HttpError::TooLarge { len, cap }) => {
                assert_eq!(len, 2 << 20);
                assert_eq!(cap, 1 << 20);
            }
            other => panic!("expected TooLarge, got {:?}", other.map(|r| r.method)),
        }
    }

    #[test]
    fn bad_content_length_is_bad_request() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n";
        assert!(matches!(parse_raw(raw, HttpLimits::default()), Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn stalled_client_times_out() {
        let limits =
            HttpLimits { header_timeout: Duration::from_millis(100), ..Default::default() };
        let t0 = std::time::Instant::now();
        // request line arrives, then the client stalls before the blank line
        let r = parse_raw(b"POST /generate HTTP/1.1\r\nContent-Len", limits);
        assert!(matches!(r, Err(HttpError::Io(_))), "stall must surface as Io");
        assert!(t0.elapsed() < Duration::from_secs(2), "timeout must bound the stall");
    }

    #[test]
    fn overlong_line_is_bad_request() {
        let limits = HttpLimits { max_line_bytes: 64, ..Default::default() };
        let mut raw = b"GET /".to_vec();
        raw.extend(std::iter::repeat(b'a').take(200));
        raw.extend(b" HTTP/1.1\r\n\r\n");
        assert!(matches!(parse_raw(&raw, limits), Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn parse_generate_full_fields() {
        let (req, stream) = parse_generate(
            r#"{"prompt":"hello","max_tokens":7,"temperature":0.8,"top_p":0.9,
               "seed":42,"stop":["###","\n\n"],"stream":true}"#,
        )
        .unwrap();
        assert!(stream);
        assert_eq!(req.max_new_tokens, 7);
        assert!((req.sample.temperature - 0.8).abs() < 1e-6);
        assert!((req.sample.top_p - 0.9).abs() < 1e-6);
        assert_eq!(req.sample.seed, 42);
        assert_eq!(req.stop, vec!["###".to_string(), "\n\n".to_string()]);
        // prompt is BOS + bytes
        assert_eq!(req.prompt.len(), "hello".len() + 1);
    }

    #[test]
    fn parse_generate_defaults_and_scalar_stop() {
        let (req, stream) = parse_generate(r#"{"prompt":"p","stop":"x"}"#).unwrap();
        assert!(!stream);
        assert_eq!(req.max_new_tokens, 32);
        assert_eq!(req.sample.temperature, 0.0);
        assert_eq!(req.sample.top_p, 1.0);
        assert_eq!(req.stop, vec!["x".to_string()]);
    }

    #[test]
    fn parse_generate_rejects_bad_input() {
        assert!(parse_generate("not json").is_err());
        assert!(parse_generate(r#"{"max_tokens":4}"#).is_err());
        assert!(parse_generate(r#"{"prompt":""}"#).is_err());
    }

    #[test]
    fn transfer_encoding_is_rejected_not_smuggled() {
        // Accepting chunked framing while draining only Content-Length
        // would leave the body on the wire for the keep-alive loop to
        // parse as the next request.
        let raw =
            b"POST /generate HTTP/1.1\r\nTransfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n";
        match parse_raw(raw, HttpLimits::default()) {
            Err(HttpError::BadRequest(msg)) => assert!(msg.contains("Transfer-Encoding"), "{}", msg),
            other => panic!("expected BadRequest, got {:?}", other.map(|r| r.method)),
        }
    }

    #[test]
    fn keep_alive_is_explicit_opt_in() {
        let r = parse_raw(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n", HttpLimits::default())
            .unwrap();
        assert!(!r.keep_alive, "no Connection header keeps the close contract");
        let r = parse_raw(
            b"GET /healthz HTTP/1.1\r\nConnection: keep-alive\r\n\r\n",
            HttpLimits::default(),
        )
        .unwrap();
        assert!(r.keep_alive);
        let r = parse_raw(
            b"GET /healthz HTTP/1.1\r\nConnection: Keep-Alive, close\r\n\r\n",
            HttpLimits::default(),
        )
        .unwrap();
        assert!(!r.keep_alive, "close wins over keep-alive");
    }

    #[test]
    fn response_advertises_connection_mode() {
        let mut buf = Vec::new();
        write_response_conn(&mut buf, 200, "text/plain", "ok", true).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("Connection: keep-alive"), "{}", s);
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", "ok").unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("Connection: close"), "{}", s);
    }

    #[test]
    fn chunked_framing() {
        let mut buf = Vec::new();
        write_chunk(&mut buf, "data: {\"a\":1}\n\n").unwrap();
        finish_chunks(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s, "f\r\ndata: {\"a\":1}\n\n\r\n0\r\n\r\n");
    }
}
