//! Minimal HTTP/1.1 server for the serving example. Hand-rolled over
//! `std::net` (the offline registry has no hyper/tokio): one acceptor
//! thread feeding a request channel, the engine thread consuming it —
//! the PJRT runtime is single-threaded by design, so the coordinator
//! owns it and the network edge stays thin.
//!
//! API:
//!   POST /generate  {"prompt": "...", "max_tokens": 64}
//!     -> {"id": n, "text": "...", "prompt_tokens": n, "generated": n}
//!   GET  /metrics   -> one-line serving metrics report
//!   GET  /healthz   -> ok

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use crate::coordinator::scheduler::{Request, Scheduler};
use crate::util::json::{Json, JsonObj};

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one HTTP/1.1 request from a stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, path, body: String::from_utf8_lossy(&body).into_owned() })
}

/// Write an HTTP response.
pub fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        status,
        reason,
        content_type,
        body.len(),
        body
    )?;
    Ok(())
}

enum Job {
    Generate { req: HttpRequest, stream: TcpStream },
    Quick { req: HttpRequest, stream: TcpStream },
}

/// Serve until `max_requests` generations complete (None = forever).
/// Single engine thread (owns the PJRT client), one acceptor thread.
pub fn serve(mut sched: Scheduler, addr: &str, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("[freekv] serving on http://{}", listener.local_addr()?);
    let (tx, rx) = mpsc::channel::<Job>();

    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            match read_request(&mut stream) {
                Ok(req) => {
                    let job = if req.method == "POST" && req.path == "/generate" {
                        Job::Generate { req, stream }
                    } else {
                        Job::Quick { req, stream }
                    };
                    if tx.send(job).is_err() {
                        break;
                    }
                }
                Err(_) => {
                    let _ = write_response(&mut stream, 400, "text/plain", "bad request");
                }
            }
        }
    });

    let mut served = 0usize;
    let mut next_id = 1u64;
    for job in rx {
        match job {
            Job::Quick { req, mut stream } => {
                let _ = match (req.method.as_str(), req.path.as_str()) {
                    ("GET", "/healthz") => write_response(&mut stream, 200, "text/plain", "ok"),
                    ("GET", "/metrics") => {
                        write_response(&mut stream, 200, "text/plain", &sched.metrics.report())
                    }
                    _ => write_response(&mut stream, 404, "text/plain", "not found"),
                };
            }
            Job::Generate { req, mut stream } => {
                let parsed = Json::parse(&req.body).unwrap_or(Json::Null);
                let prompt = parsed.get("prompt").as_str().unwrap_or("").to_string();
                let max_tokens = parsed.get("max_tokens").as_usize().unwrap_or(32);
                if prompt.is_empty() {
                    let _ = write_response(&mut stream, 400, "application/json", r#"{"error":"missing prompt"}"#);
                    continue;
                }
                let id = next_id;
                next_id += 1;
                sched.submit(Request::from_text(id, &prompt, max_tokens));
                // Drive the scheduler until this request finishes (other
                // queued requests advance too — continuous batching).
                while !sched.completions.iter().any(|c| c.id == id) {
                    sched.tick()?;
                }
                let c = sched.completions.iter().find(|c| c.id == id).unwrap().clone();
                let mut obj = JsonObj::new();
                obj.insert("id", c.id as usize);
                obj.insert("text", c.text.clone());
                obj.insert("prompt_tokens", c.prompt_tokens);
                obj.insert("generated", c.generated_tokens);
                let _ = write_response(
                    &mut stream,
                    200,
                    "application/json",
                    &Json::from(obj).to_string_compact(),
                );
                served += 1;
                if let Some(max) = max_requests {
                    if served >= max {
                        println!("[freekv] served {} requests, exiting", served);
                        return Ok(());
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_parse_roundtrip() {
        // exercise the parser through a real socket pair
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/generate");
            assert_eq!(req.body, r#"{"prompt":"hi","max_tokens":4}"#);
            write_response(&mut s, 200, "application/json", r#"{"ok":true}"#).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let body = r#"{"prompt":"hi","max_tokens":4}"#;
        write!(
            c,
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let mut resp = String::new();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.ends_with(r#"{"ok":true}"#));
        h.join().unwrap();
    }
}
