//! Artifact-free engine backend: a deterministic token generator behind
//! the [`Backend`] trait, with real per-sequence KV/pool allocation and
//! an optional artificial per-step latency.
//!
//! The real `Engine` needs compiled artifacts plus a native PJRT client,
//! so the serving stack above it (scheduler, engine loop, HTTP edge)
//! would otherwise be untestable on hosts without the XLA backend. This
//! backend stands in for it: tokens are a pure function of the previous
//! token ([`sim_next_token`]), sequences allocate genuine `RequestKv`
//! state (so memory-accounting and cancellation tests measure the real
//! thing), and `step_delay` models device time so concurrency tests get
//! an honest overlap window. Also reachable from the CLI via
//! `freekv serve --sim` / `freekv loadtest --sim`.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::coordinator::engine::{Backend, EngineStats, Sequence};

/// The deterministic next-token function: an LCG over the previous
/// token, mapped to printable ASCII (so decoded text is readable and
/// never hits EOS). Exposed so tests can precompute expected output.
pub fn sim_next_token(last: i32) -> i32 {
    let x = (last as i64).wrapping_mul(1_103_515_245).wrapping_add(12_345);
    32 + (x.rem_euclid(95)) as i32
}

/// Geometry used by [`SimBackend::tiny`]: small enough that per-request
/// pools are cheap, large enough that long prompts complete pages and
/// exercise offload.
pub fn sim_config() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        n_layers: 2,
        d_model: 16,
        n_qo: 4,
        n_kv: 2,
        d_head: 4,
        d_ffn: 32,
        vocab: crate::coordinator::tokenizer::VOCAB,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        page_size: 4,
        max_context: 4096,
        sink_pages: 1,
        window_pages: 2,
        select_pages: 2,
        kv_elem_bytes: 4,
    }
}

pub struct SimBackend {
    cfg: ModelConfig,
    stats: EngineStats,
    /// Artificial wall time per decode step (device-time stand-in).
    pub step_delay: Duration,
    /// Prompts longer than this fail admission (models prefill buckets).
    pub max_prompt: usize,
}

impl SimBackend {
    pub fn new(cfg: ModelConfig) -> SimBackend {
        let max_prompt = cfg.max_context / 2;
        SimBackend { cfg, stats: EngineStats::default(), step_delay: Duration::ZERO, max_prompt }
    }

    pub fn tiny() -> SimBackend {
        SimBackend::new(sim_config())
    }
}

impl Backend for SimBackend {
    fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let len = seq.tokens.len();
        if len > self.max_prompt {
            return Err(anyhow!(
                "prompt of {} tokens exceeds sim bucket of {}",
                len,
                self.max_prompt
            ));
        }
        let kv_row = vec![0.0f32; self.cfg.n_kv * self.cfg.d_head];
        for _ in 0..len {
            for l in 0..self.cfg.n_layers {
                seq.kv.append(l, &kv_row, &kv_row, &mut seq.xfer);
            }
        }
        let mut logits = vec![0.0f32; self.cfg.vocab];
        let tok = sim_next_token(*seq.tokens.last().unwrap());
        logits[tok as usize] = 1.0;
        self.stats.prefills += 1;
        Ok(logits)
    }

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let n = seqs.len();
        self.stats.steps += 1;
        self.stats.max_batch_lanes = self.stats.max_batch_lanes.max(n as u64);
        if n > 1 {
            self.stats.batched_steps += 1;
        }
        let kv_row = vec![0.0f32; self.cfg.n_kv * self.cfg.d_head];
        for seq in seqs.iter_mut() {
            let tok = sim_next_token(*seq.tokens.last().unwrap());
            for l in 0..self.cfg.n_layers {
                seq.kv.append(l, &kv_row, &kv_row, &mut seq.xfer);
            }
            seq.tokens.push(tok);
            if Some(tok) == seq.eos {
                seq.finished = true;
            }
        }
        Ok(())
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::SampleParams;
    use crate::coordinator::tokenizer;

    #[test]
    fn deterministic_printable_stream() {
        let mut last = tokenizer::BOS;
        for _ in 0..200 {
            let t = sim_next_token(last);
            assert!((32..127).contains(&t), "non-printable {}", t);
            assert_eq!(t, sim_next_token(last));
            last = t;
        }
    }

    #[test]
    fn prefill_and_decode_advance_kv() {
        let mut b = SimBackend::tiny();
        let prompt = tokenizer::encode("hello sim backend");
        let plen = prompt.len();
        let mut seq = b.new_sequence(1, prompt, 8, SampleParams::greedy());
        let lg = b.prefill(&mut seq).unwrap();
        assert_eq!(lg.len(), b.cfg.vocab);
        assert_eq!(seq.kv.len(), plen);
        let first = crate::linalg::argmax(&lg) as i32;
        seq.tokens.push(first);
        let mut batch = [&mut seq];
        b.decode_step(&mut batch).unwrap();
        assert_eq!(seq.kv.len(), plen + 1);
        assert_eq!(seq.generated().len(), 2);
        assert_eq!(seq.generated()[1], sim_next_token(first));
    }

    #[test]
    fn oversize_prompt_is_per_request_error() {
        let mut b = SimBackend::tiny();
        b.max_prompt = 8;
        let mut seq = b.new_sequence(1, vec![65; 9], 4, SampleParams::greedy());
        assert!(b.prefill(&mut seq).is_err());
    }
}
