//! Artifact-free engine backend: a deterministic token generator behind
//! the [`Backend`] trait, with real per-sequence KV/pool allocation and
//! an optional artificial per-step latency.
//!
//! The real `Engine` needs compiled artifacts plus a native PJRT client,
//! so the serving stack above it (scheduler, engine loop, HTTP edge)
//! would otherwise be untestable on hosts without the XLA backend. This
//! backend stands in for it: tokens are a pure function of the previous
//! token ([`sim_next_token`]), sequences allocate genuine `RequestKv`
//! state (so memory-accounting and cancellation tests measure the real
//! thing), and `step_delay` models device time so concurrency tests get
//! an honest overlap window. Also reachable from the CLI via
//! `freekv serve --sim` / `freekv loadtest --sim`.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::ModelConfig;
use crate::coordinator::engine::{Backend, EngineStats, PrefillDone, SampleParams, Sequence};
use crate::kvcache::alloc::worst_case_pages;
use crate::kvcache::{AdmitDecision, KvPoolStats, Layout, PageAllocator};
use crate::util::fault::{FaultPlan, FaultSite};

/// The deterministic next-token function: an LCG over the previous
/// token, mapped to printable ASCII (so decoded text is readable and
/// never hits EOS). Exposed so tests can precompute expected output.
pub fn sim_next_token(last: i32) -> i32 {
    let x = (last as i64).wrapping_mul(1_103_515_245).wrapping_add(12_345);
    32 + (x.rem_euclid(95)) as i32
}

/// Geometry used by [`SimBackend::tiny`]: small enough that per-request
/// pools are cheap, large enough that long prompts complete pages and
/// exercise offload.
pub fn sim_config() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        n_layers: 2,
        d_model: 16,
        n_qo: 4,
        n_kv: 2,
        d_head: 4,
        d_ffn: 32,
        vocab: crate::coordinator::tokenizer::VOCAB,
        rope_theta: 1e4,
        rms_eps: 1e-5,
        page_size: 4,
        max_context: 4096,
        sink_pages: 1,
        window_pages: 2,
        select_pages: 2,
        kv_elem_bytes: 4,
    }
}

/// Deterministic in-process stand-in for the real engine: same
/// [`Backend`](crate::coordinator::engine::Backend) surface and the
/// same shared page allocator / admission ledger, but token generation
/// is a cheap hash of the prompt — so scheduler, pool, and chaos tests
/// run without a compiled runtime.
pub struct SimBackend {
    cfg: ModelConfig,
    stats: EngineStats,
    /// Artificial wall time per decode step (device-time stand-in).
    pub step_delay: Duration,
    /// Prompts longer than this fail admission (models prefill buckets).
    pub max_prompt: usize,
    /// Asynchronous-prefill model: a prefill handed to `prefill_begin`
    /// completes only after this many `prefill_poll` rounds (0 =
    /// immediate, the synchronous default). Lets scheduler tests prove
    /// decode proceeds while a prefill is in flight.
    pub prefill_ticks: usize,
    /// Deferred prefills: (remaining poll rounds, sequence).
    prefilling: Vec<(usize, Sequence)>,
    /// Decode-failure injection: `decode_step` errors when the batch
    /// contains any of these request ids (lane-containment tests).
    pub fail_decode_ids: Vec<u64>,
    /// Shared KV page allocator, exactly like the real engine's: every
    /// sequence's pool pages come from here, and `kv_admit` reserves
    /// against its capacity.
    alloc: Arc<PageAllocator>,
    /// Deterministic fault-injection plan: decode-time panics, decode
    /// errors, and allocator-lock panics fire at seed-derived call
    /// indices (chaos tests). `None` = production behavior.
    faults: Option<Arc<FaultPlan>>,
}

impl SimBackend {
    /// Backend over an unbounded, non-sharing pool.
    pub fn new(cfg: ModelConfig) -> SimBackend {
        SimBackend::with_pool(cfg, 0, false)
    }

    /// Backend over a bounded / prefix-sharing pool (capacity in pages
    /// across all layers, 0 = unbounded) — the knobs scheduler and
    /// memory tests drive.
    pub fn with_pool(cfg: ModelConfig, pool_pages: u64, prefix_cache: bool) -> SimBackend {
        let alloc = PageAllocator::for_model(&cfg, pool_pages, prefix_cache);
        SimBackend::with_allocator(cfg, alloc)
    }

    /// [`SimBackend::with_pool`] with an explicit page codec dtype
    /// (`--kv-dtype` on the sim serve/loadtest paths).
    pub fn with_pool_dtype(
        cfg: ModelConfig,
        pool_pages: u64,
        prefix_cache: bool,
        dtype: crate::kvcache::quant::KvDtype,
    ) -> SimBackend {
        let alloc = PageAllocator::for_model_dtype(&cfg, pool_pages, prefix_cache, dtype);
        SimBackend::with_allocator(cfg, alloc)
    }

    /// [`SimBackend::with_pool`] with an explicit prefix-cache mode and
    /// retention cap (`--prefix-cache=retained` / `--kv-retain-pages`
    /// on the sim serve/loadtest paths).
    pub fn with_pool_mode(
        cfg: ModelConfig,
        pool_pages: u64,
        mode: crate::kvcache::PrefixCacheMode,
        retain_cap: u64,
        dtype: crate::kvcache::quant::KvDtype,
    ) -> SimBackend {
        let alloc = PageAllocator::for_model_mode(&cfg, pool_pages, mode, retain_cap, dtype);
        SimBackend::with_allocator(cfg, alloc)
    }

    /// [`SimBackend::with_pool_mode`] with an explicit allocator lock
    /// layout (`--kv-lock` on the sim serve/loadtest paths).
    pub fn with_pool_opts(
        cfg: ModelConfig,
        pool_pages: u64,
        mode: crate::kvcache::PrefixCacheMode,
        retain_cap: u64,
        dtype: crate::kvcache::quant::KvDtype,
        lock: crate::kvcache::KvLockMode,
    ) -> SimBackend {
        let alloc =
            PageAllocator::for_model_lock(&cfg, pool_pages, mode, retain_cap, dtype, lock);
        SimBackend::with_allocator(cfg, alloc)
    }

    /// Backend over an existing allocator. Chaos tests use this to keep
    /// one allocator (and its page gauges) alive across supervised
    /// engine restarts, exactly like the real engine sharing its pool.
    pub fn with_allocator(cfg: ModelConfig, alloc: Arc<PageAllocator>) -> SimBackend {
        let max_prompt = cfg.max_context / 2;
        SimBackend {
            cfg,
            stats: EngineStats::default(),
            step_delay: Duration::ZERO,
            max_prompt,
            prefill_ticks: 0,
            prefilling: Vec::new(),
            fail_decode_ids: Vec::new(),
            alloc,
            faults: None,
        }
    }

    /// Backend over the tiny built-in test geometry.
    pub fn tiny() -> SimBackend {
        SimBackend::new(sim_config())
    }

    /// [`SimBackend::tiny`] over a bounded / prefix-sharing pool.
    pub fn tiny_with_pool(pool_pages: u64, prefix_cache: bool) -> SimBackend {
        SimBackend::with_pool(sim_config(), pool_pages, prefix_cache)
    }

    /// [`SimBackend::tiny_with_pool`] with an explicit page codec dtype.
    pub fn tiny_with_pool_dtype(
        pool_pages: u64,
        prefix_cache: bool,
        dtype: crate::kvcache::quant::KvDtype,
    ) -> SimBackend {
        SimBackend::with_pool_dtype(sim_config(), pool_pages, prefix_cache, dtype)
    }

    /// [`SimBackend::tiny`] over an explicit prefix-cache mode
    /// (f32 pages; see [`SimBackend::tiny_with_pool_mode_dtype`]).
    pub fn tiny_with_pool_mode(
        pool_pages: u64,
        mode: crate::kvcache::PrefixCacheMode,
        retain_cap: u64,
    ) -> SimBackend {
        SimBackend::tiny_with_pool_mode_dtype(
            pool_pages,
            mode,
            retain_cap,
            crate::kvcache::quant::KvDtype::F32,
        )
    }

    /// [`SimBackend::tiny_with_pool_mode`] with an explicit page codec
    /// dtype — the full knob set `--sim` serving exposes.
    pub fn tiny_with_pool_mode_dtype(
        pool_pages: u64,
        mode: crate::kvcache::PrefixCacheMode,
        retain_cap: u64,
        dtype: crate::kvcache::quant::KvDtype,
    ) -> SimBackend {
        SimBackend::with_pool_mode(sim_config(), pool_pages, mode, retain_cap, dtype)
    }

    /// [`SimBackend::tiny_with_pool_mode_dtype`] with an explicit
    /// allocator lock layout — the full knob set `--sim` serving
    /// exposes.
    pub fn tiny_with_pool_opts(
        pool_pages: u64,
        mode: crate::kvcache::PrefixCacheMode,
        retain_cap: u64,
        dtype: crate::kvcache::quant::KvDtype,
        lock: crate::kvcache::KvLockMode,
    ) -> SimBackend {
        SimBackend::with_pool_opts(sim_config(), pool_pages, mode, retain_cap, dtype, lock)
    }

    /// The backing allocator (tests and benches inspect its gauges).
    pub fn allocator(&self) -> Arc<PageAllocator> {
        self.alloc.clone()
    }

    /// Install a fault plan (shared with other backend incarnations in
    /// chaos tests so call indices keep advancing across restarts).
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    fn sync_kv_stats(&mut self) {
        self.stats.sync_kv(&self.alloc.stats());
    }

    fn complete_prefill(&mut self, mut seq: Sequence) -> PrefillDone {
        let result = self.prefill(&mut seq);
        PrefillDone { seq, result }
    }
}

impl Backend for SimBackend {
    fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_sequence(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        sample: SampleParams,
    ) -> Sequence {
        Sequence::with_alloc(
            id,
            &self.cfg,
            prompt,
            max_new,
            Layout::Hnd,
            sample,
            self.alloc.clone(),
        )
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let len = seq.tokens.len();
        if len > self.max_prompt {
            return Err(anyhow!(
                "prompt of {} tokens exceeds sim bucket of {}",
                len,
                self.max_prompt
            ));
        }
        // prompt fully known: key completed pages for prefix sharing,
        // then adopt the longest cached prefix (resident or retained)
        // before any K/V lands — adopted pages skip their offload in
        // `RequestKv::append`, so the decode path stays bit-identical
        // to a cold prefill while the pool write work is saved.
        seq.kv.feed_tokens(&seq.tokens);
        self.stats.prefill_tokens_saved += seq.kv.adopt_prefix() as u64;
        let kv_row = vec![0.0f32; self.cfg.n_kv * self.cfg.d_head];
        for _ in 0..len {
            for l in 0..self.cfg.n_layers {
                seq.kv.append(l, &kv_row, &kv_row, &mut seq.xfer);
            }
        }
        let mut logits = vec![0.0f32; self.cfg.vocab];
        let tok = sim_next_token(*seq.tokens.last().unwrap());
        logits[tok as usize] = 1.0;
        self.stats.prefills += 1;
        self.sync_kv_stats();
        Ok(logits)
    }

    fn prefill_begin(&mut self, mut seq: Sequence) -> Option<PrefillDone> {
        if self.prefill_ticks == 0 {
            let result = self.prefill(&mut seq);
            return Some(PrefillDone { seq, result });
        }
        self.prefilling.push((self.prefill_ticks, seq));
        None
    }

    fn prefill_poll(&mut self) -> Vec<PrefillDone> {
        for slot in self.prefilling.iter_mut() {
            slot.0 = slot.0.saturating_sub(1);
        }
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.prefilling.len() {
            if self.prefilling[i].0 == 0 {
                let (_, seq) = self.prefilling.remove(i);
                out.push(self.complete_prefill(seq));
            } else {
                i += 1;
            }
        }
        out
    }

    fn prefill_wait(&mut self) -> Vec<PrefillDone> {
        if self.prefilling.is_empty() {
            return Vec::new();
        }
        let (_, seq) = self.prefilling.remove(0);
        vec![self.complete_prefill(seq)]
    }

    fn prefills_inflight(&self) -> usize {
        self.prefilling.len()
    }

    fn prefill_cancel(&mut self, id: u64) -> Option<Sequence> {
        let i = self.prefilling.iter().position(|(_, s)| s.id == id)?;
        Some(self.prefilling.remove(i).1)
    }

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        if let Some(plan) = &self.faults {
            if plan.check(FaultSite::EnginePanic) {
                panic!("injected engine panic (sim decode step)");
            }
            if plan.check(FaultSite::AllocPanic) {
                // Panics while holding an allocator lock, poisoning it
                // exactly like a crashed critical section. Alternate
                // between the metadata lock and a schedule-chosen slab
                // shard so every lock class's deliberate recovery
                // (`lock_timed` in `kvcache::alloc`) is exercised on a
                // live pool.
                let n = plan.injected() as usize;
                if n % 2 == 0 {
                    self.alloc.panic_while_locked("sim decode step");
                } else {
                    self.alloc.panic_while_locked_shard(n / 2, "sim decode step");
                }
            }
            if plan.check(FaultSite::DecodeError) {
                self.stats.faults_injected = plan.injected();
                return Err(anyhow!("injected engine-global decode error"));
            }
            self.stats.faults_injected = plan.injected();
        }
        if let Some(seq) = seqs.iter().find(|s| self.fail_decode_ids.contains(&s.id)) {
            return Err(anyhow!("injected decode failure for request {}", seq.id));
        }
        let n = seqs.len();
        self.stats.steps += 1;
        self.stats.max_batch_lanes = self.stats.max_batch_lanes.max(n as u64);
        if n > 1 {
            self.stats.batched_steps += 1;
        }
        let kv_row = vec![0.0f32; self.cfg.n_kv * self.cfg.d_head];
        for seq in seqs.iter_mut() {
            let tok = sim_next_token(*seq.tokens.last().unwrap());
            // the K/V appended belongs to the current last token
            seq.kv.feed_tokens(&seq.tokens);
            for l in 0..self.cfg.n_layers {
                seq.kv.append(l, &kv_row, &kv_row, &mut seq.xfer);
            }
            seq.tokens.push(tok);
            if Some(tok) == seq.eos {
                seq.finished = true;
            }
        }
        self.sync_kv_stats();
        Ok(())
    }

    fn kv_admit(&mut self, id: u64, prompt_tokens: usize, max_new: usize) -> AdmitDecision {
        let footprint = worst_case_pages(&self.cfg, prompt_tokens.saturating_add(max_new));
        self.alloc.try_reserve(id, footprint)
    }

    fn kv_release(&mut self, id: u64) {
        self.alloc.release_reservation(id);
    }

    fn kv_stats(&self) -> KvPoolStats {
        self.alloc.stats()
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::SampleParams;
    use crate::coordinator::tokenizer;

    #[test]
    fn deterministic_printable_stream() {
        let mut last = tokenizer::BOS;
        for _ in 0..200 {
            let t = sim_next_token(last);
            assert!((32..127).contains(&t), "non-printable {}", t);
            assert_eq!(t, sim_next_token(last));
            last = t;
        }
    }

    #[test]
    fn prefill_and_decode_advance_kv() {
        let mut b = SimBackend::tiny();
        let prompt = tokenizer::encode("hello sim backend");
        let plen = prompt.len();
        let mut seq = b.new_sequence(1, prompt, 8, SampleParams::greedy());
        let lg = b.prefill(&mut seq).unwrap();
        assert_eq!(lg.len(), b.cfg.vocab);
        assert_eq!(seq.kv.len(), plen);
        let first = crate::linalg::argmax(&lg) as i32;
        seq.tokens.push(first);
        let mut batch = [&mut seq];
        b.decode_step(&mut batch).unwrap();
        assert_eq!(seq.kv.len(), plen + 1);
        assert_eq!(seq.generated().len(), 2);
        assert_eq!(seq.generated()[1], sim_next_token(first));
    }

    #[test]
    fn oversize_prompt_is_per_request_error() {
        let mut b = SimBackend::tiny();
        b.max_prompt = 8;
        let mut seq = b.new_sequence(1, vec![65; 9], 4, SampleParams::greedy());
        assert!(b.prefill(&mut seq).is_err());
    }

    #[test]
    fn lane_failure_leaves_other_lanes_intact() {
        // The default decode_step_lanes contract: a failing lane is
        // contained — every other lane still appends its token, and the
        // failed lane's sequences simply don't advance this step.
        let mut b = SimBackend::tiny();
        let mut seqs: Vec<Sequence> = (1..=3u64)
            .map(|i| {
                let mut seq = b.new_sequence(
                    i,
                    tokenizer::encode("lane fail "),
                    8,
                    SampleParams::greedy(),
                );
                let lg = b.prefill(&mut seq).unwrap();
                let tok = crate::linalg::argmax(&lg) as i32;
                seq.tokens.push(tok);
                seq
            })
            .collect();
        b.fail_decode_ids.push(2);
        {
            let mut iter = seqs.iter_mut();
            let mut lanes: Vec<Vec<&mut Sequence>> = vec![
                vec![iter.next().unwrap()],
                vec![iter.next().unwrap()],
                vec![iter.next().unwrap()],
            ];
            let err = b.decode_step_lanes(&mut lanes).unwrap_err();
            assert!(format!("{err:#}").contains("injected"), "{err:#}");
        }
        assert_eq!(seqs[0].generated().len(), 2, "lane before the failure advanced");
        assert_eq!(seqs[1].generated().len(), 1, "failed lane did not advance");
        assert_eq!(seqs[2].generated().len(), 2, "lane after the failure advanced");
    }

    #[test]
    fn deferred_prefill_completes_after_polls() {
        let mut b = SimBackend::tiny();
        b.prefill_ticks = 2;
        let seq = b.new_sequence(5, tokenizer::encode("deferred "), 4, SampleParams::greedy());
        assert!(b.prefill_begin(seq).is_none(), "prefill deferred");
        assert_eq!(b.prefills_inflight(), 1);
        assert!(b.prefill_poll().is_empty(), "one round remaining");
        let done = b.prefill_poll();
        assert_eq!(done.len(), 1);
        assert!(done[0].result.is_ok());
        assert_eq!(done[0].seq.id, 5);
        assert_eq!(b.prefills_inflight(), 0);
    }
}
