//! Serving metrics, measured per token: TTFT (arrival → first sampled
//! token, queueing included), inter-token latency (ITL), TPOT, and
//! end-to-end latency histograms plus throughput counters. Reported by
//! the `/metrics` endpoint, the load-test driver, and the benches.

use std::time::Instant;

use crate::util::stats::Histogram;

/// Per-request timestamps, updated as the scheduler emits tokens.
#[derive(Debug, Clone)]
pub struct RequestTiming {
    /// When the request arrived (queueing counts toward TTFT).
    pub arrived: Instant,
    /// When prefill completed (TTFT fallback if no token sampled yet).
    pub prefill_done: Option<Instant>,
    /// When the first output token was sampled (TTFT endpoint).
    pub first_token: Option<Instant>,
    /// When the most recent output token was sampled (ITL base).
    pub last_token: Option<Instant>,
    /// When the request reached a terminal state.
    pub finished: Option<Instant>,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Output tokens sampled so far.
    pub generated_tokens: usize,
}

impl RequestTiming {
    /// Timing record stamped with the current instant as arrival.
    pub fn new(prompt_tokens: usize) -> RequestTiming {
        RequestTiming {
            arrived: Instant::now(),
            prefill_done: None,
            first_token: None,
            last_token: None,
            finished: None,
            prompt_tokens,
            generated_tokens: 0,
        }
    }

    /// Time to first token in seconds (prefill-done fallback).
    pub fn ttft(&self) -> Option<f64> {
        self.first_token
            .or(self.prefill_done)
            .map(|t| (t - self.arrived).as_secs_f64())
    }

    /// End-to-end latency in seconds (arrival → finish).
    pub fn e2e(&self) -> Option<f64> {
        self.finished.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// time-per-output-token after the first.
    pub fn tpot(&self) -> Option<f64> {
        let start = self.first_token.or(self.prefill_done);
        match (start, self.finished) {
            (Some(p), Some(f)) if self.generated_tokens > 1 => {
                Some((f - p).as_secs_f64() / (self.generated_tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Time-to-first-token histogram (seconds).
    pub ttft: Histogram,
    /// Inter-token latency: gap between consecutive sampled tokens of
    /// one request (the streaming user's perceived cadence).
    pub itl: Histogram,
    /// Time-per-output-token histogram (seconds).
    pub tpot: Histogram,
    /// End-to-end latency histogram (seconds).
    pub e2e: Histogram,
    /// Requests that arrived.
    pub requests: u64,
    /// Requests that completed normally.
    pub completed: u64,
    /// Requests cancelled mid-flight.
    pub cancelled: u64,
    /// Requests that failed.
    pub failed: u64,
    /// Prompt tokens accepted.
    pub tokens_in: u64,
    /// Output tokens sampled.
    pub tokens_out: u64,
    /// Times the engine-loop supervisor rebuilt the engine after a
    /// panic or engine-global error (carried across the restarts it
    /// counts).
    pub engine_restarts: u64,
    /// When this metrics window opened (throughput denominator).
    pub started: Option<Instant>,
}

impl Metrics {
    /// Fresh metrics with the throughput clock started now.
    pub fn new() -> Metrics {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    /// Record a request arrival.
    pub fn on_arrival(&mut self, prompt_tokens: usize) {
        self.requests += 1;
        self.tokens_in += prompt_tokens as u64;
    }

    /// Record one sampled token: updates the request's timestamps and
    /// the TTFT (first token) / ITL (subsequent tokens) histograms.
    pub fn on_token(&mut self, t: &mut RequestTiming) {
        let now = Instant::now();
        match t.last_token {
            None => {
                t.first_token = Some(now);
                self.ttft.record((now - t.arrived).as_secs_f64());
            }
            Some(prev) => self.itl.record((now - prev).as_secs_f64()),
        }
        t.last_token = Some(now);
        t.generated_tokens += 1;
        self.tokens_out += 1;
    }

    /// Record a normal completion (folds TPOT and E2E into histograms).
    pub fn on_complete(&mut self, t: &RequestTiming) {
        self.completed += 1;
        if let Some(x) = t.tpot() {
            self.tpot.record(x);
        }
        if let Some(x) = t.e2e() {
            self.e2e.record(x);
        }
    }

    /// Record a cancellation.
    pub fn on_cancelled(&mut self) {
        self.cancelled += 1;
    }

    /// Record a failure.
    pub fn on_failed(&mut self) {
        self.failed += 1;
    }

    /// Output tokens per second since the window opened.
    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_out as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    /// One-line human-readable summary (counters + latency percentiles).
    pub fn report(&self) -> String {
        format!(
            "requests={} completed={} cancelled={} failed={} engine_restarts={} tokens_out={} \
             throughput={:.1} tok/s \
             ttft p50={:.1}ms p95={:.1}ms p99={:.1}ms \
             itl p50={:.1}ms p95={:.1}ms p99={:.1}ms \
             tpot p50={:.1}ms e2e p50={:.2}s",
            self.requests,
            self.completed,
            self.cancelled,
            self.failed,
            self.engine_restarts,
            self.tokens_out,
            self.throughput_tok_s(),
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(95.0) * 1e3,
            self.ttft.percentile(99.0) * 1e3,
            self.itl.percentile(50.0) * 1e3,
            self.itl.percentile(95.0) * 1e3,
            self.itl.percentile(99.0) * 1e3,
            self.tpot.percentile(50.0) * 1e3,
            self.e2e.percentile(50.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn timing_math() {
        let mut t = RequestTiming::new(10);
        let base = t.arrived;
        t.first_token = Some(base + Duration::from_millis(100));
        t.finished = Some(base + Duration::from_millis(1100));
        t.generated_tokens = 11;
        assert!((t.ttft().unwrap() - 0.1).abs() < 1e-9);
        assert!((t.tpot().unwrap() - 0.1).abs() < 1e-9);
        assert!((t.e2e().unwrap() - 1.1).abs() < 1e-9);
    }

    #[test]
    fn ttft_falls_back_to_prefill_done() {
        let mut t = RequestTiming::new(4);
        let base = t.arrived;
        t.prefill_done = Some(base + Duration::from_millis(50));
        assert!((t.ttft().unwrap() - 0.05).abs() < 1e-9);
    }

    #[test]
    fn per_token_accounting() {
        let mut m = Metrics::new();
        m.on_arrival(5);
        let mut t = RequestTiming::new(5);
        for _ in 0..6 {
            m.on_token(&mut t);
        }
        t.finished = Some(Instant::now());
        m.on_complete(&t);
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens_out, 6);
        assert_eq!(t.generated_tokens, 6);
        assert_eq!(m.ttft.count(), 1);
        assert_eq!(m.itl.count(), 5);
        assert!(t.first_token.is_some() && t.last_token.is_some());
        let r = m.report();
        assert!(r.contains("completed=1"), "{}", r);
        assert!(r.contains("itl p50="), "{}", r);
    }

    #[test]
    fn cancelled_and_failed_counters() {
        let mut m = Metrics::new();
        m.on_arrival(1);
        m.on_arrival(1);
        m.on_cancelled();
        m.on_failed();
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.failed, 1);
        assert!(m.report().contains("cancelled=1"));
    }
}
