//! Serving metrics: TTFT / TPOT / end-to-end latency histograms and
//! throughput counters, reported by the server and the bench drivers.

use std::time::Instant;

use crate::util::stats::Histogram;

#[derive(Debug, Clone)]
pub struct RequestTiming {
    pub arrived: Instant,
    pub prefill_done: Option<Instant>,
    pub finished: Option<Instant>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
}

impl RequestTiming {
    pub fn new(prompt_tokens: usize) -> RequestTiming {
        RequestTiming {
            arrived: Instant::now(),
            prefill_done: None,
            finished: None,
            prompt_tokens,
            generated_tokens: 0,
        }
    }

    pub fn ttft(&self) -> Option<f64> {
        self.prefill_done.map(|t| (t - self.arrived).as_secs_f64())
    }

    pub fn e2e(&self) -> Option<f64> {
        self.finished.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// time-per-output-token after the first.
    pub fn tpot(&self) -> Option<f64> {
        match (self.prefill_done, self.finished) {
            (Some(p), Some(f)) if self.generated_tokens > 1 => {
                Some((f - p).as_secs_f64() / (self.generated_tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
    pub requests: u64,
    pub completed: u64,
    pub tokens_in: u64,
    pub tokens_out: u64,
    pub started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn on_arrival(&mut self, prompt_tokens: usize) {
        self.requests += 1;
        self.tokens_in += prompt_tokens as u64;
    }

    pub fn on_complete(&mut self, t: &RequestTiming) {
        self.completed += 1;
        self.tokens_out += t.generated_tokens as u64;
        if let Some(x) = t.ttft() {
            self.ttft.record(x);
        }
        if let Some(x) = t.tpot() {
            self.tpot.record(x);
        }
        if let Some(x) = t.e2e() {
            self.e2e.record(x);
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_out as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} completed={} tokens_out={} throughput={:.1} tok/s \
             ttft p50={:.1}ms p99={:.1}ms tpot p50={:.1}ms p99={:.1}ms e2e p50={:.2}s",
            self.requests,
            self.completed,
            self.tokens_out,
            self.throughput_tok_s(),
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(99.0) * 1e3,
            self.tpot.percentile(50.0) * 1e3,
            self.tpot.percentile(99.0) * 1e3,
            self.e2e.percentile(50.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn timing_math() {
        let mut t = RequestTiming::new(10);
        let base = t.arrived;
        t.prefill_done = Some(base + Duration::from_millis(100));
        t.finished = Some(base + Duration::from_millis(1100));
        t.generated_tokens = 11;
        assert!((t.ttft().unwrap() - 0.1).abs() < 1e-9);
        assert!((t.tpot().unwrap() - 0.1).abs() < 1e-9);
        assert!((t.e2e().unwrap() - 1.1).abs() < 1e-9);
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = Metrics::new();
        m.on_arrival(5);
        let mut t = RequestTiming::new(5);
        t.prefill_done = Some(t.arrived);
        t.finished = Some(t.arrived + std::time::Duration::from_millis(50));
        t.generated_tokens = 6;
        m.on_complete(&t);
        assert_eq!(m.completed, 1);
        assert_eq!(m.tokens_out, 6);
        assert!(m.report().contains("completed=1"));
    }
}
