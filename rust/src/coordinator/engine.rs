//! The real inference engine: drives the AOT artifacts through the FreeKV
//! data path — per-layer QKV, fine-grained correction, gathered-page
//! attention, append/offload, and speculative selection+recall for the
//! next step. Python is never touched; everything runs over the PJRT CPU
//! client against `artifacts/`.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{FreeKvParams, ModelConfig};
use crate::kvcache::{Layout, RequestKv};
use crate::policies::freekv::{correction_check, SpecState};
use crate::runtime::{HostTensor, Runtime};
use crate::transfer::TransferEngine;
use crate::util::rng::Rng;

/// Wall-time breakdown of the real pipeline (per engine, cumulative).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub qkv_secs: f64,
    pub attn_secs: f64,
    pub select_secs: f64,
    pub gather_secs: f64,
    pub recall_secs: f64,
    pub logits_secs: f64,
    pub steps: u64,
    pub prefills: u64,
    pub corrections: u64,
    pub correction_checks: u64,
    pub recalled_pages: u64,
    pub speculative_hits: u64,
}

impl EngineStats {
    pub fn correction_rate(&self) -> f64 {
        if self.correction_checks == 0 {
            0.0
        } else {
            self.corrections as f64 / self.correction_checks as f64
        }
    }
}

/// Sampling parameters.
#[derive(Debug, Clone)]
pub struct SampleParams {
    pub temperature: f32,
    pub top_p: f32,
    pub seed: u64,
}

impl SampleParams {
    pub fn greedy() -> SampleParams {
        SampleParams { temperature: 0.0, top_p: 1.0, seed: 0 }
    }
}

/// One in-flight sequence (request) with its KV state.
pub struct Sequence {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub kv: RequestKv,
    pub xfer: TransferEngine,
    pub sample: SampleParams,
    pub rng: Rng,
    pub finished: bool,
    pub eos: Option<i32>,
    spec: Vec<SpecState>,
    /// scratch gather buffers (reused every layer/step).
    gk: Vec<f32>,
    gv: Vec<f32>,
    gvalid: Vec<f32>,
}

impl Sequence {
    pub fn new(id: u64, cfg: &ModelConfig, prompt: Vec<i32>, max_new: usize, layout: Layout, sample: SampleParams) -> Sequence {
        let s = cfg.budget_slots();
        Sequence {
            id,
            prompt_len: prompt.len(),
            tokens: prompt,
            max_new_tokens: max_new,
            kv: RequestKv::new(cfg, layout),
            xfer: TransferEngine::new(cfg.page_size, cfg.d_head, true),
            rng: Rng::new(sample.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15)),
            sample,
            finished: false,
            eos: None,
            spec: (0..cfg.n_layers).map(|_| SpecState::new(cfg.n_qo, cfg.n_kv, cfg.d_head)).collect(),
            gk: vec![0.0; cfg.n_kv * s * cfg.d_head],
            gv: vec![0.0; cfg.n_kv * s * cfg.d_head],
            gvalid: vec![0.0; cfg.n_kv * s],
        }
    }

    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    pub fn pos(&self) -> usize {
        self.kv.len()
    }

    pub fn done(&self) -> bool {
        self.finished || self.generated().len() >= self.max_new_tokens
    }
}

/// The engine: owns the runtime handle + model config and executes the
/// decode pipeline for batches of sequences.
pub struct Engine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub cfg_name: String,
    pub params: FreeKvParams,
    pub stats: EngineStats,
    /// disable speculation+correction entirely: run selection blocking
    /// each step (tau=1-like reference mode).
    pub blocking_mode: bool,
    /// when set, per-head query similarities are recorded as
    /// (layer, sims[n_qo]) tuples each decode step (Fig. 3 / Table 8).
    pub record_sims: bool,
    pub sim_trace: Vec<(usize, Vec<f32>)>,
}

impl Engine {
    pub fn new(rt: Runtime, cfg_name: &str, params: FreeKvParams) -> Result<Engine> {
        let cfg = rt.manifest.config(cfg_name)?.clone();
        Ok(Engine {
            rt,
            cfg,
            cfg_name: cfg_name.to_string(),
            params,
            stats: EngineStats::default(),
            blocking_mode: false,
            record_sims: false,
            sim_trace: Vec::new(),
        })
    }

    pub fn art(&self, name: &str) -> String {
        format!("{}_{}", self.cfg_name, name)
    }

    /// Create a fresh sequence for a prompt.
    pub fn new_sequence(&self, id: u64, prompt: Vec<i32>, max_new: usize, sample: SampleParams) -> Sequence {
        Sequence::new(id, &self.cfg, prompt, max_new, Layout::Hnd, sample)
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run prefill for one sequence; returns the next-token logits.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let len = seq.tokens.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(len)
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds prefill buckets", len))?;

        let mut toks = seq.tokens.clone();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = (0..len as i32).collect();
        pos.resize(bucket, -1);
        let mut valid = vec![1.0f32; len];
        valid.resize(bucket, 0.0);

        let h = self
            .rt
            .run(&self.art(&format!("embed_t{}", bucket)), &[HostTensor::I32(toks, vec![bucket])], None)?
            .remove(0);
        let mut h = h;
        let pos_t = HostTensor::I32(pos, vec![bucket]);
        let valid_t = HostTensor::F32(valid, vec![bucket]);
        let mut q_last_per_layer: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);

        for l in 0..cfg.n_layers {
            let out = self.rt.run(
                &self.art(&format!("layer_prefill_t{}", bucket)),
                &[h.clone(), pos_t.clone(), valid_t.clone()],
                Some(l),
            )?;
            let mut it = out.into_iter();
            h = it.next().unwrap();
            let k = it.next().unwrap().into_f32s()?;
            let v = it.next().unwrap().into_f32s()?;
            let q_last = it.next().unwrap().into_f32s()?;
            // populate GPU cache + offload completed pages
            let st = &mut seq.kv.layers[l];
            let completed = st.gpu.load_prefill(&k, &v, len, bucket);
            for cp in &completed {
                seq.xfer.offload_page(cp, &mut st.pool);
            }
            q_last_per_layer.push(q_last);
        }

        // Final logits of the last valid token.
        let lg = self
            .rt
            .run(
                &self.art(&format!("logits_t{}", bucket)),
                &[h],
                None,
            )?
            .remove(0)
            .into_f32s()?;
        let row = &lg[(len - 1) * cfg.vocab..len * cfg.vocab];

        // Seed speculation: select with the last prompt token's query.
        for l in 0..cfg.n_layers {
            let q = &q_last_per_layer[l];
            let sel = self.run_selection_single(seq, l, q)?;
            for (m, pages) in sel.iter().enumerate() {
                let n = seq.kv.apply_selection(l, m, pages, &mut seq.xfer);
                self.stats.recalled_pages += n as u64;
            }
            seq.spec[l].store(q);
        }

        self.stats.prefills += 1;
        self.stats.prefill_secs += t0.elapsed().as_secs_f64();
        Ok(row.to_vec())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// Run one decode step for a batch of sequences (all must have at
    /// least one token; finished lanes are skipped by the caller).
    /// Appends the sampled token to each sequence.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        let t_step = Instant::now();
        let cfg = self.cfg.clone();
        let n = seqs.len();
        let bucket = self
            .rt
            .manifest
            .decode_bucket(n)
            .ok_or_else(|| anyhow!("batch {} exceeds decode buckets", n))?;
        let (m, dh, qo, s) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.budget_slots());

        // ---- embed ----
        let mut toks: Vec<i32> = seqs.iter().map(|q| *q.tokens.last().unwrap()).collect();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = seqs.iter().map(|q| q.pos() as i32).collect();
        pos.resize(bucket, 0);
        let mut h = self
            .rt
            .run(&self.art(&format!("embed_b{}", bucket)), &[HostTensor::I32(toks, vec![bucket])], None)?
            .remove(0);
        let pos_t = HostTensor::I32(pos, vec![bucket]);

        for l in 0..cfg.n_layers {
            // ---- QKV (split from attention so correction can intercept
            // between computing q_i and attending, per Fig. 4b) ----
            let t0 = Instant::now();
            let out = self.rt.run(
                &self.art(&format!("layer_qkv_b{}", bucket)),
                &[h.clone(), pos_t.clone()],
                Some(l),
            )?;
            self.stats.qkv_secs += t0.elapsed().as_secs_f64();
            let mut it = out.into_iter();
            let q_t = it.next().unwrap();
            let k_new_t = it.next().unwrap();
            let v_new_t = it.next().unwrap();
            let q_all = q_t.f32s()?.to_vec();
            let k_new = k_new_t.f32s()?.to_vec();
            let v_new = v_new_t.f32s()?.to_vec();

            // ---- selection with the current step's queries (batched):
            // used NOW for corrected heads, and for the NEXT step's
            // speculative reuse. ----
            let t0 = Instant::now();
            let sel_pages = self.run_selection_batch(seqs, l, &q_all, bucket)?;
            self.stats.select_secs += t0.elapsed().as_secs_f64();

            // ---- correction check + blocking recall for flagged heads --
            for (i, seq) in seqs.iter_mut().enumerate() {
                let q_i = &q_all[i * qo * dh..(i + 1) * qo * dh];
                // Following the paper (App. A), compression heuristics are
                // not applied to the first layer: its query similarity is
                // inherently low (h = embedding only), so layer 0 always
                // runs blocking selection and is excluded from correction
                // statistics.
                let decision = if self.blocking_mode || l == 0 {
                    None
                } else {
                    seq.spec[l].head_similarities(q_i).map(|sims| {
                        self.stats.correction_checks += m as u64;
                        if self.record_sims {
                            self.sim_trace.push((l, sims.clone()));
                        }
                        correction_check(&sims, m, &self.params)
                    })
                };
                match decision {
                    Some(d) => {
                        for &head in &d.corrected_heads {
                            self.stats.corrections += 1;
                            let t1 = Instant::now();
                            let nrec = seq.kv.apply_selection(
                                l,
                                head,
                                &sel_pages[i][head],
                                &mut seq.xfer,
                            );
                            self.stats.recall_secs += t1.elapsed().as_secs_f64();
                            self.stats.recalled_pages += nrec as u64;
                        }
                        let hit = m - d.corrected_heads.len();
                        self.stats.speculative_hits += hit as u64;
                    }
                    None => {
                        // blocking/first-layer path: install the current
                        // selection before attention.
                        for head in 0..m {
                            let t1 = Instant::now();
                            let nrec = seq.kv.apply_selection(
                                l,
                                head,
                                &sel_pages[i][head],
                                &mut seq.xfer,
                            );
                            self.stats.recall_secs += t1.elapsed().as_secs_f64();
                            self.stats.recalled_pages += nrec as u64;
                        }
                    }
                }
            }

            // ---- gather + attention ----
            let t0 = Instant::now();
            let (gk, gv, gvalid) = self.gather_batch(seqs, l, bucket);
            self.stats.gather_secs += t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let out = self.rt.run(
                &self.art(&format!("layer_attn_b{}", bucket)),
                &[
                    h,
                    q_t.clone(),
                    k_new_t.clone(),
                    v_new_t.clone(),
                    HostTensor::F32(gk, vec![bucket, m, s, dh]),
                    HostTensor::F32(gv, vec![bucket, m, s, dh]),
                    HostTensor::F32(gvalid, vec![bucket, m, s]),
                ],
                Some(l),
            )?;
            self.stats.attn_secs += t0.elapsed().as_secs_f64();
            h = out.into_iter().next().unwrap();

            // ---- append new KV, offload completed pages ----
            for (i, seq) in seqs.iter_mut().enumerate() {
                let kn = &k_new[i * m * dh..(i + 1) * m * dh];
                let vn = &v_new[i * m * dh..(i + 1) * m * dh];
                seq.kv.append(l, kn, vn, &mut seq.xfer);
            }

            // ---- speculative recall for the NEXT step (non-corrected
            // heads; page-cache diff makes re-selection cheap) ----
            if !self.blocking_mode {
                for (i, seq) in seqs.iter_mut().enumerate() {
                    for head in 0..m {
                        let t1 = Instant::now();
                        let nrec =
                            seq.kv.apply_selection(l, head, &sel_pages[i][head], &mut seq.xfer);
                        self.stats.recall_secs += t1.elapsed().as_secs_f64();
                        self.stats.recalled_pages += nrec as u64;
                    }
                }
            }

            // remember q for the next step's correction check
            for (i, seq) in seqs.iter_mut().enumerate() {
                seq.spec[l].store(&q_all[i * qo * dh..(i + 1) * qo * dh]);
            }
        }

        // ---- logits + sampling ----
        let t0 = Instant::now();
        let lg = self
            .rt
            .run(&self.art(&format!("logits_b{}", bucket)), &[h], None)?
            .remove(0)
            .into_f32s()?;
        self.stats.logits_secs += t0.elapsed().as_secs_f64();
        for (i, seq) in seqs.iter_mut().enumerate() {
            let row = &lg[i * cfg.vocab..(i + 1) * cfg.vocab];
            let tok = sample_token(row, &seq.sample, &mut seq.rng);
            seq.tokens.push(tok);
            if Some(tok) == seq.eos {
                seq.finished = true;
            }
        }

        self.stats.steps += 1;
        self.stats.decode_secs += t_step.elapsed().as_secs_f64();
        Ok(())
    }

    /// Gather every sequence's resident pages into batch tensors.
    fn gather_batch(
        &self,
        seqs: &mut [&mut Sequence],
        layer: usize,
        bucket: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let cfg = &self.cfg;
        let (m, dh, s) = (cfg.n_kv, cfg.d_head, cfg.budget_slots());
        let mut gk = vec![0.0f32; bucket * m * s * dh];
        let mut gv = vec![0.0f32; bucket * m * s * dh];
        let mut gvalid = vec![0.0f32; bucket * m * s];
        for (i, seq) in seqs.iter_mut().enumerate() {
            let st = &seq.kv.layers[layer];
            st.gpu.gather(&mut seq.gk, &mut seq.gv, &mut seq.gvalid);
            gk[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&seq.gk);
            gv[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&seq.gv);
            gvalid[i * m * s..(i + 1) * m * s].copy_from_slice(&seq.gvalid);
        }
        (gk, gv, gvalid)
    }

    /// Batched page selection via the select artifact; returns pages per
    /// (sequence, kv head), filtered to genuinely selectable pages.
    fn run_selection_batch(
        &mut self,
        seqs: &mut [&mut Sequence],
        layer: usize,
        q_all: &[f32],
        bucket: usize,
    ) -> Result<Vec<Vec<Vec<usize>>>> {
        let cfg = &self.cfg;
        let (m, dh, qo, p) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.n_pages_max());
        let mut q = q_all.to_vec();
        q.resize(bucket * qo * dh, 0.0);
        let mut smin = vec![0.0f32; bucket * m * p * dh];
        let mut smax = vec![0.0f32; bucket * m * p * dh];
        let mut mask = vec![0.0f32; bucket * p];
        let mut masks: Vec<Vec<f32>> = Vec::with_capacity(seqs.len());
        for (i, seq) in seqs.iter().enumerate() {
            let gpu = &seq.kv.layers[layer].gpu;
            let (lo, hi) = gpu.summaries_sanitized();
            smin[i * m * p * dh..(i + 1) * m * p * dh].copy_from_slice(&lo);
            smax[i * m * p * dh..(i + 1) * m * p * dh].copy_from_slice(&hi);
            let mk = gpu.selectable_mask();
            mask[i * p..(i + 1) * p].copy_from_slice(&mk);
            masks.push(mk);
        }
        let variant = self.params.variant.as_str();
        let out = self.rt.run(
            &self.art(&format!("select_{}_b{}", variant, bucket)),
            &[
                HostTensor::F32(q, vec![bucket, qo, dh]),
                HostTensor::F32(smin, vec![bucket, m, p, dh]),
                HostTensor::F32(smax, vec![bucket, m, p, dh]),
                HostTensor::F32(mask, vec![bucket, p]),
            ],
            None,
        )?;
        let idx = out[1].i32s()?;
        let k_sel = cfg.select_pages;
        let mut result = Vec::with_capacity(seqs.len());
        for (i, mk) in masks.iter().enumerate() {
            let mut per_head = Vec::with_capacity(m);
            for head in 0..m {
                let base = (i * m + head) * k_sel;
                let pages: Vec<usize> = idx[base..base + k_sel]
                    .iter()
                    .map(|&x| x as usize)
                    .filter(|&pg| pg < p && mk[pg] > 0.0)
                    .collect();
                per_head.push(pages);
            }
            result.push(per_head);
        }
        Ok(result)
    }

    /// Selection for a single sequence (prefill seeding path, bucket 1).
    fn run_selection_single(
        &mut self,
        seq: &mut Sequence,
        layer: usize,
        q: &[f32],
    ) -> Result<Vec<Vec<usize>>> {
        let cfg = &self.cfg;
        let (m, dh, qo, p) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.n_pages_max());
        let gpu = &seq.kv.layers[layer].gpu;
        let (smin, smax) = gpu.summaries_sanitized();
        let mask = gpu.selectable_mask();
        let variant = self.params.variant.as_str();
        let out = self.rt.run(
            &self.art(&format!("select_{}_b1", variant)),
            &[
                HostTensor::F32(q.to_vec(), vec![1, qo, dh]),
                HostTensor::F32(smin, vec![1, m, p, dh]),
                HostTensor::F32(smax, vec![1, m, p, dh]),
                HostTensor::F32(mask.clone(), vec![1, p]),
            ],
            None,
        )?;
        let idx = out[1].i32s()?;
        let k_sel = cfg.select_pages;
        Ok((0..m)
            .map(|head| {
                idx[head * k_sel..(head + 1) * k_sel]
                    .iter()
                    .map(|&x| x as usize)
                    .filter(|&pg| pg < p && mask[pg] > 0.0)
                    .collect()
            })
            .collect())
    }

    /// Convenience: generate to completion for a single sequence.
    pub fn generate(&mut self, seq: &mut Sequence) -> Result<()> {
        let lg = self.prefill(seq)?;
        let params = seq.sample.clone();
        let tok = sample_token(&lg, &params, &mut seq.rng);
        seq.tokens.push(tok);
        if Some(tok) == seq.eos {
            seq.finished = true;
        }
        while !seq.done() {
            let mut batch = [&mut *seq];
            self.decode_step(&mut batch)?;
        }
        Ok(())
    }
}

/// Temperature + nucleus sampling (greedy when temperature == 0).
pub fn sample_token(logits: &[f32], p: &SampleParams, rng: &mut Rng) -> i32 {
    if p.temperature <= 0.0 {
        return crate::linalg::argmax(logits) as i32;
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| x / p.temperature).collect();
    crate::linalg::softmax_inplace(&mut probs);
    if p.top_p < 1.0 {
        let mut order: Vec<usize> = (0..probs.len()).collect();
        order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut acc = 0.0f32;
        let mut cut = probs.len();
        for (rank, &i) in order.iter().enumerate() {
            acc += probs[i];
            if acc >= p.top_p {
                cut = rank + 1;
                break;
            }
        }
        let keep: std::collections::HashSet<usize> = order[..cut].iter().cloned().collect();
        for (i, pr) in probs.iter_mut().enumerate() {
            if !keep.contains(&i) {
                *pr = 0.0;
            }
        }
    }
    rng.categorical(&probs) as i32
}
