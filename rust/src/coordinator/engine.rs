//! The real inference engine: drives the AOT artifacts through the FreeKV
//! data path — per-layer QKV, fine-grained correction, gathered-page
//! attention, append/offload, and speculative selection+recall for the
//! next step. Python is never touched; everything runs over the PJRT CPU
//! client against `artifacts/`.
//!
//! Speculative recall is dispatched to the background worker of
//! `transfer::pipeline` (when `FreeKvParams::overlap` is set): layer
//! *l*'s next-step recall runs while this thread computes layers
//! *l+1..L* and the step's logits, and is drained at the next step's
//! correction check. Gather is incremental: each sequence keeps
//! per-layer persistent batch-lane tensors that only dirty slots are
//! rewritten into.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{FreeKvParams, ModelConfig};
use crate::kvcache::{Layout, RequestKv};
use crate::policies::freekv::{correction_check, SpecState};
use crate::runtime::{HostTensor, Runtime};
use crate::transfer::{RecallJob, RecallPipeline, TransferEngine};
use crate::util::rng::Rng;

/// Distinguishes Sequence objects even when callers reuse request ids
/// (the recall pipeline keys in-flight work by this uid).
static SEQ_UID: AtomicU64 = AtomicU64::new(1);

/// Wall-time breakdown of the real pipeline (per engine, cumulative).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub qkv_secs: f64,
    pub attn_secs: f64,
    pub select_secs: f64,
    pub gather_secs: f64,
    pub recall_secs: f64,
    pub logits_secs: f64,
    /// Recall wall time spent on the background worker (off the decode
    /// critical path).
    pub recall_hidden_secs: f64,
    /// Recall latency the decode thread actually waited for: blocking
    /// correction recalls, serial-mode speculative recall, and drain
    /// waits on the worker.
    pub recall_exposed_secs: f64,
    /// Speculative-recall jobs handed to the background worker.
    pub recall_jobs: u64,
    /// Peak number of jobs simultaneously in flight on the worker.
    pub max_queue_depth: u64,
    pub steps: u64,
    /// Decode steps that carried ≥ 2 sequences (continuous batching
    /// actually interleaving concurrent requests).
    pub batched_steps: u64,
    /// Largest number of sequences decoded together in one step.
    pub max_batch_lanes: u64,
    pub prefills: u64,
    pub corrections: u64,
    pub correction_checks: u64,
    pub recalled_pages: u64,
    pub speculative_hits: u64,
}

impl EngineStats {
    pub fn correction_rate(&self) -> f64 {
        if self.correction_checks == 0 {
            0.0
        } else {
            self.corrections as f64 / self.correction_checks as f64
        }
    }

    /// Fraction of recall wall time hidden behind compute (0 when every
    /// transfer blocked the decode thread).
    pub fn recall_hidden_fraction(&self) -> f64 {
        let total = self.recall_hidden_secs + self.recall_exposed_secs;
        if total <= 0.0 {
            0.0
        } else {
            self.recall_hidden_secs / total
        }
    }
}

/// The engine interface the scheduler drives. `Engine` is the real
/// artifact-backed implementation; `coordinator::sim_backend::SimBackend`
/// is an artifact-free stand-in for tests, benches, and `--sim` serving.
///
/// Contract: `prefill` fills the sequence's KV state for the prompt and
/// returns next-token logits (the scheduler samples the first token);
/// `decode_step` appends exactly one sampled token to every sequence in
/// the batch; `retire_sequence` releases any engine-held resources of a
/// sequence leaving mid-generation (the sequence's KV memory itself is
/// freed when the `Sequence` drops).
pub trait Backend {
    fn model(&self) -> &ModelConfig;

    fn new_sequence(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        sample: SampleParams,
    ) -> Sequence {
        Sequence::new(id, self.model(), prompt, max_new, Layout::Hnd, sample)
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>>;

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()>;

    /// Mid-flight retirement hook: reclaim in-flight transfer state so a
    /// cancelled sequence strands nothing on background workers.
    fn retire_sequence(&mut self, _seq: &mut Sequence) {}

    fn stats(&self) -> &EngineStats;
}

/// Sampling parameters.
#[derive(Debug, Clone)]
pub struct SampleParams {
    pub temperature: f32,
    pub top_p: f32,
    pub seed: u64,
}

impl SampleParams {
    pub fn greedy() -> SampleParams {
        SampleParams { temperature: 0.0, top_p: 1.0, seed: 0 }
    }
}

/// Per-layer persistent gather destination (one batch lane).
struct GatherBuf {
    k: Vec<f32>,
    v: Vec<f32>,
    valid: Vec<f32>,
}

/// One in-flight sequence (request) with its KV state.
pub struct Sequence {
    pub id: u64,
    uid: u64,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub kv: RequestKv,
    pub xfer: TransferEngine,
    pub sample: SampleParams,
    pub rng: Rng,
    pub finished: bool,
    pub eos: Option<i32>,
    spec: Vec<SpecState>,
    /// per-layer persistent gather lanes (incrementally maintained).
    gather: Vec<GatherBuf>,
}

impl Sequence {
    pub fn new(id: u64, cfg: &ModelConfig, prompt: Vec<i32>, max_new: usize, layout: Layout, sample: SampleParams) -> Sequence {
        let s = cfg.budget_slots();
        Sequence {
            id,
            uid: SEQ_UID.fetch_add(1, Ordering::Relaxed),
            prompt_len: prompt.len(),
            tokens: prompt,
            max_new_tokens: max_new,
            kv: RequestKv::new(cfg, layout),
            xfer: TransferEngine::new(cfg.page_size, cfg.d_head, true),
            rng: Rng::new(sample.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15)),
            sample,
            finished: false,
            eos: None,
            spec: (0..cfg.n_layers).map(|_| SpecState::new(cfg.n_qo, cfg.n_kv, cfg.d_head)).collect(),
            gather: (0..cfg.n_layers)
                .map(|_| GatherBuf {
                    k: vec![0.0; cfg.n_kv * s * cfg.d_head],
                    v: vec![0.0; cfg.n_kv * s * cfg.d_head],
                    valid: vec![0.0; cfg.n_kv * s],
                })
                .collect(),
        }
    }

    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    pub fn pos(&self) -> usize {
        self.kv.len()
    }

    pub fn done(&self) -> bool {
        self.finished || self.generated().len() >= self.max_new_tokens
    }
}

/// Reused artifact-input scratch for batched selection (the smin/smax
/// planes are the largest per-step host allocations; rebuilding them
/// every layer/step is pure waste).
struct SelScratch {
    bucket: usize,
    /// [q, smin, smax, mask] in the select artifact's argument order.
    args: Vec<HostTensor>,
}

/// The engine: owns the runtime handle + model config and executes the
/// decode pipeline for batches of sequences.
pub struct Engine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub cfg_name: String,
    pub params: FreeKvParams,
    pub stats: EngineStats,
    /// disable speculation+correction entirely: run selection blocking
    /// each step (tau=1-like reference mode).
    pub blocking_mode: bool,
    /// when set, per-head query similarities are recorded as
    /// (layer, sims[n_qo]) tuples each decode step (Fig. 3 / Table 8).
    pub record_sims: bool,
    pub sim_trace: Vec<(usize, Vec<f32>)>,
    /// background recall worker (lazily spawned when overlap is active).
    pipeline: Option<RecallPipeline>,
    sel_scratch: Option<SelScratch>,
    /// reclaimed batch gather tensors (gk, gv, gvalid).
    attn_scratch: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

impl Engine {
    pub fn new(rt: Runtime, cfg_name: &str, params: FreeKvParams) -> Result<Engine> {
        let cfg = rt.manifest.config(cfg_name)?.clone();
        Ok(Engine {
            rt,
            cfg,
            cfg_name: cfg_name.to_string(),
            params,
            stats: EngineStats::default(),
            blocking_mode: false,
            record_sims: false,
            sim_trace: Vec::new(),
            pipeline: None,
            sel_scratch: None,
            attn_scratch: None,
        })
    }

    pub fn art(&self, name: &str) -> String {
        format!("{}_{}", self.cfg_name, name)
    }

    /// Create a fresh sequence for a prompt.
    pub fn new_sequence(&self, id: u64, prompt: Vec<i32>, max_new: usize, sample: SampleParams) -> Sequence {
        Sequence::new(id, &self.cfg, prompt, max_new, Layout::Hnd, sample)
    }

    fn overlap_active(&self) -> bool {
        self.params.overlap && !self.blocking_mode
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Run prefill for one sequence; returns the next-token logits.
    pub fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let len = seq.tokens.len();
        let bucket = self
            .rt
            .manifest
            .prefill_bucket(len)
            .ok_or_else(|| anyhow!("prompt of {} tokens exceeds prefill buckets", len))?;

        let mut toks = seq.tokens.clone();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = (0..len as i32).collect();
        pos.resize(bucket, -1);
        let mut valid = vec![1.0f32; len];
        valid.resize(bucket, 0.0);

        let h = self
            .rt
            .run(&self.art(&format!("embed_t{}", bucket)), &[HostTensor::I32(toks, vec![bucket])], None)?
            .remove(0);
        let mut h = h;
        let pos_t = HostTensor::I32(pos, vec![bucket]);
        let valid_t = HostTensor::F32(valid, vec![bucket]);
        let mut q_last_per_layer: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers);

        for l in 0..cfg.n_layers {
            let out = self.rt.run(
                &self.art(&format!("layer_prefill_t{}", bucket)),
                &[h.clone(), pos_t.clone(), valid_t.clone()],
                Some(l),
            )?;
            let mut it = out.into_iter();
            h = it.next().unwrap();
            let k = it.next().unwrap().into_f32s()?;
            let v = it.next().unwrap().into_f32s()?;
            let q_last = it.next().unwrap().into_f32s()?;
            // populate GPU cache + offload completed pages
            let st = &mut seq.kv.layers[l];
            let completed = st.gpu.load_prefill(&k, &v, len, bucket);
            let x = st.xfer_mut();
            for cp in &completed {
                seq.xfer.offload_page(cp, &mut x.pool);
            }
            q_last_per_layer.push(q_last);
        }

        // Final logits of the last valid token.
        let lg = self
            .rt
            .run(
                &self.art(&format!("logits_t{}", bucket)),
                &[h],
                None,
            )?
            .remove(0)
            .into_f32s()?;
        let row = &lg[(len - 1) * cfg.vocab..len * cfg.vocab];

        // Seed speculation: select with the last prompt token's query.
        for l in 0..cfg.n_layers {
            let q = &q_last_per_layer[l];
            let sel = self.run_selection_single(seq, l, q)?;
            for (m, pages) in sel.iter().enumerate() {
                let n = seq.kv.apply_selection(l, m, pages, &mut seq.xfer);
                self.stats.recalled_pages += n as u64;
            }
            seq.spec[l].store(q);
        }

        self.stats.prefills += 1;
        self.stats.prefill_secs += t0.elapsed().as_secs_f64();
        Ok(row.to_vec())
    }

    // ------------------------------------------------------------------
    // Decode
    // ------------------------------------------------------------------

    /// Run one decode step for a batch of sequences (all must have at
    /// least one token; finished lanes are skipped by the caller).
    /// Appends the sampled token to each sequence.
    pub fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        let t_step = Instant::now();
        let cfg = self.cfg.clone();
        let n = seqs.len();
        self.stats.max_batch_lanes = self.stats.max_batch_lanes.max(n as u64);
        if n > 1 {
            self.stats.batched_steps += 1;
        }
        let bucket = self
            .rt
            .manifest
            .decode_bucket(n)
            .ok_or_else(|| anyhow!("batch {} exceeds decode buckets", n))?;
        let (m, dh, qo, s) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.budget_slots());
        let overlap = self.overlap_active();
        if overlap && self.pipeline.is_none() {
            self.pipeline = Some(RecallPipeline::new(cfg.page_size, cfg.d_head));
        }

        // ---- embed ----
        let mut toks: Vec<i32> = seqs.iter().map(|q| *q.tokens.last().unwrap()).collect();
        toks.resize(bucket, 0);
        let mut pos: Vec<i32> = seqs.iter().map(|q| q.pos() as i32).collect();
        pos.resize(bucket, 0);
        let mut h = self
            .rt
            .run(&self.art(&format!("embed_b{}", bucket)), &[HostTensor::I32(toks, vec![bucket])], None)?
            .remove(0);
        let pos_t = HostTensor::I32(pos, vec![bucket]);

        for l in 0..cfg.n_layers {
            // ---- QKV (split from attention so correction can intercept
            // between computing q_i and attending, per Fig. 4b) ----
            let t0 = Instant::now();
            let out = self.rt.run(
                &self.art(&format!("layer_qkv_b{}", bucket)),
                &[h.clone(), pos_t.clone()],
                Some(l),
            )?;
            self.stats.qkv_secs += t0.elapsed().as_secs_f64();
            let mut it = out.into_iter();
            let q_t = it.next().unwrap();
            let k_new_t = it.next().unwrap();
            let v_new_t = it.next().unwrap();
            let q_all = q_t.f32s()?.to_vec();
            let k_new = k_new_t.f32s()?.to_vec();
            let v_new = v_new_t.f32s()?.to_vec();

            // ---- selection with the current step's queries (batched):
            // used NOW for corrected heads, and for the NEXT step's
            // speculative reuse. Needs only the compute half, so it runs
            // before the drain to hide a little more of the worker's
            // recall. ----
            let t0 = Instant::now();
            let sel_pages = self.run_selection_batch(seqs, l, &q_all, bucket)?;
            self.stats.select_secs += t0.elapsed().as_secs_f64();

            // ---- drain: re-attach this layer's transfer half (the
            // previous step's speculative recall) before anything below
            // touches the select table or pool ----
            for seq in seqs.iter_mut() {
                self.drain_layer(seq, l);
            }

            // ---- correction check + blocking recall for flagged heads --
            for (i, seq) in seqs.iter_mut().enumerate() {
                let q_i = &q_all[i * qo * dh..(i + 1) * qo * dh];
                // Following the paper (App. A), compression heuristics are
                // not applied to the first layer: its query similarity is
                // inherently low (h = embedding only), so layer 0 always
                // runs blocking selection and is excluded from correction
                // statistics.
                let decision = if self.blocking_mode || l == 0 {
                    None
                } else {
                    seq.spec[l].head_similarities(q_i).map(|sims| {
                        self.stats.correction_checks += m as u64;
                        if self.record_sims {
                            self.sim_trace.push((l, sims.clone()));
                        }
                        correction_check(&sims, m, &self.params)
                    })
                };
                match decision {
                    Some(d) => {
                        for &head in &d.corrected_heads {
                            self.stats.corrections += 1;
                            let t1 = Instant::now();
                            let nrec = seq.kv.apply_selection(
                                l,
                                head,
                                &sel_pages[i][head],
                                &mut seq.xfer,
                            );
                            let dt = t1.elapsed().as_secs_f64();
                            self.stats.recall_secs += dt;
                            self.stats.recall_exposed_secs += dt;
                            self.stats.recalled_pages += nrec as u64;
                        }
                        let hit = m - d.corrected_heads.len();
                        self.stats.speculative_hits += hit as u64;
                    }
                    None => {
                        // blocking/first-layer path: install the current
                        // selection before attention.
                        for head in 0..m {
                            let t1 = Instant::now();
                            let nrec = seq.kv.apply_selection(
                                l,
                                head,
                                &sel_pages[i][head],
                                &mut seq.xfer,
                            );
                            let dt = t1.elapsed().as_secs_f64();
                            self.stats.recall_secs += dt;
                            self.stats.recall_exposed_secs += dt;
                            self.stats.recalled_pages += nrec as u64;
                        }
                    }
                }
            }

            // ---- incremental gather into persistent per-seq lanes ----
            let t0 = Instant::now();
            let (mut gk, mut gv, mut gvalid) = self.take_attn_scratch(bucket, m, s, dh);
            for (i, seq) in seqs.iter_mut().enumerate() {
                let (gpu, x) = seq.kv.layers[l].parts_mut();
                let buf = &mut seq.gather[l];
                gpu.gather_dirty(&mut x.select, &mut buf.k, &mut buf.v, &mut buf.valid);
                gk[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&buf.k);
                gv[i * m * s * dh..(i + 1) * m * s * dh].copy_from_slice(&buf.v);
                gvalid[i * m * s..(i + 1) * m * s].copy_from_slice(&buf.valid);
            }
            for lane in n..bucket {
                gvalid[lane * m * s..(lane + 1) * m * s].iter_mut().for_each(|v| *v = 0.0);
            }
            self.stats.gather_secs += t0.elapsed().as_secs_f64();

            // ---- attention ----
            let t0 = Instant::now();
            let args = [
                h,
                q_t,
                k_new_t,
                v_new_t,
                HostTensor::F32(gk, vec![bucket, m, s, dh]),
                HostTensor::F32(gv, vec![bucket, m, s, dh]),
                HostTensor::F32(gvalid, vec![bucket, m, s]),
            ];
            let out = self.rt.run(&self.art(&format!("layer_attn_b{}", bucket)), &args, Some(l))?;
            self.stats.attn_secs += t0.elapsed().as_secs_f64();
            h = out.into_iter().next().unwrap();
            // reclaim the big gather tensors for the next layer/step
            let mut it = args.into_iter().skip(4);
            if let (
                Some(HostTensor::F32(a, _)),
                Some(HostTensor::F32(b, _)),
                Some(HostTensor::F32(c, _)),
            ) = (it.next(), it.next(), it.next())
            {
                self.attn_scratch = Some((a, b, c));
            }

            // ---- append new KV, offload completed pages ----
            for (i, seq) in seqs.iter_mut().enumerate() {
                let kn = &k_new[i * m * dh..(i + 1) * m * dh];
                let vn = &v_new[i * m * dh..(i + 1) * m * dh];
                seq.kv.append(l, kn, vn, &mut seq.xfer);
            }

            // ---- speculative recall for the NEXT step (non-corrected
            // heads; page-cache diff makes re-selection cheap). With
            // overlap on, the transfer half is checked out to the worker
            // and the recall hides under the remaining layers' compute;
            // serial mode keeps it inline as the ablation baseline. ----
            if !self.blocking_mode {
                if overlap {
                    for (i, seq) in seqs.iter_mut().enumerate() {
                        let xfer = seq.kv.layers[l].take_xfer();
                        let pipe = self.pipeline.as_mut().expect("pipeline active");
                        pipe.submit(RecallJob {
                            seq_uid: seq.uid,
                            layer: l,
                            selections: sel_pages[i].clone(),
                            xfer,
                        });
                        self.stats.recall_jobs += 1;
                        // sweep finished completions first so this counts
                        // actual worker backlog, not jobs-since-drain
                        pipe.poll();
                        let depth = pipe.pending() as u64;
                        self.stats.max_queue_depth = self.stats.max_queue_depth.max(depth);
                    }
                } else {
                    for (i, seq) in seqs.iter_mut().enumerate() {
                        for head in 0..m {
                            let t1 = Instant::now();
                            let nrec =
                                seq.kv.apply_selection(l, head, &sel_pages[i][head], &mut seq.xfer);
                            let dt = t1.elapsed().as_secs_f64();
                            self.stats.recall_secs += dt;
                            self.stats.recall_exposed_secs += dt;
                            self.stats.recalled_pages += nrec as u64;
                        }
                    }
                }
            }

            // remember q for the next step's correction check
            for (i, seq) in seqs.iter_mut().enumerate() {
                seq.spec[l].store(&q_all[i * qo * dh..(i + 1) * qo * dh]);
            }
        }

        // ---- logits + sampling ----
        let t0 = Instant::now();
        let lg = self
            .rt
            .run(&self.art(&format!("logits_b{}", bucket)), &[h], None)?
            .remove(0)
            .into_f32s()?;
        self.stats.logits_secs += t0.elapsed().as_secs_f64();
        for (i, seq) in seqs.iter_mut().enumerate() {
            let row = &lg[i * cfg.vocab..(i + 1) * cfg.vocab];
            let tok = sample_token(row, &seq.sample, &mut seq.rng);
            seq.tokens.push(tok);
            if Some(tok) == seq.eos {
                seq.finished = true;
            }
        }

        // Finished sequences leave the batch after this step: reclaim
        // their in-flight transfer halves so nothing strands on the
        // worker.
        for seq in seqs.iter_mut() {
            if seq.done() {
                self.drain_sequence(seq);
            }
        }

        self.stats.steps += 1;
        self.stats.decode_secs += t_step.elapsed().as_secs_f64();
        Ok(())
    }

    /// Re-attach one layer's transfer half if its speculative-recall job
    /// is still in flight; merges the worker's counters/stats.
    fn drain_layer(&mut self, seq: &mut Sequence, layer: usize) {
        if !seq.kv.layers[layer].in_flight() {
            return;
        }
        let pipe = self
            .pipeline
            .as_mut()
            .expect("transfer half checked out but no pipeline is running");
        let t0 = Instant::now();
        let done = pipe.wait(seq.uid, layer);
        let waited = t0.elapsed().as_secs_f64();
        // Of the worker's busy time, the part we just blocked for was NOT
        // hidden; only the remainder ran under compute.
        self.stats.recall_exposed_secs += waited;
        self.stats.recall_hidden_secs += (done.busy_secs - waited).max(0.0);
        self.stats.recall_secs += done.busy_secs;
        self.stats.recalled_pages += done.recalled_pages as u64;
        seq.xfer.counters = seq.xfer.counters.merged(&done.counters);
        seq.kv.layers[layer].put_xfer(done.xfer);
    }

    /// Block until every in-flight recall job of this sequence has been
    /// re-attached. Called automatically when a sequence finishes inside
    /// `decode_step`; callers abandoning a sequence mid-generation must
    /// call it themselves before dropping the engine.
    pub fn drain_sequence(&mut self, seq: &mut Sequence) {
        if self.pipeline.is_none() {
            return;
        }
        for l in 0..self.cfg.n_layers {
            self.drain_layer(seq, l);
        }
    }

    /// Take (or allocate) the batch gather tensors for this bucket.
    fn take_attn_scratch(&mut self, bucket: usize, m: usize, s: usize, dh: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let want_kv = bucket * m * s * dh;
        let want_valid = bucket * m * s;
        match self.attn_scratch.take() {
            Some((gk, gv, gvalid)) if gk.len() == want_kv && gvalid.len() == want_valid => {
                (gk, gv, gvalid)
            }
            _ => (vec![0.0; want_kv], vec![0.0; want_kv], vec![0.0; want_valid]),
        }
    }

    /// Batched page selection via the select artifact; returns pages per
    /// (sequence, kv head), filtered to genuinely selectable pages. The
    /// artifact inputs live in a scratch reused across layers/steps.
    fn run_selection_batch(
        &mut self,
        seqs: &mut [&mut Sequence],
        layer: usize,
        q_all: &[f32],
        bucket: usize,
    ) -> Result<Vec<Vec<Vec<usize>>>> {
        let (m, dh, qo, p) = (self.cfg.n_kv, self.cfg.d_head, self.cfg.n_qo, self.cfg.n_pages_max());
        let k_sel = self.cfg.select_pages;
        let rebuild = self.sel_scratch.as_ref().map_or(true, |sc| sc.bucket != bucket);
        if rebuild {
            self.sel_scratch = Some(SelScratch {
                bucket,
                args: vec![
                    HostTensor::F32(vec![0.0; bucket * qo * dh], vec![bucket, qo, dh]),
                    HostTensor::F32(vec![0.0; bucket * m * p * dh], vec![bucket, m, p, dh]),
                    HostTensor::F32(vec![0.0; bucket * m * p * dh], vec![bucket, m, p, dh]),
                    HostTensor::F32(vec![0.0; bucket * p], vec![bucket, p]),
                ],
            });
        }
        {
            let scratch = self.sel_scratch.as_mut().unwrap();
            let mut it = scratch.args.iter_mut();
            let (qt, smin_t, smax_t, mask_t) =
                (it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
            let (
                HostTensor::F32(qd, _),
                HostTensor::F32(lo, _),
                HostTensor::F32(hi, _),
                HostTensor::F32(mk, _),
            ) = (qt, smin_t, smax_t, mask_t)
            else {
                unreachable!("selection scratch is always f32")
            };
            qd[..q_all.len()].copy_from_slice(q_all);
            qd[q_all.len()..].iter_mut().for_each(|x| *x = 0.0);
            for (i, seq) in seqs.iter().enumerate() {
                let gpu = &seq.kv.layers[layer].gpu;
                gpu.summaries_sanitized_into(
                    &mut lo[i * m * p * dh..(i + 1) * m * p * dh],
                    &mut hi[i * m * p * dh..(i + 1) * m * p * dh],
                );
                gpu.selectable_mask_into(&mut mk[i * p..(i + 1) * p]);
            }
            // padded lanes: clean mask so the artifact selects nothing
            for lane in seqs.len()..bucket {
                mk[lane * p..(lane + 1) * p].iter_mut().for_each(|x| *x = 0.0);
            }
        }
        let name = {
            let variant = self.params.variant.as_str();
            self.art(&format!("select_{}_b{}", variant, bucket))
        };
        let out = {
            let scratch = self.sel_scratch.as_ref().unwrap();
            self.rt.run(&name, &scratch.args, None)?
        };
        let idx = out[1].i32s()?;
        let scratch = self.sel_scratch.as_ref().unwrap();
        let HostTensor::F32(mk, _) = &scratch.args[3] else {
            unreachable!("selection scratch is always f32")
        };
        let mut result = Vec::with_capacity(seqs.len());
        for i in 0..seqs.len() {
            let mut per_head = Vec::with_capacity(m);
            for head in 0..m {
                let base = (i * m + head) * k_sel;
                let pages: Vec<usize> = idx[base..base + k_sel]
                    .iter()
                    .map(|&x| x as usize)
                    .filter(|&pg| pg < p && mk[i * p + pg] > 0.0)
                    .collect();
                per_head.push(pages);
            }
            result.push(per_head);
        }
        Ok(result)
    }

    /// Selection for a single sequence (prefill seeding path, bucket 1).
    fn run_selection_single(
        &mut self,
        seq: &mut Sequence,
        layer: usize,
        q: &[f32],
    ) -> Result<Vec<Vec<usize>>> {
        let cfg = &self.cfg;
        let (m, dh, qo, p) = (cfg.n_kv, cfg.d_head, cfg.n_qo, cfg.n_pages_max());
        let gpu = &seq.kv.layers[layer].gpu;
        let (smin, smax) = gpu.summaries_sanitized();
        let mask = gpu.selectable_mask();
        let variant = self.params.variant.as_str();
        let out = self.rt.run(
            &self.art(&format!("select_{}_b1", variant)),
            &[
                HostTensor::F32(q.to_vec(), vec![1, qo, dh]),
                HostTensor::F32(smin, vec![1, m, p, dh]),
                HostTensor::F32(smax, vec![1, m, p, dh]),
                HostTensor::F32(mask.clone(), vec![1, p]),
            ],
            None,
        )?;
        let idx = out[1].i32s()?;
        let k_sel = cfg.select_pages;
        Ok((0..m)
            .map(|head| {
                idx[head * k_sel..(head + 1) * k_sel]
                    .iter()
                    .map(|&x| x as usize)
                    .filter(|&pg| pg < p && mask[pg] > 0.0)
                    .collect()
            })
            .collect())
    }

    /// Convenience: generate to completion for a single sequence.
    pub fn generate(&mut self, seq: &mut Sequence) -> Result<()> {
        let lg = self.prefill(seq)?;
        let params = seq.sample.clone();
        let tok = sample_token(&lg, &params, &mut seq.rng);
        seq.tokens.push(tok);
        if Some(tok) == seq.eos {
            seq.finished = true;
        }
        while !seq.done() {
            let mut batch = [&mut *seq];
            self.decode_step(&mut batch)?;
        }
        Ok(())
    }
}

impl Backend for Engine {
    fn model(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_sequence(
        &self,
        id: u64,
        prompt: Vec<i32>,
        max_new: usize,
        sample: SampleParams,
    ) -> Sequence {
        Engine::new_sequence(self, id, prompt, max_new, sample)
    }

    fn prefill(&mut self, seq: &mut Sequence) -> Result<Vec<f32>> {
        Engine::prefill(self, seq)
    }

    fn decode_step(&mut self, seqs: &mut [&mut Sequence]) -> Result<()> {
        Engine::decode_step(self, seqs)
    }

    fn retire_sequence(&mut self, seq: &mut Sequence) {
        self.drain_sequence(seq);
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }
}

/// Temperature + nucleus sampling (greedy when temperature == 0).
pub fn sample_token(logits: &[f32], p: &SampleParams, rng: &mut Rng) -> i32 {
    if p.temperature <= 0.0 {
        return crate::linalg::argmax(logits) as i32;
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| x / p.temperature).collect();
    crate::linalg::softmax_inplace(&mut probs);
    if p.top_p < 1.0 {
        truncate_top_p(&mut probs, p.top_p);
    }
    rng.categorical(&probs) as i32
}

/// Zero every probability outside the nucleus: the shortest prefix of
/// the (probability-descending, index-ascending on ties) order whose
/// mass reaches `top_p`. Uses partial selection with a doubling
/// candidate set instead of sorting the whole vocabulary — the nucleus
/// is tiny compared to V, so this is O(V + c log c) per call instead of
/// O(V log V), and it needs no auxiliary hash set.
fn truncate_top_p(probs: &mut [f32], top_p: f32) {
    let v = probs.len();
    if v == 0 {
        return;
    }
    let cmp = |a: &usize, b: &usize| {
        probs[*b]
            .partial_cmp(&probs[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    let mut order: Vec<usize> = (0..v).collect();
    let mut k = 64.min(v);
    let cut = loop {
        if k < v {
            order.select_nth_unstable_by(k - 1, cmp);
        }
        order[..k].sort_unstable_by(cmp);
        let mut acc = 0.0f32;
        let mut cut = None;
        for (rank, &i) in order[..k].iter().enumerate() {
            acc += probs[i];
            if acc >= top_p {
                cut = Some(rank + 1);
                break;
            }
        }
        match cut {
            Some(c) => break c,
            // numerical shortfall: the whole distribution is the nucleus
            None if k == v => break v,
            None => k = (k * 2).min(v),
        }
    };
    for &i in &order[cut..] {
        probs[i] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The seed's straightforward implementation (full vocab sort + hash
    /// set), kept as the behavioural reference for the optimized path.
    fn sample_token_reference(logits: &[f32], p: &SampleParams, rng: &mut Rng) -> i32 {
        if p.temperature <= 0.0 {
            return crate::linalg::argmax(logits) as i32;
        }
        let mut probs: Vec<f32> = logits.iter().map(|&x| x / p.temperature).collect();
        crate::linalg::softmax_inplace(&mut probs);
        if p.top_p < 1.0 {
            let mut order: Vec<usize> = (0..probs.len()).collect();
            order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let mut acc = 0.0f32;
            let mut cut = probs.len();
            for (rank, &i) in order.iter().enumerate() {
                acc += probs[i];
                if acc >= p.top_p {
                    cut = rank + 1;
                    break;
                }
            }
            let keep: std::collections::HashSet<usize> = order[..cut].iter().cloned().collect();
            for (i, pr) in probs.iter_mut().enumerate() {
                if !keep.contains(&i) {
                    *pr = 0.0;
                }
            }
        }
        rng.categorical(&probs) as i32
    }

    #[test]
    fn nucleus_sampling_matches_reference_for_fixed_seeds() {
        let mut gen = Rng::new(0xBEEF);
        for case in 0..200u64 {
            let vocab = 1 + gen.below(300);
            let logits: Vec<f32> = (0..vocab).map(|_| gen.normal_f32(0.0, 3.0)).collect();
            let p = SampleParams {
                temperature: 0.25 + gen.f32() * 1.5,
                top_p: [0.1f32, 0.5, 0.9, 0.95, 0.999, 1.0][gen.below(6)],
                seed: case,
            };
            let mut r1 = Rng::new(case);
            let mut r2 = Rng::new(case);
            let a = sample_token(&logits, &p, &mut r1);
            let b = sample_token_reference(&logits, &p, &mut r2);
            assert_eq!(a, b, "case {} vocab {} top_p {}", case, vocab, p.top_p);
            // identical RNG consumption, so downstream draws stay aligned
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng stream diverged at case {}", case);
        }
    }

    #[test]
    fn nucleus_growth_past_initial_candidate_set() {
        // near-uniform distribution with top_p close to 1 forces the
        // doubling loop well past the initial 64 candidates.
        let logits = vec![0.0f32; 4096];
        let p = SampleParams { temperature: 1.0, top_p: 0.999, seed: 1 };
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = sample_token(&logits, &p, &mut r1);
        let b = sample_token_reference(&logits, &p, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn greedy_ignores_rng() {
        let logits = vec![0.1f32, 2.0, -1.0];
        let mut rng = Rng::new(4);
        assert_eq!(sample_token(&logits, &SampleParams::greedy(), &mut rng), 1);
    }
}
